"""Switchable-precision serving demo: batched requests against one packed
SEFP master with per-request-class precision (the paper's deployment
scenario: generation tasks want high precision, understanding tasks want
low latency) and a mid-stream precision drop for long generations.

Everything runs device-resident: decode is one fused scan per generation
(one host transfer), and every precision below — including the
mid-generation drop — is a traced mantissa width of the SAME compiled
executable.  No weight tree is ever rebuilt.

    PYTHONPATH=src python examples/serve_switchable.py
"""

import time

import jax
import numpy as np

from repro import configs as C
from repro.models import init_params
from repro.serve import SwitchableServer
from repro.train.data import SyntheticCorpus


def main():
    cfg = C.get_reduced("llama3_8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    server = SwitchableServer(cfg, params, max_len=128)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=1)

    rep = server.memory_report()
    print(f"model resident as SEFP master: {rep['master_bytes']/1e6:.2f} MB "
          f"({rep['n_params']/1e6:.2f}M params at "
          f"{rep['master_bits_per_param']:.3f} bits/param packed; "
          f"fp16 would be {rep['fp16_bytes']/1e6:.2f} MB)")

    # two request classes arriving in batches
    gen_batch = np.asarray(corpus.batch(0, 4, 33)["inputs"][:, :32])
    cls_batch = np.asarray(corpus.batch(1, 8, 33)["inputs"][:, :32])

    # generation requests: high precision.  set_precision is O(1) — it
    # picks the traced width for the next calls, nothing is rebuilt.
    server.set_precision(7)
    t0 = time.perf_counter()
    gen = server.generate(gen_batch, max_new=32)
    t_gen = time.perf_counter() - t0
    print(f"\n[generation @E5M7] batch=4, 32 new tokens in {t_gen:.2f}s "
          f"({4*32/t_gen:.1f} tok/s, {gen.host_transfers} host transfer)")

    # understanding requests: drop to E5M3 — same executable, new scalar
    server.set_precision(3)
    t0 = time.perf_counter()
    cls = server.generate(cls_batch, max_new=4)
    t_cls = time.perf_counter() - t0
    print(f"[understanding @E5M3] batch=8, 4 new tokens in {t_cls:.2f}s "
          f"({8*4/t_cls:.1f} tok/s, {cls.host_transfers} host transfer)")

    # long generation with a precision schedule: high for the first tokens,
    # low for the tail (prefill/decode asymmetry from the paper).  The
    # schedule is a traced int32 array consumed inside the fused decode
    # scan — switching mid-generation costs nothing per token.
    schedule = [8] * 8 + [4] * 16
    mixed = server.generate(gen_batch, max_new=24,
                            precision_schedule=schedule)
    print(f"[scheduled] precision trace: {mixed.precision_trace}")
    print("\nall three request classes served from ONE packed master, "
          "one fused decode scan per generation — no per-precision model "
          "zoo, no weight rebuilds.")


if __name__ == "__main__":
    main()
