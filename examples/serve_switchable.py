"""Switchable-precision serving demo over the repro.api facade: one packed
artifact, one PrecisionPolicy, three request classes (the paper's deployment
scenario: generation tasks want high precision, understanding tasks want low
latency, long generations drop precision mid-stream).

Everything runs device-resident: decode is one fused scan per generation
(one host transfer), and every precision below — including the
mid-generation drop — is a traced mantissa width of the SAME compiled
executable.  No weight tree is ever rebuilt; loading an exported artifact
performs no fp32 quantize/pack pass at startup.

    PYTHONPATH=src python examples/serve_switchable.py
    # or serve a train-exported artifact:
    PYTHONPATH=src python examples/serve_switchable.py \
        --artifact /tmp/otaro_run/artifact
"""

import argparse
import time

import numpy as np

from repro import api
from repro import configs as C
from repro.train.data import SyntheticCorpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=None,
                    help="serve this exported artifact (default: pack "
                    "random-init weights for a self-contained demo)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    if args.artifact:
        art = api.Artifact.load(args.artifact)
        source = f"loaded {args.artifact} (no pack pass)"
    else:
        import jax
        cfg = C.get_reduced("llama3_8b")
        art = api.Artifact.from_params(
            cfg, api.init_params(cfg, jax.random.PRNGKey(0)))
        source = "packed from random-init fp32"
    cfg = art.cfg

    # ONE policy covers all three request classes; each class lowers to a
    # traced schedule of the same compiled decode scan.
    policy = (api.PrecisionPolicy.all_widths()
              .with_class("generation", 7)
              .with_class("understanding", 3)
              .with_class("longform", [(8, 8), (4, None)]))
    server = art.server(policy, max_len=128)
    print(f"server up in {time.perf_counter() - t0:.2f}s ({source})")

    rep = server.memory_report()
    print(f"model resident as SEFP master: {rep['master_bytes']/1e6:.2f} MB "
          f"({rep['n_params']/1e6:.2f}M params at "
          f"{rep['master_bits_per_param']:.3f} bits/param packed; "
          f"fp16 would be {rep['fp16_bytes']/1e6:.2f} MB)")

    # two request classes arriving in batches
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=1)
    gen_batch = np.asarray(corpus.batch(0, 4, 33)["inputs"][:, :32])
    cls_batch = np.asarray(corpus.batch(1, 8, 33)["inputs"][:, :32])

    t0 = time.perf_counter()
    gen = server.generate(gen_batch, max_new=32, request_class="generation")
    t_gen = time.perf_counter() - t0
    print(f"\n[generation @E5M{gen.precision_trace[0]}] batch=4, 32 new "
          f"tokens in {t_gen:.2f}s ({4*32/t_gen:.1f} tok/s, "
          f"{gen.host_transfers} host transfer)")

    t0 = time.perf_counter()
    cls = server.generate(cls_batch, max_new=4,
                          request_class="understanding")
    t_cls = time.perf_counter() - t0
    print(f"[understanding @E5M{cls.precision_trace[0]}] batch=8, 4 new "
          f"tokens in {t_cls:.2f}s ({8*4/t_cls:.1f} tok/s, "
          f"{cls.host_transfers} host transfer)")

    # long generation: high for the first tokens, low for the tail (the
    # paper's prefill/decode asymmetry).  The class plan compiles to a
    # traced int32 array consumed inside the fused decode scan — switching
    # mid-generation costs nothing per token.
    mixed = server.generate(gen_batch, max_new=24, request_class="longform")
    print(f"[longform] precision trace: {mixed.precision_trace}")
    print("\nall three request classes served from ONE packed master under "
          "ONE PrecisionPolicy, one fused decode scan per generation — no "
          "per-precision model zoo, no weight rebuilds.")


if __name__ == "__main__":
    main()
