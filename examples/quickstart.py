"""Quickstart: the whole OTARo lifecycle in ~40 lines of repro.api.

One ``finetune`` call tunes a small LM for every SEFP precision (BPS + LAA)
and exports ONE packed artifact; that artifact is then evaluated at every
width and served at two precisions — all from a single set of weights.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.train.data import SyntheticCorpus

# 1. a small model + task ----------------------------------------------------
cfg = api.ModelConfig(name="quickstart", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
                      d_ff=256, vocab_size=512, q_block=32, kv_block=32,
                      loss_chunk=32, remat="none", dtype="float32")
corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)

# 2. once fine-tuning for ALL precisions (the paper's method) ----------------
policy = api.PrecisionPolicy.all_widths()     # BPS over E5M8..E5M3
result = api.finetune(cfg, out_dir="/tmp/otaro_quickstart", policy=policy,
                      steps=400, global_batch=8, seq=64, lr=0.15,
                      ckpt_every=200, log_every=100,
                      otaro_overrides=dict(lam=5.0, laa_n=10))  # paper
for rec in result.history:
    if "loss" in rec:
        print(f"step {rec['step']:4d}  loss {rec['loss']:.3f}  "
              f"trained at E5M{rec['m']}")

# 3. one artifact, every precision --------------------------------------------
art = result.artifact
eval_batch = {k: jnp.asarray(v)
              for k, v in corpus.batch(10**7, 8, 64).items()}
print("\nPPL by precision (one artifact, no re-tuning):")
for m, loss in art.evaluate(eval_batch).items():
    print(f"  E5M{m}: {float(jnp.exp(loss)):7.3f}")

# 4. deploy: load the artifact, switch precision at runtime -------------------
server = api.Artifact.load(result.artifact_path).server(max_len=96)
prompts = np.asarray(corpus.batch(0, 2, 17)["inputs"][:, :16])
server.set_precision(8)
hi = server.generate(prompts, max_new=8).tokens
server.set_precision(3)   # a mantissa shift away — no scales, no reload
lo = server.generate(prompts, max_new=8).tokens
rep = server.memory_report()
print(f"\nserved at E5M8 -> {hi[0].tolist()}")
print(f"served at E5M3 -> {lo[0].tolist()}")
print(f"packed master: {rep['master_bytes']/1e6:.2f} MB "
      f"(fp16 would be {rep['fp16_bytes']/1e6:.2f} MB)")
