"""Quickstart: OTARo in ~60 lines.

Fine-tunes a small LM with OTARo (BPS + LAA), evaluates it at every SEFP
precision, then packs one master and serves it at two precisions — all from
a single set of weights.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OTAROConfig, init_state, make_eval_fn, make_otaro_step
from repro.models import ModelConfig, init_params, make_loss_fn
from repro.serve import SwitchableServer
from repro.train import sgd
from repro.train.data import SyntheticCorpus

# 1. a small model + task ----------------------------------------------------
cfg = ModelConfig(name="quickstart", family="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
                  vocab_size=512, q_block=32, kv_block=32, loss_chunk=32,
                  remat="none", dtype="float32")
corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
params = init_params(cfg, jax.random.PRNGKey(0))
loss_fn = make_loss_fn(cfg)

# 2. once fine-tuning for ALL precisions (the paper's method) ----------------
ocfg = OTAROConfig(mode="otaro", lam=5.0, laa_n=10)   # paper defaults
opt = sgd(0.15)
step = jax.jit(make_otaro_step(loss_fn, opt, ocfg))
state = init_state(params, opt, ocfg)
for i in range(400):
    batch = {k: jnp.asarray(v) for k, v in corpus.batch(i, 8, 64).items()}
    state, metrics = step(state, batch)
    if i % 100 == 0:
        print(f"step {i:4d}  loss {float(metrics['loss']):.3f}  "
              f"trained at E5M{int(metrics['mantissa_width'])}")

# 3. one model, every precision ----------------------------------------------
evalf = jax.jit(make_eval_fn(loss_fn, ocfg))
eval_batch = {k: jnp.asarray(v) for k, v in corpus.batch(10**7, 8, 64).items()}
print("\nPPL by precision (one model, no re-tuning):")
for m in (8, 7, 6, 5, 4, 3):
    ppl = float(jnp.exp(evalf(state.params, eval_batch, jnp.int32(m))))
    print(f"  E5M{m}: {ppl:7.3f}")

# 4. deploy: pack once, switch precision at runtime ---------------------------
server = SwitchableServer(cfg, state.params, max_len=96)
prompts = np.asarray(corpus.batch(0, 2, 17)["inputs"][:, :16])
server.set_precision(8)
hi = server.generate(prompts, max_new=8).tokens
server.set_precision(3)   # a mantissa shift away — no scales, no reload
lo = server.generate(prompts, max_new=8).tokens
rep = server.memory_report()
print(f"\nserved at E5M8 -> {hi[0].tolist()}")
print(f"served at E5M3 -> {lo[0].tolist()}")
print(f"packed master: {rep['master_bytes']/1e6:.2f} MB "
      f"(fp16 would be {rep['fp16_bytes']/1e6:.2f} MB)")
