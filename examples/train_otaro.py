"""End-to-end training driver: fine-tune any registered architecture with
OTARo, with checkpoint/resume fault tolerance and multi-width evaluation.

Reduced configs run on this CPU container; full configs are for TPU pods
(same code path — pass --full and a real mesh materializes via
launch/train.py).

    # a few hundred steps on the paper's task model (reduced):
    PYTHONPATH=src python examples/train_otaro.py --arch llama3_2_1b \
        --steps 300 --out /tmp/otaro_run

    # resume after an interruption (same command — auto-resumes):
    PYTHONPATH=src python examples/train_otaro.py --arch llama3_2_1b \
        --steps 300 --out /tmp/otaro_run
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.core import otaro as otaro_lib
from repro.models import model_zoo as Z
from repro.train import optimizer as opt_lib
from repro.train import runner as runner_lib
from repro.train import steps as steps_lib
from repro.train.data import SyntheticCorpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (TPU-scale) config instead of reduced")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="otaro",
                    choices=["otaro", "bps_only", "uniform", "fixed", "fp16"])
    ap.add_argument("--out", default="/tmp/otaro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = C.get_config(args.arch) if args.full else C.get_reduced(args.arch)
    print(f"training {cfg.name} ({cfg.family}) with mode={args.mode}")

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    ocfg = otaro_lib.OTAROConfig(mode=args.mode)
    opt = opt_lib.sgd(args.lr)
    step_fn, init_fn = steps_lib.make_train_step(cfg, ocfg, opt, mesh=None)

    def batch_fn(step):
        b = corpus.batch(step, args.batch, args.seq)
        return {k: jnp.asarray(v) for k, v in b.items()}

    job = runner_lib.JobConfig(total_steps=args.steps, out_dir=args.out,
                               ckpt_every=args.ckpt_every, log_every=20)
    state, history = runner_lib.run_training(
        step_fn, lambda: init_fn(jax.random.PRNGKey(0)), batch_fn, job)

    # evaluate the ONE fine-tuned model at every precision
    evalf = steps_lib.make_eval_step(cfg, ocfg)
    eb = batch_fn(10_000_000)
    print("\nfinal PPL by precision:")
    for m in ocfg.widths:
        ppl = float(np.exp(float(evalf(state.params, eb, jnp.int32(m)))))
        print(f"  E5M{m}: {ppl:8.3f}")


if __name__ == "__main__":
    main()
