"""End-to-end training driver over the repro.api facade: fine-tune any
registered architecture ONCE with OTARo, export the all-precision serving
artifact, and evaluate the deployed numerics at every width.

Reduced configs run on this CPU container; full configs are for TPU pods
(same code path — pass --full and a real mesh materializes via
launch/train.py).

    # a few hundred steps on the paper's task model (reduced):
    PYTHONPATH=src python examples/train_otaro.py --arch llama3_2_1b \
        --steps 300 --out /tmp/otaro_run

    # resume after an interruption (same command — auto-resumes), then
    # serve the exported artifact without touching fp32 again:
    PYTHONPATH=src python examples/serve_switchable.py \
        --artifact /tmp/otaro_run/artifact
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro import api
from repro import configs as C
from repro.train.data import SyntheticCorpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (TPU-scale) config instead of reduced")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="otaro",
                    choices=["otaro", "bps_only", "uniform", "fixed", "fp16"])
    ap.add_argument("--out", default="/tmp/otaro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--no-export", action="store_true")
    args = ap.parse_args()

    cfg = C.get_config(args.arch) if args.full else C.get_reduced(args.arch)
    print(f"training {cfg.name} ({cfg.family}) with mode={args.mode}")

    # ONE PrecisionPolicy drives training (BPS arm set + mode) and, stored
    # in the exported artifact, later serving.
    policy = (api.PrecisionPolicy.fixed(8) if args.mode == "fixed"
              else api.PrecisionPolicy.all_widths(mode=args.mode))
    result = api.finetune(
        cfg, out_dir=args.out, policy=policy, steps=args.steps,
        global_batch=args.batch, seq=args.seq, lr=args.lr,
        ckpt_every=args.ckpt_every, export=not args.no_export)

    if result.artifact is None:
        print("done (no export requested); final step",
              int(result.state.step))
        return

    art = result.artifact
    print(f"\nexported {result.artifact_path}: "
          f"{art.memory_report()['total_bytes']/1e6:.2f} MB packed master; "
          f"BPS visits {art.bps_stats['t_b']}")

    # evaluate the ONE artifact at every trained precision (the numbers a
    # deployment will actually see: master-truncation numerics)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    eb = {k: jnp.asarray(v)
          for k, v in corpus.batch(10_000_000, args.batch, args.seq).items()}
    print("\nfinal PPL by precision (one artifact, no re-tuning):")
    for m, loss in art.evaluate(eb).items():
        print(f"  E5M{m}: {float(np.exp(loss)):8.3f}")


if __name__ == "__main__":
    main()
