"""Conventional per-group scaled integer quantization — the baseline format
OTARo argues against (scales are bit-width-specific, so precision switching
requires re-quantization from the master weights).

Provided so benchmarks/tests can demonstrate the paper's Fig. 1 point
quantitatively: reinterpreting an INT-b2 model's scales at b1 != b2 is
catastrophically wrong, while SEFP truncation is exact re-quantization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def int_quantize(w: jax.Array, bits: int, group_size: int = 64,
                 group_axis: int = -1):
    """Symmetric per-group int quantization.  Returns (dequantized, codes,
    scales)."""
    wf = jnp.moveaxis(w.astype(jnp.float32), group_axis, -1)
    *lead, n = wf.shape
    g = wf.reshape(*lead, n // group_size, group_size)
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.abs(g).max(axis=-1, keepdims=True) / qmax
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(g / scale), -qmax, qmax)
    deq = (codes * scale).reshape(*lead, n)
    deq = jnp.moveaxis(deq, -1, group_axis) if group_axis not in (
        -1, w.ndim - 1) else deq
    return deq.astype(w.dtype), codes, scale


def int_quantize_ste(w: jax.Array, bits: int, group_size: int = 64,
                     group_axis: int = -1) -> jax.Array:
    deq, _, _ = int_quantize(w, bits, group_size, group_axis)
    return w + lax.stop_gradient(deq - w)


def naive_bitwidth_switch(codes: jax.Array, scale: jax.Array,
                          from_bits: int, to_bits: int) -> jax.Array:
    """What a device WOULD have to do to switch an int-quantized model's
    precision without re-deriving scales: shift the codes and reuse the old
    scale.  This is wrong because the scale is anchored to qmax(from_bits) —
    exactly the incompatibility the paper's Fig. 1 illustrates."""
    shift = from_bits - to_bits
    if shift <= 0:
        raise ValueError("only downshifts are meaningful here")
    new_codes = jnp.trunc(codes / (2.0 ** shift))
    return new_codes * scale * (2.0 ** shift)
