from repro.quant.int_quant import int_quantize, int_quantize_ste  # noqa: F401
