"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run launcher must set XLA_FLAGS
before anything initializes devices.
"""

from __future__ import annotations

import jax

from repro.kernels import compat


def _mk(shape, axes):
    return compat.make_mesh(shape, axes, axis_types="auto")


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 = 256 chips per pod; the multi-pod
    variant adds a leading pod axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return _mk((n // model, model), ("data", "model"))


def describe(mesh) -> str:
    return (f"mesh {dict(mesh.shape)} on {mesh.devices.size} "
            f"{mesh.devices.flat[0].platform} devices")
