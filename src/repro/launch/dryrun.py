import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ---------------------------------------
# Multi-pod dry-run: for every (architecture x input-shape x mesh) cell,
# lower + compile the real step function (OTARo train step, prefill step, or
# serve step), print memory/cost analysis, parse collective bytes from the
# optimized HLO, and persist one JSON artifact per cell for the roofline
# harness (benchmarks/roofline.py).
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch minitron_8b \
#       --shape train_4k --mesh single
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--force]
#
# Artifacts: benchmarks/artifacts/<arch>__<shape>__<mesh>.json

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import api                               # noqa: E402
from repro import configs as C                      # noqa: E402
from repro.kernels import compat                    # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model_zoo as Z             # noqa: E402
from repro.models.config import SHAPES, shape_applicable  # noqa: E402
from repro.sharding import partition as SH          # noqa: E402
from repro.train import optimizer as opt_lib        # noqa: E402
from repro.train import steps as steps_lib          # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "benchmarks", "artifacts")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|"
                       r"pred|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=(]+?)\s+"
                      r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_BODY_REF_RE = re.compile(r"body=%?([\w.\-]+)")
_CALL_REF_RE = re.compile(r"(?:to_apply|calls|body|condition)=%?([\w.\-]+)")


def _shape_bytes(outshape: str) -> int:
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(outshape):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO,
    split into top-level vs inside-while-loop-body (the latter execute once
    per loop trip but are counted once in the text — the roofline scales
    them by the dominant trip count, see benchmarks/roofline.py).  Tuple
    outputs contribute every element; ring all-reduce/all-gather move
    ~(n-1)/n of these bytes on the wire."""
    # 1. split into computations
    comps: dict[str, list[str]] = {}
    cur = "__toplevel__"
    comps[cur] = []
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps.setdefault(cur, [])
            continue
        comps[cur].append(line)

    # 2. loop-body computations + transitive callees
    text_of = {name: "\n".join(lines) for name, lines in comps.items()}
    loop_roots = set()
    for body in text_of.values():
        loop_roots.update(_BODY_REF_RE.findall(body))
    in_loop = set()
    frontier = [r for r in loop_roots if r in text_of]
    while frontier:
        name = frontier.pop()
        if name in in_loop:
            continue
        in_loop.add(name)
        for callee in _CALL_REF_RE.findall(text_of.get(name, "")):
            if callee in text_of and callee not in in_loop:
                frontier.append(callee)

    # 3. collect collectives per computation
    out = {k: {"count": 0, "bytes": 0, "loop_count": 0, "loop_bytes": 0}
           for k in _COLLECTIVES}
    for name, lines in comps.items():
        looped = name in in_loop
        for line in lines:
            m = _COLL_RE.match(line.strip())
            if not m:
                continue
            outshape, op = m.group(1), m.group(2)
            nbytes = _shape_bytes(outshape)
            out[op]["count"] += 1
            out[op]["bytes"] += nbytes
            if looped:
                out[op]["loop_count"] += 1
                out[op]["loop_bytes"] += nbytes
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["loop_bytes"] = sum(v["loop_bytes"] for v in out.values()
                            if isinstance(v, dict))
    out["top_level_bytes"] = out["total_bytes"] - out["loop_bytes"]
    return out


def _mem_dict(ma) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    d = {}
    for k in keys:
        d[k] = int(getattr(ma, k, -1))
    d["per_device_total"] = (max(d["argument_size_in_bytes"], 0)
                             + max(d["output_size_in_bytes"], 0)
                             + max(d["temp_size_in_bytes"], 0)
                             - max(d["alias_size_in_bytes"], 0))
    return d


def _serve_param_shapes(cfg):
    """Serving weights in bf16 (the deployed dtype)."""
    shapes = jax.eval_shape(lambda: Z.init_params(cfg, jax.random.PRNGKey(0)))

    def cast(x):
        if x.dtype == jnp.float32:
            return jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        return x

    return jax.tree_util.tree_map(cast, shapes)


def build_cell(cfg, shape, mesh, variant: str = ""):
    """Returns (lowered, state_summary: dict).

    Perf-iteration variants (EXPERIMENTS.md §Perf):
      "dp"         train: batch sharded over ALL mesh axes (pure DP/FSDP;
                   the TP activation all-reduces disappear)
      "bf16master" train: bf16 master weights + LAA buffer (capacity)
      "compress8"  train, multi-pod: SEFP-compressed cross-pod grads
      "kvheads"    decode: KV cache sharded over heads instead of sequence
      "packed"     decode: SEFP packed-master streaming w/ in-scan dequant
    """
    batch_shapes = Z.input_specs(cfg, shape)

    if shape.kind == "train":
        ocfg = api.otaro_config(api.PrecisionPolicy.all_widths())
        opt = opt_lib.sgd(1e-5)
        kw = {}
        if variant in ("dp", "dp128"):
            kw["batch_layout"] = "dp"
        if variant == "dp128":
            import dataclasses as _dc
            cfg = _dc.replace(cfg, ssm_chunk=128)
        if variant == "bf16master":
            kw["master_dtype"] = jnp.bfloat16
        if variant == "compress8":
            kw["compress_pods_m"] = 8
        if variant == "accum4":
            kw["grad_accum"] = 4
            kw["master_dtype"] = jnp.bfloat16  # composes with bf16 master
        step, state_shapes, state_shardings = steps_lib.train_step_artifacts(
            cfg, ocfg, opt, mesh, batch_shapes, **kw)
        lowered = step.lower(state_shapes, batch_shapes)
        return lowered, {"step": "otaro_train_step"}

    if shape.kind == "prefill":
        pre = Z.make_prefill_step(cfg, max_len=shape.seq_len)
        params_shapes = _serve_param_shapes(cfg)
        pspecs = SH.param_pspecs(params_shapes, mesh)
        bspecs = SH.batch_pspecs(batch_shapes, mesh)
        # the produced decode cache must leave the step sharded like the
        # decode cells consume it (otherwise XLA materializes it replicated)
        logits_shapes, cache_shapes = jax.eval_shape(pre, params_shapes,
                                                     batch_shapes)
        cspecs = SH.cache_pspecs(cache_shapes, mesh)
        lspec = SH.batch_pspecs(logits_shapes, mesh)
        step = jax.jit(
            pre,
            in_shardings=(SH.to_named_sharding(pspecs, mesh),
                          SH.to_named_sharding(bspecs, mesh)),
            out_shardings=(SH.to_named_sharding(lspec, mesh),
                           SH.to_named_sharding(cspecs, mesh)))
        lowered = step.lower(params_shapes, batch_shapes)
        return lowered, {"step": "prefill_step"}

    # decode / long_decode
    if variant == "packed":
        # layer_unroll=1: the dry-run lowers deep production stacks on a CPU
        # host — HLO compactness (one layer's graph) beats CPU loop overhead
        master_serve = api.make_packed_serve_step(cfg, layer_unroll=1)

        def serve(params, cache, token, _serve=master_serve):
            # serving width is a traced scalar; lower at the paper's E5M7
            # deployment point (any width shares this executable)
            return _serve(params, cache, token, jnp.int32(7))

        params_shapes = api.packed_param_shapes(cfg)
    else:
        serve = Z.make_serve_step(cfg)
        params_shapes = _serve_param_shapes(cfg)
    # "kv8": SEFP-style 8-bit KV cache (f8_e4m3 storage, bf16 compute) —
    # at decode_32k the memory roofline is KV-bound, not weight-bound, so
    # this is the lever that halves the dominant term (EXPERIMENTS §Perf C)
    kv_dtype = jnp.float8_e4m3fn if variant == "kv8" else jnp.bfloat16
    cache_shapes = Z.cache_specs(cfg, shape, dtype=kv_dtype)
    kv_layout = "heads" if variant == "kvheads" else "seq"
    pspecs = SH.param_pspecs(params_shapes, mesh)
    cspecs = SH.cache_pspecs(cache_shapes, mesh, kv_layout=kv_layout)
    tspecs = SH.batch_pspecs(batch_shapes, mesh)
    step = jax.jit(
        serve,
        in_shardings=(SH.to_named_sharding(pspecs, mesh),
                      SH.to_named_sharding(cspecs, mesh),
                      SH.to_named_sharding(tspecs["token"], mesh)),
        donate_argnums=(1,))
    lowered = step.lower(params_shapes, cache_shapes, batch_shapes["token"])
    return lowered, {"step": f"serve_step{'_' + variant if variant else ''}"}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             artifact_dir: str, force: bool = False,
             variant: str = "") -> dict:
    suffix = f"__{variant}" if variant else ""
    path = os.path.join(artifact_dir,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "skipped"):
            print(f"[cached ] {arch} x {shape_name} x {mesh_kind}{suffix}: "
                  f"{rec['status']}")
            return rec

    cfg = C.get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "family": cfg.family}

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        _write(path, rec)
        print(f"[skipped] {arch} x {shape_name} x {mesh_kind}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):
            lowered, info = build_cell(cfg, shape, mesh, variant)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            print(ma)
            ca = compat.cost_analysis(compiled)
            print({k: v for k, v in ca.items()
                   if k in ("flops", "bytes accessed")})
            hlo = compiled.as_text()
            coll = parse_collective_bytes(hlo)

        rec.update(
            status="ok",
            step=info["step"],
            n_devices=int(mesh.devices.size),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=_mem_dict(ma),
            flops=float(ca.get("flops", -1)),
            bytes_accessed=float(ca.get("bytes accessed", -1)),
            collectives=coll,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(status="error", error=repr(e),
                   traceback=traceback.format_exc()[-4000:])
        print(f"[ERROR  ] {arch} x {shape_name} x {mesh_kind}: {e!r}")
    _write(path, rec)
    if rec["status"] == "ok":
        print(f"[ok     ] {arch} x {shape_name} x {mesh_kind}{suffix}: "
              f"flops={rec['flops']:.3e} "
              f"mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB "
              f"coll={rec['collectives']['total_bytes']/2**20:.1f}MiB "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
    return rec


def _write(path: str, rec: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=1)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all 10)")
    ap.add_argument("--shape", default=None,
                    help="shape name (default: all 4)")
    ap.add_argument("--mesh", default=None, choices=["single", "multi"],
                    help="default: both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="perf variant: dp | bf16master | compress8 | "
                         "kvheads | packed (see build_cell)")
    ap.add_argument("--artifact-dir", default=None)
    args = ap.parse_args()

    artifact_dir = args.artifact_dir or os.path.normpath(ARTIFACT_DIR)
    archs = [args.arch] if args.arch else C.ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                results.append(run_cell(arch, shape, mesh_kind, artifact_dir,
                                        force=args.force,
                                        variant=args.variant))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} cells")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
