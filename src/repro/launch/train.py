"""Production training launcher over the repro.api facade.

On a TPU pod this is invoked once per host (jax.distributed initializes from
the TPU environment); on this CPU container it runs the same code path over
a host mesh (optionally with fake devices via XLA_FLAGS for integration
rehearsal).  Fault tolerance: api.finetune auto-resumes from the newest
valid checkpoint, so the relaunch command IS the recovery procedure; elastic
resizes restore the same checkpoint onto the new mesh.  Every finished run
exports the all-precision serving artifact to <out>/artifact — feed it to
``python -m repro.launch.serve --artifact <out>/artifact``.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --reduced \
        --steps 200 --out /tmp/run1
    # multi-pod with SEFP-compressed cross-pod gradients:
    PYTHONPATH=src python -m repro.launch.train ... --multi-pod \
        --compress-pods 8
"""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-5)        # paper setting
    ap.add_argument("--mode", default="otaro")
    ap.add_argument("--fixed-m", type=int, default=8,
                    help="the single width when --mode fixed")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--out", default="/tmp/otaro_launch")
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--no-export", action="store_true",
                    help="skip the end-of-training artifact export")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 (or 2x16x16) production mesh; "
                         "requires 256/512 devices (TPU pod or XLA_FLAGS)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compress-pods", type=int, default=None,
                    help="SEFP mantissa width for cross-pod grad compression")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="set XLA_FLAGS host device count (rehearsal only)")
    args = ap.parse_args()

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices}")

    from repro import api
    from repro import configs as C
    from repro.launch.mesh import describe, make_host_mesh, \
        make_production_mesh

    cfg = C.get_reduced(args.arch) if args.reduced else C.get_config(args.arch)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh()
    print(f"training {cfg.name} on {describe(mesh)}")

    policy = (api.PrecisionPolicy.fixed(args.fixed_m)
              if args.mode == "fixed"
              else api.PrecisionPolicy.all_widths(mode=args.mode))
    result = api.finetune(
        cfg, out_dir=args.out, policy=policy, steps=args.steps,
        global_batch=args.global_batch, seq=args.seq, lr=args.lr,
        grad_accum=args.grad_accum, mesh=mesh,
        compress_pods_m=args.compress_pods, ckpt_every=args.ckpt_every,
        log_every=20, export=not args.no_export)
    print("done; final step", int(result.state.step))
    if result.artifact is not None:
        nb = result.artifact.memory_report()
        print(f"exported {result.artifact_path}: "
              f"{nb['total_bytes']/1e6:.2f} MB packed master, trained "
              f"widths {list(result.artifact.trained_widths)}")


if __name__ == "__main__":
    main()
