"""Production training launcher.

On a TPU pod this is invoked once per host (jax.distributed initializes from
the TPU environment); on this CPU container it runs the same code path over
a host mesh (optionally with fake devices via XLA_FLAGS for integration
rehearsal).  Fault tolerance: the runner auto-resumes from the newest valid
checkpoint, so the relaunch command IS the recovery procedure; elastic
resizes restore the same checkpoint onto the new mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --reduced \
        --steps 200 --out /tmp/run1
    # multi-pod with SEFP-compressed cross-pod gradients:
    PYTHONPATH=src python -m repro.launch.train ... --multi-pod \
        --compress-pods 8
"""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-5)        # paper setting
    ap.add_argument("--mode", default="otaro")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--out", default="/tmp/otaro_launch")
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 (or 2x16x16) production mesh; "
                         "requires 256/512 devices (TPU pod or XLA_FLAGS)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compress-pods", type=int, default=None,
                    help="SEFP mantissa width for cross-pod grad compression")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="set XLA_FLAGS host device count (rehearsal only)")
    args = ap.parse_args()

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices}")

    import jax
    import jax.numpy as jnp

    from repro import configs as C
    from repro.core import otaro as otaro_lib
    from repro.kernels import compat
    from repro.launch.mesh import describe, make_host_mesh, \
        make_production_mesh
    from repro.train import optimizer as opt_lib
    from repro.train import runner as runner_lib
    from repro.train import steps as steps_lib
    from repro.train.data import SyntheticCorpus

    cfg = C.get_reduced(args.arch) if args.reduced else C.get_config(args.arch)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh()
    print(f"training {cfg.name} on {describe(mesh)}")

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    ocfg = otaro_lib.OTAROConfig(mode=args.mode)
    opt = opt_lib.sgd(args.lr)

    jit_builder, init_fn = steps_lib.make_train_step(
        cfg, ocfg, opt, mesh=mesh, grad_accum=args.grad_accum,
        compress_pods_m=args.compress_pods)

    b0 = corpus.batch(0, args.global_batch, args.seq)
    batch_shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        {k: jnp.asarray(v) for k, v in b0.items()})

    def batch_fn(step):
        b = corpus.batch(step, args.global_batch, args.seq)
        return {k: jnp.asarray(v) for k, v in b.items()}

    with compat.set_mesh(mesh):
        step_fn = jit_builder(batch_shapes)
        job = runner_lib.JobConfig(total_steps=args.steps, out_dir=args.out,
                                   ckpt_every=args.ckpt_every, log_every=20)
        state, _ = runner_lib.run_training(
            step_fn, lambda: init_fn(jax.random.PRNGKey(0)), batch_fn, job)
    print("done; final step", int(state.step))


if __name__ == "__main__":
    main()
