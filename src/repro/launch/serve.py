"""Serving launcher over the repro.api facade: load an exported artifact
(pack-free startup) — or import a train checkpoint / random-init weights —
and serve batched synthetic requests under a PrecisionPolicy.

    # the production path: serve a train-exported artifact directly
    PYTHONPATH=src python -m repro.launch.serve --artifact /tmp/run1/artifact \
        --precision 4 --batch 8 --new-tokens 16

    # import a raw train checkpoint (pays the one fp32->pack pass here)
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced \
        --ckpt /tmp/run1/checkpoints --precision 4

    # smoke-serve random-init weights
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=None,
                    help="exported repro.artifact directory (model config "
                    "travels inside it; --arch not needed)")
    ap.add_argument("--arch", default=None,
                    help="architecture id (required without --artifact)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="train checkpoint dir to import (fails with the "
                    "available steps listed if no DONE-marked step exists)")
    ap.add_argument("--ckpt-widths", default=None,
                    help="comma-separated width set the checkpoint was "
                    "trained over (e.g. '4' for a --mode fixed --fixed-m 4 "
                    "run); default: the full E5M8..E5M3 set")
    ap.add_argument("--precision", type=int, default=8)
    ap.add_argument("--decode-precision", type=int, default=None,
                    help="switch to this width after the first 1/4 of new "
                    "tokens (mid-generation switching; free — the policy "
                    "compiles to the traced schedule of the fused scan)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    if args.artifact is None and args.arch is None:
        ap.error("pass --artifact (self-describing) or --arch")
    if args.artifact is not None and args.ckpt is not None:
        ap.error("--artifact and --ckpt are mutually exclusive: an "
                 "artifact is already packed, a checkpoint would be "
                 "packed here — pick the weight source")

    import numpy as np

    from repro import api
    from repro.train.data import SyntheticCorpus

    t0 = time.perf_counter()
    if args.artifact:
        artifact = api.Artifact.load(args.artifact)
        cfg = artifact.cfg
        source = f"artifact {args.artifact} (pack-free startup)"
    else:
        import jax

        from repro import configs as C
        cfg = (C.get_reduced(args.arch) if args.reduced
               else C.get_config(args.arch))
        if args.ckpt:
            trained_policy = None
            if args.ckpt_widths:
                ws = tuple(int(x) for x in args.ckpt_widths.split(","))
                trained_policy = (
                    api.PrecisionPolicy.fixed(ws[0]) if len(ws) == 1
                    else api.PrecisionPolicy.all_widths(widths=ws))
            artifact = api.Artifact.from_checkpoint(args.ckpt, cfg,
                                                    policy=trained_policy)
            source = (f"checkpoint {args.ckpt} step "
                      f"{artifact.provenance['train_step']} (packed here)")
        else:
            artifact = api.Artifact.from_params(
                cfg, api.init_params(cfg, jax.random.PRNGKey(0)))
            source = "random init (packed here)"

    # the three historical precision knobs, as ONE policy
    policy = api.PrecisionPolicy.all_widths(default=args.precision)
    if args.decode_precision is not None:
        knee = max(1, args.new_tokens // 4)
        policy = policy.with_schedule(
            [(args.precision, knee), (args.decode_precision, None)])

    server = artifact.server(
        policy, max_len=args.prompt_len + args.new_tokens + 1)
    startup_s = time.perf_counter() - t0
    rep = server.memory_report()
    print(f"serving {cfg.name} at E5M{server.precision} from {source}: "
          f"startup {startup_s:.2f}s, master {rep['master_bytes']/1e6:.2f} MB "
          f"(fp16 {rep['fp16_bytes']/1e6:.2f} MB)")

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=3)
    prompts = np.asarray(
        corpus.batch(0, args.batch, args.prompt_len + 1)["inputs"]
        [:, :args.prompt_len])
    res = server.generate(prompts, max_new=args.new_tokens)
    tput = args.batch * args.new_tokens / max(res.decode_seconds, 1e-9)
    print(f"generated {args.new_tokens} tokens x {args.batch} requests "
          f"in {res.decode_seconds:.2f}s ({tput:.1f} tok/s, "
          f"{res.host_transfers} host transfer(s), fused decode scan)")
    if args.decode_precision is not None:
        print(f"precision trace: {res.precision_trace}")
    for i in range(min(2, args.batch)):
        print(f"  req{i}: {res.tokens[i].tolist()}")


if __name__ == "__main__":
    main()
