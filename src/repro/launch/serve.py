"""Serving launcher: load (or initialize) weights, pack the SEFP master,
serve batched synthetic requests with a precision policy.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced \
        --precision 4 --batch 8 --new-tokens 16
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir from launch/train.py (optional)")
    ap.add_argument("--precision", type=int, default=8)
    ap.add_argument("--decode-precision", type=int, default=None,
                    help="switch to this width after the first 1/4 of new "
                    "tokens (mid-generation switching; free — the schedule "
                    "is a traced array of the fused decode scan)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro import configs as C
    from repro.models import init_params
    from repro.serve import SwitchableServer
    from repro.train.data import SyntheticCorpus

    cfg = C.get_reduced(args.arch) if args.reduced else C.get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        from repro.core import otaro as otaro_lib
        from repro.train import checkpoint as CKPT
        from repro.train import optimizer as opt_lib
        like = jax.eval_shape(lambda: otaro_lib.init_state(
            params, opt_lib.sgd(1e-5), otaro_lib.OTAROConfig()))
        state, meta = CKPT.restore_checkpoint(args.ckpt, like)
        params = state.params
        print(f"restored checkpoint step {meta['step']} from {args.ckpt}")

    server = SwitchableServer(
        cfg, params, max_len=args.prompt_len + args.new_tokens + 1)
    server.set_precision(args.precision)
    rep = server.memory_report()
    print(f"serving {cfg.name} at E5M{args.precision}: master "
          f"{rep['master_bytes']/1e6:.2f} MB "
          f"(fp16 {rep['fp16_bytes']/1e6:.2f} MB)")

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=3)
    prompts = np.asarray(
        corpus.batch(0, args.batch, args.prompt_len + 1)["inputs"]
        [:, :args.prompt_len])
    schedule = None
    if args.decode_precision is not None:
        hi, lo, knee = args.precision, args.decode_precision, max(
            1, args.new_tokens // 4)
        schedule = [hi if i < knee else lo for i in range(args.new_tokens)]
    res = server.generate(prompts, max_new=args.new_tokens,
                          precision_schedule=schedule)
    tput = args.batch * args.new_tokens / max(res.decode_seconds, 1e-9)
    print(f"generated {args.new_tokens} tokens x {args.batch} requests "
          f"in {res.decode_seconds:.2f}s ({tput:.1f} tok/s, "
          f"{res.host_transfers} host transfer(s), fused decode scan)")
    if schedule is not None:
        print(f"precision trace: {res.precision_trace}")
    for i in range(min(2, args.batch)):
        print(f"  req{i}: {res.tokens[i].tolist()}")


if __name__ == "__main__":
    main()
