"""Serving launcher over the repro.api facade: load an exported artifact
(pack-free startup) — or import a train checkpoint / random-init weights —
and serve either batched synthetic requests under a PrecisionPolicy
(lockstep mode) or a JSONL request replay through the continuous-batching
scheduler (precision-aware scheduling over per-request classes).

    # the production path: serve a train-exported artifact directly
    PYTHONPATH=src python -m repro.launch.serve --artifact /tmp/run1/artifact \
        --precision 4 --batch 8 --new-tokens 16

    # import a raw train checkpoint (pays the one fp32->pack pass here)
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced \
        --ckpt /tmp/run1/checkpoints --precision 4

    # smoke-serve random-init weights
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced

    # continuous batching: replay a JSONL workload with per-request classes
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --reduced \
        --requests workload.jsonl --slots 8 --width-policy width-rr \
        --classes "generation=8,understanding=4"

JSONL request lines (one object per request):

    {"prompt_len": 24, "max_new": 12, "class": "understanding",
     "arrival": 3, "temperature": 0.0, "top_k": 0, "seed": 1,
     "deadline": 40, "min_width": 4}

``prompt`` may be an explicit token-id list instead of ``prompt_len``
(synthetic tokens are derived from ``seed`` otherwise); ``arrival`` is the
scheduler step clock tick at which the request becomes visible; ``class``
may be a registered class name or a bare int width (auto-registered as a
fixed-width class).  Requests are admitted into free slots as they arrive
and leave on EOS/max_new — no lockstep barrier.

``--width-policy heterogeneous`` (DESIGN.md §14) serves every slot at its
own class width in one fused per-row-width step — exact per-class fidelity
with no width-rr rotation tax; the summary's ``tokens by width`` line
reports the committed-token mix.

Resilience knobs (DESIGN.md §12) apply in replay mode:
``--width-policy slo-degrade`` downshifts widths under pressure (tune with
``--slo-step-ms``), ``--max-queue`` bounds the queue (overflowing arrivals
are *rejected*, reported in the summary), ``--queue-ttl`` evicts stale
queued requests, and per-request ``deadline``/``min_width`` JSONL fields
set step budgets and degradation floors (``--floors
"generation=8"`` sets class-level floors).  Each replayed request prints
its terminal status (ok / evicted / deadline / poisoned).
"""

from __future__ import annotations

import argparse
import json
import time


def _load_requests(path: str, vocab_size: int):
    import numpy as np

    reqs = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: bad JSON: {e}") from e
            if "prompt" in d:
                prompt = np.asarray(d["prompt"], np.int32)
            else:
                n = int(d.get("prompt_len", 16))
                rng = np.random.default_rng(int(d.get("seed", 0)) + lineno)
                prompt = rng.integers(0, vocab_size, (n,)).astype(np.int32)
            reqs.append({
                "prompt": prompt,
                "max_new": int(d.get("max_new", 16)),
                "request_class": d.get("class"),
                "arrival": int(d.get("arrival", 0)),
                "temperature": float(d.get("temperature", 0.0)),
                "top_k": int(d.get("top_k", 0)),
                "seed": int(d.get("seed", 0)),
                "eos_id": d.get("eos_id"),
                "deadline": (int(d["deadline"])
                             if d.get("deadline") is not None else None),
                "min_width": (int(d["min_width"])
                              if d.get("min_width") is not None else None),
            })
    if not reqs:
        raise ValueError(f"{path}: no requests")
    return sorted(reqs, key=lambda r: r["arrival"])


def _replay(server, args, policy):
    """Drive the continuous scheduler over the JSONL workload via
    ``ContinuousScheduler.replay`` (the shared arrival-clock loop)."""
    reqs = _load_requests(args.requests, server.cfg.vocab_size)
    # bare-int classes auto-register as fixed-width plans (bool is an int
    # subclass in JSON — reject it rather than serving "mTrue" at width 1)
    for r in reqs:
        c = r["request_class"]
        if isinstance(c, bool):
            raise ValueError(f"request class must be a name or a width "
                             f"int, got {c!r}")
        if isinstance(c, int):
            name = f"m{c}"
            if name not in policy.classes:
                policy = policy.with_class(name, c)
            r["request_class"] = name
    server.set_policy(policy)
    width_policy = args.width_policy
    if width_policy == "slo-degrade" and args.slo_step_ms is not None:
        from repro.serve.scheduler import SLODegradePolicy
        width_policy = SLODegradePolicy(
            slo_step_seconds=args.slo_step_ms / 1e3)
    spec_decode = None
    if args.speculative:
        spec_kw = {}
        if args.draft_k is not None:
            spec_kw["k"] = args.draft_k
        if args.draft_width is not None:
            spec_kw["draft_width"] = args.draft_width
            spec_kw["candidates"] = (args.draft_width,)
        spec_decode = spec_kw or True
    elif args.draft_width is not None or args.draft_k is not None:
        raise SystemExit("--draft-width/--draft-k require --speculative")
    # full telemetry (trace spans + wall-clock TTFT/ITL, DESIGN.md §16)
    # rides on either observability flag; the metrics registry itself is
    # always on — it is what the report below renders from
    from repro.serve.telemetry import (Telemetry, parse_prometheus,
                                       render_report, serve_metrics)
    telemetry = (Telemetry() if (args.metrics_port is not None
                                 or args.trace_out) else None)
    sched = server.continuous(slots=args.slots,
                              width_policy=width_policy,
                              eos_id=args.eos_id,
                              max_queue=args.max_queue,
                              queue_ttl=args.queue_ttl,
                              page_size=args.page_size,
                              n_pages=args.n_pages,
                              prefill_chunk=args.prefill_chunk,
                              kv_dtype=args.kv_dtype,
                              prefix_cache=not args.no_prefix_cache,
                              spec_decode=spec_decode,
                              telemetry=telemetry)
    metrics_srv = None
    if args.metrics_port is not None:
        metrics_srv = serve_metrics(sched.metrics, args.metrics_port)
        print(f"metrics: {metrics_srv.url}")
    kv = sched.memory_report()["kv_cache"]
    if kv.get("paged"):
        print(f"paged KV: {kv['n_pages']} pages x {kv['page_size']} "
              f"positions ({kv['kv_dtype']}, "
              f"{kv['bytes_per_page']/1e3:.1f} kB/page, pool "
              f"{kv['total_bytes']/1e6:.2f} MB)")
    t0 = time.perf_counter()
    done = sched.replay([{"prompt": r["prompt"], "max_new": r["max_new"],
                          "request_class": r["request_class"],
                          "temperature": r["temperature"],
                          "top_k": r["top_k"], "seed": r["seed"],
                          "eos_id": r["eos_id"], "arrival": r["arrival"],
                          "deadline": r["deadline"],
                          "min_width": r["min_width"]}
                         for r in reqs])
    wall = time.perf_counter() - t0
    stats = sched.stats
    total_toks = sum(len(fr.tokens) for fr in done.values())
    print(f"replayed {len(reqs)} requests / {total_toks} tokens in "
          f"{wall:.2f}s ({total_toks / max(wall, 1e-9):.1f} tok/s) — "
          f"{stats['steps']} steps, occupancy {stats['occupancy']:.2f}, "
          f"commit rate {stats['commit_rate']:.2f}")
    # every aggregate line below renders from the ONE metrics registry
    # (repro/serve/telemetry.py render_report) — the CLI no longer keeps
    # its own formatting of the same counters
    for line in render_report(sched):
        print(line)
    if metrics_srv is not None:
        # self-scrape once: proves the exposition end-to-end (the CI
        # smoke's validation path) and leaves the endpoint's last render
        # in the log for debugging
        text = metrics_srv.scrape()
        parse_prometheus(text)  # raises on a malformed exposition
        print(f"metrics: scraped {len(text.splitlines())} exposition "
              f"lines from {metrics_srv.url} (valid)")
        metrics_srv.close()
    if args.trace_out:
        tracer = sched.telemetry.tracer
        if args.trace_out.endswith(".jsonl"):
            tracer.write_jsonl(args.trace_out)
        else:
            tracer.write_chrome_trace(args.trace_out)
        print(f"trace: {len(tracer.events())} events -> {args.trace_out} "
              f"(open in ui.perfetto.dev; {tracer.dropped} dropped)")
    for rid in sorted(done):
        fr = done[rid]
        widths = dict.fromkeys(fr.decode_widths)
        print(f"  req{rid} class={fr.request_class or '-'} "
              f"submit@{fr.submit_step} admit@{fr.admit_step} "
              f"finish@{fr.finish_step} {fr.status}/{fr.finish_reason} "
              f"tokens={len(fr.tokens)} prefill=E5M{fr.prefill_precision} "
              f"widths={list(widths)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact", default=None,
                    help="exported repro.artifact directory (model config "
                    "travels inside it; --arch not needed)")
    ap.add_argument("--arch", default=None,
                    help="architecture id (required without --artifact)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="train checkpoint dir to import (fails with the "
                    "available steps listed if no DONE-marked step exists)")
    ap.add_argument("--ckpt-widths", default=None,
                    help="comma-separated width set the checkpoint was "
                    "trained over (e.g. '4' for a --mode fixed --fixed-m 4 "
                    "run); default: the full E5M8..E5M3 set")
    ap.add_argument("--precision", type=int, default=8)
    ap.add_argument("--decode-precision", type=int, default=None,
                    help="switch to this width after the first 1/4 of new "
                    "tokens (mid-generation switching; free — the policy "
                    "compiles to the traced schedule of the fused scan)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    # continuous-batching replay mode
    ap.add_argument("--requests", default=None, metavar="PATH.jsonl",
                    help="continuous-batching mode: replay this JSONL "
                    "workload (per-request class/arrival/sampling) through "
                    "the precision-aware scheduler instead of a lockstep "
                    "batch")
    ap.add_argument("--slots", type=int, default=8,
                    help="continuous batch slots (replay mode)")
    ap.add_argument("--width-policy", default="max-width",
                    choices=("max-width", "width-rr", "slo-degrade",
                             "heterogeneous"),
                    help="per-step weight-width selection policy "
                    "(slo-degrade downshifts widths under overload; "
                    "heterogeneous serves every slot at its own width in "
                    "one fused per-row-width step)")
    ap.add_argument("--classes", default=None,
                    help="register request classes, e.g. "
                    "'generation=8,understanding=4' (name=width)")
    ap.add_argument("--floors", default=None,
                    help="per-class degradation floors, e.g. "
                    "'generation=8' — slo-degrade never serves the class "
                    "below its floor")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the request queue (overflowing replay "
                    "arrivals are rejected with backpressure)")
    ap.add_argument("--queue-ttl", type=int, default=None,
                    help="evict requests queued longer than this many "
                    "scheduler steps")
    ap.add_argument("--slo-step-ms", type=float, default=None,
                    help="step-latency SLO budget for slo-degrade's EWMA "
                    "trigger (milliseconds)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=("bf16", "int8", "f8", "kv8"),
                    help="paged KV page storage dtype (replay mode): "
                    "'int8'/'f8'/'kv8' store pages as f8 E4M3 bytes — "
                    "half the KV memory, a tolerance (not bitwise) regime")
    ap.add_argument("--page-size", type=int, default=16,
                    help="positions per KV page (must divide max-len)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="KV page pool size (default: every slot can hold "
                    "a max-len request)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split prefills into chunks of this many tokens, "
                    "one chunk per step interleaved with decode (default: "
                    "whole prompt at admission)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request prompt-prefix KV reuse")
    ap.add_argument("--speculative", action="store_true",
                    help="self-speculative decoding (replay mode, DESIGN.md "
                    "§15): draft k tokens per slot at a low width and "
                    "verify them in one full-width batched step — greedy "
                    "full-width requests speculate, everything else (and "
                    "any degraded/sub-full-width step) decodes plain")
    ap.add_argument("--draft-width", type=int, default=None,
                    help="static fallback draft width for --speculative "
                    "(default 4; the BPS acceptance estimator picks per "
                    "request among {3,4} when the artifact has stats)")
    ap.add_argument("--draft-k", type=int, default=None,
                    help="draft tokens per speculative macro-step "
                    "(default 3; the verify step batches k+1 positions)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="default EOS token id for replayed requests")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve the Prometheus metrics exposition on "
                    "http://127.0.0.1:PORT/metrics during replay (0 = "
                    "ephemeral port, printed at startup); also enables "
                    "full telemetry (trace spans + wall-clock TTFT/ITL)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the per-request trace timeline after "
                    "replay: Chrome trace_event JSON (open in "
                    "ui.perfetto.dev), or JSONL when PATH ends in "
                    ".jsonl; enables full telemetry")
    ap.add_argument("--max-len", type=int, default=None,
                    help="serving cache length (replay mode; default "
                    "prompt-len + new-tokens + 1)")
    args = ap.parse_args()
    if args.artifact is None and args.arch is None:
        ap.error("pass --artifact (self-describing) or --arch")
    if args.artifact is not None and args.ckpt is not None:
        ap.error("--artifact and --ckpt are mutually exclusive: an "
                 "artifact is already packed, a checkpoint would be "
                 "packed here — pick the weight source")

    import numpy as np

    from repro import api
    from repro.train.data import SyntheticCorpus

    t0 = time.perf_counter()
    if args.artifact:
        artifact = api.Artifact.load(args.artifact)
        cfg = artifact.cfg
        source = f"artifact {args.artifact} (pack-free startup)"
    else:
        import jax

        from repro import configs as C
        cfg = (C.get_reduced(args.arch) if args.reduced
               else C.get_config(args.arch))
        if args.ckpt:
            trained_policy = None
            if args.ckpt_widths:
                ws = tuple(int(x) for x in args.ckpt_widths.split(","))
                trained_policy = (
                    api.PrecisionPolicy.fixed(ws[0]) if len(ws) == 1
                    else api.PrecisionPolicy.all_widths(widths=ws))
            artifact = api.Artifact.from_checkpoint(args.ckpt, cfg,
                                                    policy=trained_policy)
            source = (f"checkpoint {args.ckpt} step "
                      f"{artifact.provenance['train_step']} (packed here)")
        else:
            artifact = api.Artifact.from_params(
                cfg, api.init_params(cfg, jax.random.PRNGKey(0)))
            source = "random init (packed here)"

    # the three historical precision knobs, as ONE policy
    policy = api.PrecisionPolicy.all_widths(default=args.precision)
    if args.decode_precision is not None:
        knee = max(1, args.new_tokens // 4)
        policy = policy.with_schedule(
            [(args.precision, knee), (args.decode_precision, None)])
    if args.classes:
        for part in args.classes.split(","):
            name, sep, w = part.partition("=")
            if not sep or not name.strip() or not w.strip().isdigit():
                ap.error(f"--classes: expected 'name=width' segments, got "
                         f"{part!r}")
            policy = policy.with_class(name.strip(), int(w))
    if args.floors:
        for part in args.floors.split(","):
            name, sep, w = part.partition("=")
            if not sep or not name.strip() or not w.strip().isdigit():
                ap.error(f"--floors: expected 'name=width' segments, got "
                         f"{part!r}")
            policy = policy.with_floor(name.strip(), int(w))

    max_len = args.max_len or (args.prompt_len + args.new_tokens + 1)
    if args.requests:
        # the paged cache requires page_size | max_len (the decode view
        # must equal max_len for the bitwise-oracle property)
        ps = max(1, args.page_size)
        max_len = -(-max_len // ps) * ps
    server = artifact.server(policy, max_len=max_len)
    startup_s = time.perf_counter() - t0
    rep = server.memory_report()
    print(f"serving {cfg.name} at E5M{server.precision} from {source}: "
          f"startup {startup_s:.2f}s, master {rep['master_bytes']/1e6:.2f} MB "
          f"(fp16 {rep['fp16_bytes']/1e6:.2f} MB)")

    if args.requests:
        _replay(server, args, policy)
        return

    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=3)
    prompts = np.asarray(
        corpus.batch(0, args.batch, args.prompt_len + 1)["inputs"]
        [:, :args.prompt_len])
    res = server.generate(prompts, max_new=args.new_tokens)
    tput = args.batch * args.new_tokens / max(res.decode_seconds, 1e-9)
    print(f"generated {args.new_tokens} tokens x {args.batch} requests "
          f"in {res.decode_seconds:.2f}s ({tput:.1f} tok/s, "
          f"{res.host_transfers} host transfer(s), fused decode scan)")
    if args.decode_precision is not None:
        print(f"precision trace: {res.precision_trace}")
    for i in range(min(2, args.batch)):
        print(f"  req{i}: {res.tokens[i].tolist()}")


if __name__ == "__main__":
    main()
