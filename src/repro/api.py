"""repro.api: the single public entry point for the OTARo lifecycle.

    train               finetune(cfg, policy=..., out_dir=...) -> FinetuneResult
    export              (automatic at the end of finetune, or export_artifact)
    serve               Artifact.load(path).server(policy).generate(...)
    serve (continuous)  .server(policy).continuous(slots=...).submit()/drain()
    evaluate            Artifact.evaluate(batch, widths)

Everything a driver (repro/launch/*, examples/*) needs passes through this
module; the wiring between the core OTARo policy, the train substrate, the
packed master format and the serving engine is internal.  A grep-invariant
test (tests/test_api_facade.py) enforces that no driver reaches around the
facade into core.packed / serve.packed_step / core.otaro.

The two first-class nouns (DESIGN.md §10):

  * ``PrecisionPolicy`` — the one precision specification, lowered to the
    BPS arm set in training and to traced decode schedules in serving;
  * ``Artifact`` — the packed-SEFP deployment artifact, written once at the
    end of training and served at every precision with pack-free startup.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

from repro.artifact import (  # noqa: F401
    Artifact,
    MissingBPSStats,
    export_artifact,
    load_artifact,
)
from repro.core.otaro import OTAROConfig  # noqa: F401
from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model_zoo import init_params, make_loss_fn  # noqa: F401
from repro.policy import PrecisionPolicy  # noqa: F401
from repro.serve import errors as serve_errors  # noqa: F401
from repro.serve import faults as serve_faults  # noqa: F401
from repro.serve.engine import GenerationResult, SwitchableServer  # noqa: F401
from repro.serve.errors import (  # noqa: F401
    DeadlineExceeded,
    QueueFull,
    ServeError,
    SlotPoisoned,
    UnknownRequestClass,
)
from repro.serve.scheduler import (  # noqa: F401
    WIDTH_POLICIES,
    Admission,
    ContinuousScheduler,
    SLODegradePolicy,
)
from repro.serve.slots import FinishedRequest, Request  # noqa: F401
from repro.serve.speculative import (  # noqa: F401
    SpecAccounting,
    SpeculativeConfig,
)
from repro.serve.telemetry import (  # noqa: F401
    MetricsRegistry,
    NullTelemetry,
    Telemetry,
    Tracer,
    parse_prometheus,
    serve_metrics,
)

__all__ = [
    "Admission", "Artifact", "ContinuousScheduler", "DeadlineExceeded",
    "FinetuneResult", "FinishedRequest", "GenerationResult",
    "MetricsRegistry", "MissingBPSStats", "ModelConfig", "NullTelemetry",
    "OTAROConfig", "PrecisionPolicy",
    "QueueFull", "Request", "SLODegradePolicy", "ServeError", "SlotPoisoned",
    "SpecAccounting", "SpeculativeConfig", "SwitchableServer", "Telemetry",
    "Tracer", "UnknownRequestClass", "WIDTH_POLICIES", "export_artifact",
    "finetune", "init_params", "load_artifact", "make_loss_fn",
    "make_packed_serve_step", "otaro_config", "packed_param_shapes",
    "parse_prometheus", "serve_errors", "serve_faults", "serve_metrics",
]


def otaro_config(policy: PrecisionPolicy, **overrides) -> OTAROConfig:
    """Train-side lowering of a policy (the BPS arm set + training mode);
    ``overrides`` set the remaining OTARo hyperparameters (lam, laa_n...)."""
    return OTAROConfig.from_policy(policy, **overrides)


def make_packed_serve_step(cfg: ModelConfig, kernel_backend=None,
                           layer_unroll=None):
    """The packed-master decode step (traced width m), for callers that
    lower/compile it directly (launch/dryrun.py) rather than serving."""
    from repro.serve import packed_step as PS
    return PS.make_master_serve_step(cfg, kernel_backend, layer_unroll)


def packed_param_shapes(cfg: ModelConfig, min_size: int = 1 << 16):
    """ShapeDtypeStruct tree of the packed serving master (dry-run)."""
    from repro.serve import packed_step as PS
    return PS.master_param_shapes(cfg, min_size=min_size)


@dataclasses.dataclass
class FinetuneResult:
    """What ``finetune`` hands back: the exported all-precision artifact
    (and where it lives), plus the raw final state and metric history for
    callers that keep training or inspect convergence."""
    artifact: Optional[Artifact]
    artifact_path: Optional[str]
    state: Any
    history: list


def finetune(
    cfg: ModelConfig,
    *,
    out_dir: str,
    policy: Optional[PrecisionPolicy] = None,
    steps: int = 300,
    global_batch: int = 8,
    seq: int = 128,
    lr: float = 1e-5,
    grad_accum: int = 1,
    mesh=None,
    compress_pods_m: Optional[int] = None,
    ckpt_every: int = 200,
    log_every: int = 20,
    keep: int = 3,
    resume: bool = True,
    data_seed: int = 0,
    rng_seed: int = 0,
    export: bool = True,
    artifact_name: str = "artifact",
    otaro_overrides: Optional[dict] = None,
    hooks: Optional[dict] = None,
) -> FinetuneResult:
    """Once-tune ``cfg`` for every precision in ``policy`` and export ONE
    servable artifact.

    Fault tolerance comes from the runner (auto-resume from the newest
    valid checkpoint under ``out_dir`` — rerunning the same call IS the
    recovery procedure; ``resume=False`` forces a fresh run instead of
    restoring); pass ``mesh`` (see repro.launch.mesh) to shard the
    step, plus ``compress_pods_m`` for SEFP-compressed cross-pod gradients.
    The export itself runs in the runner's on_complete hook, so a finished
    run always leaves ``<out_dir>/<artifact_name>`` ready for
    ``Artifact.load(...).server(policy)``.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import compat
    from repro.train import optimizer as opt_lib
    from repro.train import runner as runner_lib
    from repro.train import steps as steps_lib
    from repro.train.data import SyntheticCorpus

    policy = policy or PrecisionPolicy.all_widths()
    ocfg = otaro_config(policy, **(otaro_overrides or {}))
    opt = opt_lib.sgd(lr)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=data_seed)

    def batch_fn(step):
        b = corpus.batch(step, global_batch, seq)
        return {k: jnp.asarray(v) for k, v in b.items()}

    job = runner_lib.JobConfig(total_steps=steps, out_dir=out_dir,
                               ckpt_every=ckpt_every, log_every=log_every,
                               keep=keep, resume=resume)
    artifact_path = os.path.join(out_dir, artifact_name) if export else None
    box = {"artifact": None}
    run_hooks = dict(hooks or {})

    if export:
        def on_complete(state,
                        _user=run_hooks.get("on_complete")):
            box["artifact"] = export_artifact(
                artifact_path, cfg, state, policy=policy,
                provenance={"source": f"finetune:{cfg.name}",
                            "total_steps": steps, "lr": lr,
                            "global_batch": global_batch, "seq": seq})
            if _user is not None:
                _user(state)

        run_hooks["on_complete"] = on_complete

    key = jax.random.PRNGKey(rng_seed)
    if mesh is None:
        step_fn, init_fn = steps_lib.make_train_step(
            cfg, ocfg, opt, mesh=None, grad_accum=grad_accum)
        state, history = runner_lib.run_training(
            step_fn, lambda: init_fn(key), batch_fn, job, hooks=run_hooks)
    else:
        jit_builder, init_fn = steps_lib.make_train_step(
            cfg, ocfg, opt, mesh=mesh, grad_accum=grad_accum,
            compress_pods_m=compress_pods_m)
        batch_shapes = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch_fn(0))
        with compat.set_mesh(mesh):
            step_fn = jit_builder(batch_shapes)
            state, history = runner_lib.run_training(
                step_fn, lambda: init_fn(key), batch_fn, job,
                hooks=run_hooks)

    return FinetuneResult(artifact=box["artifact"],
                          artifact_path=artifact_path,
                          state=state, history=history)
