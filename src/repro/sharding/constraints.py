"""Activation sharding constraints (mesh-optional helpers).

GSPMD propagation is weakest through while-loop carries and gather/scatter
ops; without hints it can silently replicate the batch dimension inside
scanned layers (observed on the 256-chip dry-run: f32[global_batch, ...]
temporaries and multi-GiB all-gathers in the loss/attention).  These helpers
apply `with_sharding_constraint` only when an ambient mesh is active, so the
same model code runs unsharded on CPU tests and fully sharded under pjit.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

from repro.kernels import compat

# Which mesh axes may carry the batch dim.  "tp" (default) reserves the
# model axis for tensor parallelism; "dp" lets the batch span it (pure
# data/FSDP parallelism).  Set at TRACE time by the step builder
# (train/steps.py) so in-model constraints agree with the input layout.
_LAYOUT = contextvars.ContextVar("batch_layout", default="tp")


@contextlib.contextmanager
def batch_layout(layout: str):
    tok = _LAYOUT.set(layout)
    try:
        yield
    finally:
        _LAYOUT.reset(tok)


def _ambient_mesh():
    # compat degrades to the explicit-mesh path (the thread-resources
    # physical mesh) on JAX versions without abstract meshes.
    try:
        mesh = compat.ambient_mesh()
    except Exception:  # pragma: no cover
        return None
    if mesh is None or mesh.empty:
        return None
    return mesh


def _axis_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain_batch(x, extra=()):
    """Shard dim 0 over the layout's batch axes when divisible; dims listed
    in ``extra`` as (dim_index, axis_name) are constrained too (when
    divisible and not already carrying batch)."""
    mesh = _ambient_mesh()
    if mesh is None or x.ndim == 0:
        return x
    layout = _LAYOUT.get()
    pool = (("pod", "data", "model") if layout == "dp"
            else ("pod", "data"))
    # axes already manual (e.g. inside shard_map over pod) cannot appear in
    # sharding constraints
    manual = compat.manual_axis_names(mesh)
    baxes = tuple(a for a in pool
                  if a in mesh.axis_names and a not in manual)
    spec = [None] * x.ndim
    used = set()
    if baxes and x.shape[0] % _axis_size(mesh, baxes) == 0:
        spec[0] = baxes
        used.update(baxes)
    for dim, axis in extra:
        if (axis in mesh.axis_names and axis not in manual
                and axis not in used and dim < x.ndim
                and x.shape[dim] % mesh.shape[axis] == 0):
            spec[dim] = axis
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_tree_batch(tree, extra=()):
    return jax.tree_util.tree_map(lambda x: constrain_batch(x, extra), tree)
