from repro.sharding.partition import (  # noqa: F401
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    state_pspecs,
    to_named_sharding,
)
