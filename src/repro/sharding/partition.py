"""Logical-axis sharding rules with divisibility fallback (MaxText-style).

Weights: FSDP over the ``data`` axis (first/contraction dim) + TP over the
``model`` axis (output/ff/vocab/head dims).  The ``pod`` axis (multi-pod
mesh) carries pure data parallelism — weights are replicated across pods,
batches are sharded over (pod, data).

A dim is sharded by a mesh axis only if evenly divisible; otherwise the rule
is dropped for that dim (replication).  This is what makes one rule set
serve all 10 architectures on the fixed production mesh — e.g. qwen2's 14
attention heads fall back to replicated heads while its MLP and vocab dims
still carry 16-way TP.

Rules are path-based (we control every parameter name) and apply to the
TRAILING dims of each weight, so stacked-layer leading axes ([L, ...]) and
MoE expert axes ([L, E, ...]) are replicated automatically.
"""

from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on the /-joined path, spec for the trailing dims)
# "data" = FSDP shard, "model" = TP shard.
_PARAM_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    (r"embedding$", (None, "model")),
    (r"w_unembed$", ("data", "model")),
    # column-parallel (input dim FSDP, output dim TP)
    (r"(wq|wk|wv|wg|wr|w_gate|w_up|wk_ffn|wr_ffn|in_proj(_\w+)?|fuse_proj)$",
     ("data", "model")),
    # row-parallel (input dim TP, output dim FSDP)
    (r"(wo|w_down|wv_ffn|out_proj)$", ("model", "data")),
    (r"router$", ("data", None)),
    (r"time_decay_A$", ("data", None)),
    (r"time_decay_B$", (None, "data")),
)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _resolve(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Fit a trailing-dims rule onto `shape` with divisibility fallback."""
    ndim = len(shape)
    full = (None,) * (ndim - len(spec)) + tuple(spec)
    out = []
    for dim, axis in zip(shape, full):
        if axis is None or axis not in mesh.axis_names:
            out.append(None)
        elif dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)  # divisibility fallback -> replicate
    return P(*out)


def param_pspecs(param_shapes: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree for a parameter pytree (of arrays or
    ShapeDtypeStructs)."""

    def visit(path, leaf):
        name = _path_str(path)
        # packed-master leaves ({w}/mag, {w}/sign, {w}/exp — the stacked
        # SEFP layout, core/packed.py) inherit the rule of the weight they
        # pack; sign/exp rows divide K by 8/64, so their K dim usually hits
        # the divisibility fallback and replicates, which is correct — they
        # are 1/8 and 1/64 of the payload.
        name = re.sub(r"/(mag|sign|exp)$", "", name)
        if len(leaf.shape) < 2:
            return P()  # biases / norms / scalars replicated
        for pat, spec in _PARAM_RULES:
            if re.search(pat, name):
                return _resolve(spec, leaf.shape, mesh)
        return P()  # unknown params replicated (conv kernels, bonus, ...)

    return jax.tree_util.tree_map_with_path(visit, param_shapes)


def _batch_axes(mesh: Mesh, layout: str = "tp"):
    """Batch-dim mesh axes.  layout="tp": batch over (pod, data), model axis
    reserved for tensor parallelism.  layout="dp": batch over (pod, data,
    model) — pure data/FSDP parallelism (weights still sharded per the param
    rules; GSPMD all-gathers them per layer).  layout="pod": batch over pod
    only (the SEFP-compressed step shard_maps over pod; manual and auto axes
    cannot share a dim spec, so data-sharding happens inside).  Small-model
    training is collective-bound under TP on v5e ICI; "dp" is the §Perf
    alternative."""
    pool = {"dp": ("pod", "data", "model"),
            "pod": ("pod",)}.get(layout, ("pod", "data"))
    axes = tuple(a for a in pool if a in mesh.axis_names)
    return axes if axes else None


def batch_pspecs(batch_shapes: Any, mesh: Mesh, layout: str = "tp") -> Any:
    """Shard every batch array along its leading (batch) dim, with
    divisibility fallback."""
    baxes = _batch_axes(mesh, layout)

    def visit(path, leaf):
        if not leaf.shape:
            return P()
        bsz = leaf.shape[0]
        if baxes and bsz % _axis_size(mesh, baxes) == 0:
            return P(baxes, *([None] * (len(leaf.shape) - 1)))
        # progressively drop trailing axes (e.g. batch 8 on a 2x16x16 mesh)
        for cut in range(len(baxes or ()) - 1, 0, -1):
            sub = baxes[:cut]
            if bsz % _axis_size(mesh, sub) == 0:
                return P(sub, *([None] * (len(leaf.shape) - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(visit, batch_shapes)


# cache rules keyed by parameter-path suffix; specs apply to TRAILING dims
# of [L?, B, ...] arrays *after* the batch dim is handled separately.
def cache_pspecs(cache_shapes: Any, mesh: Mesh,
                 kv_layout: str = "seq") -> Any:
    """Decode-cache sharding:
      - KV caches [L, B, S, KV, hd]: batch over (pod,data); kv_layout="seq"
        shards the sequence over model (flash-decode style — works for every
        GQA width); kv_layout="heads" shards kv-heads over model when
        divisible (avoids resharding around the cache append — the §Perf
        alternative for wide-GQA archs), falling back to seq;
      - SSM states [L, B, H, P, N] / wkv states [L, B, H, k, v]: batch +
        heads over model;
      - small shift/conv states: batch only."""
    baxes = _batch_axes(mesh)

    def shard_dim(dim, axis):
        return axis if axis and dim % _axis_size(mesh, axis) == 0 else None

    def visit(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if not shape:
            return P()
        if re.search(r"(^|/)(k|v|cross_k|cross_v)$", name) and len(shape) == 5:
            L_, B, S, KV, hd = shape
            if kv_layout == "heads" and shard_dim(KV, "model"):
                return P(None, shard_dim(B, baxes), None, "model", None)
            return P(None, shard_dim(B, baxes), shard_dim(S, "model"),
                     None, None)
        if re.search(r"(ssm_state|wkv_state)$", name) and len(shape) == 5:
            L_, B, H = shape[:3]
            return P(None, shard_dim(B, baxes), shard_dim(H, "model"),
                     None, None)
        # conv_state [L,B,W,C] / shift states [L,B,1,d] / misc
        if len(shape) >= 2:
            return P(None, shard_dim(shape[1], baxes),
                     *([None] * (len(shape) - 2)))
        return P()

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)


def state_pspecs(state_shapes: Any, mesh: Mesh) -> Any:
    """Sharding for an OTAROState: params/opt/LAA buffers follow the param
    specs; BPS scalars and counters are replicated."""
    from repro.core.otaro import OTAROState  # local import to avoid cycle

    def like_params(tree_shapes):
        def visit(path, leaf):
            name = _path_str(path)
            if len(leaf.shape) < 2:
                return P()
            for pat, spec in _PARAM_RULES:
                if re.search(pat, name):
                    return _resolve(spec, leaf.shape, mesh)
            return P()
        return jax.tree_util.tree_map_with_path(visit, tree_shapes)

    assert isinstance(state_shapes, OTAROState)
    return OTAROState(
        params=like_params(state_shapes.params),
        opt_state=like_params(state_shapes.opt_state),
        bps=jax.tree_util.tree_map(lambda l: P(), state_shapes.bps),
        laa=like_params(state_shapes.laa),
        step=P(),
    )


def to_named_sharding(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
