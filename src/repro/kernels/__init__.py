"""Pallas TPU kernels for the SEFP hot paths.

Kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling with
MXU-aligned dims); on this CPU-only container they are validated with
``interpret=True`` (the default here is backend-derived).
"""

import jax

# interpret=True executes kernel bodies in Python on CPU; on a real TPU this
# resolves to False and the Mosaic path is used.
INTERPRET = jax.default_backend() != "tpu"
