"""SEFP kernel subsystem.

Three layers (DESIGN.md §2):

  * :mod:`repro.kernels.compat`   — the single owner of every JAX
    version-sensitive symbol (Pallas compiler params, mesh construction,
    ambient-mesh lookup, shard_map);
  * :mod:`repro.kernels.dispatch` — op -> backend registry with runtime
    auto-selection (compiled Mosaic on TPU, interpreter or jnp oracle
    elsewhere), per-call override, and the ``REPRO_KERNEL_BACKEND`` env
    escape hatch;
  * the ops themselves — ``sefp_quant`` (training fake-quant), ``sefp_pack``
    (master packing), ``sefp_matmul`` (fused dequant-matmul serving path),
    each a package with the Pallas kernel body, a pure-jnp oracle (ref.py),
    and the registered backend wrappers (ops.py).
"""

from repro.kernels import compat  # noqa: F401
from repro.kernels import dispatch  # noqa: F401


def __getattr__(name):
    # Deprecated: pre-dispatch interpret default, kept for external callers.
    # Computed lazily (PEP 562): jax.default_backend() initializes the XLA
    # backend, and importing this package must never touch device state —
    # launchers set XLA_FLAGS after import (see launch/mesh.py).
    if name == "INTERPRET":
        import jax
        return jax.default_backend() != "tpu"
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
