"""Pure-jnp oracle for the sefp_matmul kernel.

Defines the semantic contract: truncate the M8 master to width m (shift),
dequantize, cast weights AND activations to bf16 (MXU input precision),
matmul with fp32 accumulation.
"""

import jax.numpy as jnp
from jax import lax

from repro.kernels.common import GROUP, exp2i


def dequant_ref(mag, sign_bits, exp, m):
    """k-major packed arrays -> dequantized f32 weight [K, N]."""
    m = jnp.asarray(m, jnp.int32)
    shift = (8 - m).astype(jnp.uint32)
    magk = lax.shift_right_logical(mag.astype(jnp.uint32),
                                   shift).astype(jnp.float32)
    kb, n = sign_bits.shape
    shifts = jnp.arange(8, dtype=jnp.int32).reshape(1, 8, 1)
    bits = (sign_bits.astype(jnp.int32)[:, None, :] >> shifts) & 1
    sign = 1.0 - 2.0 * bits.reshape(kb * 8, n).astype(jnp.float32)
    quantum = exp2i(jnp.repeat(exp.astype(jnp.int32), GROUP, axis=0)
                    - (m - 1))
    return sign * magk * quantum


def sefp_matmul_ref(x, mag, sign_bits, exp, m):
    w = dequant_ref(mag, sign_bits, exp, m).astype(jnp.bfloat16)
    return jnp.dot(x.astype(jnp.bfloat16), w,
                   preferred_element_type=jnp.float32)
