"""Pure-jnp oracles for the sefp_matmul kernels.

Define the semantic contract: truncate the M8 master to width m (shift),
dequantize, cast weights AND activations to bf16 (MXU input precision),
matmul with fp32 accumulation.  The gemv oracle additionally mirrors the
decode kernel's (n, k) tiling — k innermost, one fp32 add per k-tile — so
it matches the Pallas kernel BITWISE on identical inputs, not just to
tolerance (fp32 accumulation order is part of the contract).
"""

import jax.numpy as jnp
from jax import lax

from repro.kernels.common import GROUP, exp2i, pick_block


def dequant_ref(mag, sign_bits, exp, m):
    """k-major packed arrays -> dequantized f32 weight [K, N]."""
    m = jnp.asarray(m, jnp.int32)
    shift = (8 - m).astype(jnp.uint32)
    magk = lax.shift_right_logical(mag.astype(jnp.uint32),
                                   shift).astype(jnp.float32)
    kb, n = sign_bits.shape
    shifts = jnp.arange(8, dtype=jnp.int32).reshape(1, 8, 1)
    bits = (sign_bits.astype(jnp.int32)[:, None, :] >> shifts) & 1
    sign = 1.0 - 2.0 * bits.reshape(kb * 8, n).astype(jnp.float32)
    quantum = exp2i(jnp.repeat(exp.astype(jnp.int32), GROUP, axis=0)
                    - (m - 1))
    return sign * magk * quantum


def sefp_matmul_ref(x, mag, sign_bits, exp, m):
    w = dequant_ref(mag, sign_bits, exp, m).astype(jnp.bfloat16)
    return jnp.dot(x.astype(jnp.bfloat16), w,
                   preferred_element_type=jnp.float32)


def sefp_matmul_gemv_ref(x, mag, sign_bits, exp, m, *, block_n: int = 256,
                         block_k: int = 512):
    """Tiled oracle for the decode gemv kernel: same block resolution
    (pick_block), same (n, k) tile walk with k innermost, one bf16 dot and
    one fp32 accumulate per k-tile — the exact reduction order of
    sefp_gemv_raw, so agreement is bitwise."""
    k_dim, n_dim = mag.shape
    bn = pick_block(n_dim, block_n)
    bk = pick_block(k_dim, block_k, multiple=GROUP)
    xb = x.astype(jnp.bfloat16)
    cols = []
    for j in range(n_dim // bn):
        ns = slice(j * bn, (j + 1) * bn)
        acc = jnp.zeros((x.shape[0], bn), jnp.float32)
        for k in range(k_dim // bk):
            w = dequant_ref(mag[k * bk:(k + 1) * bk, ns],
                            sign_bits[k * bk // 8:(k + 1) * bk // 8, ns],
                            exp[k * bk // GROUP:(k + 1) * bk // GROUP, ns],
                            m).astype(jnp.bfloat16)
            acc = acc + jnp.dot(xb[:, k * bk:(k + 1) * bk], w,
                                preferred_element_type=jnp.float32)
        cols.append(acc)
    return jnp.concatenate(cols, axis=1)


def sefp_matmul_gemv_hetero_ref(x, mag, sign_bits, exp, m_rows, *, widths,
                                block_n: int = 256, block_k: int = 512):
    """Per-row-width tiled oracle: output row ``i`` is dequantized at its
    own mantissa width ``m_rows[i]``.

    Walks the exact same (n, k) tile sequence as sefp_matmul_gemv_ref, but
    inside each k-tile sweeps the *static* candidate ``widths`` ladder:
    dequantize the shared packed tile once per width, take the full-batch
    bf16 dot, and merge via ``where(row wants w, acc + part, acc)``.  Each
    row matches exactly one ladder width per k-tile, so its fp32 adds are
    the same sequence — at the same dot shape — as running the whole batch
    through sefp_matmul_gemv_ref at scalar ``m = m_rows[i]`` and reading
    row ``i``: agreement is BITWISE, not to tolerance.

    Rows whose width is absent from ``widths`` are never accumulated and
    return zeros; callers validate ladder membership.  The merge uses
    ``where(mask, acc + part, acc)`` (never ``acc + where(...)``) so
    untouched rows keep their bit pattern (-0.0 is preserved)."""
    k_dim, n_dim = mag.shape
    bn = pick_block(n_dim, block_n)
    bk = pick_block(k_dim, block_k, multiple=GROUP)
    xb = x.astype(jnp.bfloat16)
    m_rows = jnp.asarray(m_rows, jnp.int32)
    rmasks = [(m_rows == w)[:, None] for w in widths]
    cols = []
    for j in range(n_dim // bn):
        ns = slice(j * bn, (j + 1) * bn)
        acc = jnp.zeros((x.shape[0], bn), jnp.float32)
        for k in range(k_dim // bk):
            xk = xb[:, k * bk:(k + 1) * bk]
            for w, rm in zip(widths, rmasks):
                wq = dequant_ref(
                    mag[k * bk:(k + 1) * bk, ns],
                    sign_bits[k * bk // 8:(k + 1) * bk // 8, ns],
                    exp[k * bk // GROUP:(k + 1) * bk // GROUP, ns],
                    w).astype(jnp.bfloat16)
                part = jnp.dot(xk, wq, preferred_element_type=jnp.float32)
                acc = jnp.where(rm, acc + part, acc)
        cols.append(acc)
    return jnp.concatenate(cols, axis=1)
