"""Pallas TPU kernel: fused SEFP dequant + matmul over the packed master.

Serving hot path.  Computes ``x @ dequantize(packed_W, m)`` where ``packed_W``
is the k-major PackedSEFP master (mag uint8 [K,N], sign_bits uint8 [K//8,N],
exp int8 [K//64,N]) and ``m`` is the *runtime* mantissa width (scalar
prefetch).  This realizes the paper's on-device mechanism end to end:

  * the model is stored once (M8 master, ~9.1 bits/param);
  * switching precision moves zero bytes — the truncation ``mag >> (8-m)``
    happens in VMEM registers right before the MXU dot;
  * HBM->VMEM weight traffic is 1 byte/param (+1/8 sign +1/64 exp) instead of
    2 (bf16): the memory-bound decode step speeds up ~2x, which is the
    mechanism behind Table 2's 2.45x decode throughput.

TPU mapping:
  * grid (M/bm, N/bn, K/bk), k innermost ("arbitrary"), fp32 accumulation in
    the revisited output block;
  * bk is a multiple of 64 so sign bytes (8 rows/byte) and group exponents
    (64 rows/group) never straddle tiles;
  * dequant is pure VPU integer/bit work: shift, sign unpack via iota&7,
    exponent-field construction for exact 2^e; the MXU consumes bf16 weights
    (exact for |code| <= 255) and bf16 activations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.common import GROUP, exp2i


def _dequant_tile(m, mag_ref, sgn_ref, exp_ref):
    """Shared in-VMEM dequant of one [bk, bn] weight tile at runtime width
    m: pure VPU integer/bit work, consumed by the MXU as bf16 (exact for
    |code| <= 255).  Used by both the square-tiled matmul kernel and the
    decode-shaped gemv kernel, so the two paths cannot drift."""
    bk, bn = mag_ref.shape

    # --- truncate mantissas to width m (the precision switch) -------------
    shift = (8 - m).astype(jnp.uint32)
    mag = mag_ref[...].astype(jnp.uint32)
    magk = lax.shift_right_logical(mag, shift).astype(jnp.float32)

    # --- unpack signs: bit (row % 8) of byte (row // 8) -------------------
    sgn_bytes = sgn_ref[...].astype(jnp.int32)          # [bk//8, bn]
    rep = jnp.repeat(sgn_bytes, 8, axis=0)              # [bk, bn]
    row_bit = lax.broadcasted_iota(jnp.int32, (bk, bn), 0) & 7
    bits = lax.shift_right_logical(rep, row_bit) & 1
    sign = 1.0 - 2.0 * bits.astype(jnp.float32)

    # --- per-group quanta 2^(E* - (m-1)) ----------------------------------
    e = exp_ref[...].astype(jnp.int32)                  # [bk//64, bn]
    quantum = exp2i(jnp.repeat(e, GROUP, axis=0) - (m - 1))

    return (sign * magk * quantum).astype(jnp.bfloat16)


def _matmul_kernel(m_ref, x_ref, mag_ref, sgn_ref, exp_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(m_ref[0], mag_ref, sgn_ref, exp_ref)
    x = x_ref[...].astype(jnp.bfloat16)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def sefp_matmul_raw(x, mag, sign_bits, exp, m, *, block_m: int, block_n: int,
                    block_k: int, interpret: bool):
    """x [M, K] x packed W [K, N] -> f32 [M, N]."""
    m_dim, k_dim = x.shape
    _, n_dim = mag.shape
    grid = (m_dim // block_m, n_dim // block_n, k_dim // block_k)

    grid_spec = compat.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k, s: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k, s: (k, j)),
            pl.BlockSpec((block_k // 8, block_n), lambda i, j, k, s: (k, j)),
            pl.BlockSpec((block_k // GROUP, block_n),
                         lambda i, j, k, s: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k, s: (i, j)),
    )
    return pl.pallas_call(
        _matmul_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(m, x, mag, sign_bits, exp)


def _gemv_kernel(m_ref, x_ref, mag_ref, sgn_ref, exp_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(m_ref[0], mag_ref, sgn_ref, exp_ref)
    x = x_ref[...].astype(jnp.bfloat16)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def sefp_gemv_raw(x, mag, sign_bits, exp, m, *, block_n: int, block_k: int,
                  interpret: bool):
    """Decode-shaped (tall-skinny) variant: x [M, K] with small M x packed
    W [K, N] -> f32 [M, N].

    The whole row block rides along every grid step (decode batches are a
    handful of rows), so the grid is 2-D — (N/bn, K/bk) with k innermost
    ("arbitrary") — and each step streams one packed weight tile from HBM,
    dequantizes it in VMEM at runtime width m and accumulates into the
    revisited [M, bn] output block in fp32.  This is the gemv that dominates
    the decode step (per-token activations never amortize a [bm, bk] tile),
    where weight streaming is the whole cost and the ~2x HBM saving of the
    packed master pays off directly.  Callers pad M to the fp32 sublane
    multiple (repro/kernels/sefp_matmul/ops.py)."""
    m_dim, k_dim = x.shape
    _, n_dim = mag.shape
    grid = (n_dim // block_n, k_dim // block_k)

    grid_spec = compat.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_dim, block_k), lambda j, k, s: (0, k)),
            pl.BlockSpec((block_k, block_n), lambda j, k, s: (k, j)),
            pl.BlockSpec((block_k // 8, block_n), lambda j, k, s: (k, j)),
            pl.BlockSpec((block_k // GROUP, block_n),
                         lambda j, k, s: (k, j)),
        ],
        out_specs=pl.BlockSpec((m_dim, block_n), lambda j, k, s: (0, j)),
    )
    return pl.pallas_call(
        _gemv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(m, x, mag, sign_bits, exp)


def _gemv_hetero_kernel(m_ref, x_ref, mag_ref, sgn_ref, exp_ref, o_ref, *,
                        widths):
    """Width-heterogeneous gemv step: output row i is accumulated at its
    own mantissa width m_ref[i].

    The per-row width vector rides in SMEM (scalar prefetch) and is read
    with python-unrolled scalar loads — M is a static handful of decode
    rows, and scalar SMEM reads are the only access pattern guaranteed to
    lower on real TPU.  For each candidate width in the *static* ladder we
    dequantize the shared packed tile once (VPU work; the HBM bytes were
    already streamed for this k-step regardless of how many widths are
    live), take the full-row-block MXU dot, and merge only the rows that
    want that width.  pl.when skips absent widths entirely, so a batch
    that happens to agree on one width costs exactly the scalar kernel.

    The merge is ``where(mask, o + part, o)`` — never ``o + where(...)``
    — so untouched rows keep their accumulated bit pattern exactly."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    m_dim, bn = o_ref.shape
    x = x_ref[...].astype(jnp.bfloat16)
    row = lax.broadcasted_iota(jnp.int32, (m_dim, bn), 0)
    for w in widths:
        hits = [m_ref[i] == w for i in range(m_dim)]
        present = functools.reduce(jnp.logical_or, hits)

        @pl.when(present)
        def _(w=w, hits=hits):
            wq = _dequant_tile(jnp.int32(w), mag_ref, sgn_ref, exp_ref)
            part = jnp.dot(x, wq, preferred_element_type=jnp.float32)
            mask = functools.reduce(
                jnp.logical_or,
                [jnp.logical_and(row == i, h) for i, h in enumerate(hits)])
            o_ref[...] = jnp.where(mask, o_ref[...] + part, o_ref[...])


def sefp_gemv_hetero_raw(x, mag, sign_bits, exp, m_rows, *, widths,
                         block_n: int, block_k: int, interpret: bool):
    """Per-row-width decode gemv: x [M, K] x packed W [K, N] -> f32 [M, N]
    where row i is dequantized at width ``m_rows[i]`` (int32 [M], SMEM
    scalar prefetch).  ``widths`` is the static candidate ladder; rows
    whose width is absent from it come back zero.  Same 2-D (N/bn, K/bk)
    grid and fp32 revisit-accumulation as sefp_gemv_raw, so a row served
    here is bitwise equal to the same row batch served by the scalar
    kernel at its width."""
    m_dim, k_dim = x.shape
    _, n_dim = mag.shape
    grid = (n_dim // block_n, k_dim // block_k)

    grid_spec = compat.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_dim, block_k), lambda j, k, s: (0, k)),
            pl.BlockSpec((block_k, block_n), lambda j, k, s: (k, j)),
            pl.BlockSpec((block_k // 8, block_n), lambda j, k, s: (k, j)),
            pl.BlockSpec((block_k // GROUP, block_n),
                         lambda j, k, s: (k, j)),
        ],
        out_specs=pl.BlockSpec((m_dim, block_n), lambda j, k, s: (0, j)),
    )
    return pl.pallas_call(
        functools.partial(_gemv_hetero_kernel, widths=widths),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(m_rows, x, mag, sign_bits, exp)
