"""Public fused SEFP dequant-matmul op: backend impls + dispatch wrapper."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packed import MASTER_M, PackedSEFP
from repro.kernels import dispatch
from repro.kernels.common import pick_block
from repro.kernels.sefp_matmul.ref import (sefp_matmul_gemv_hetero_ref,
                                           sefp_matmul_gemv_ref,
                                           sefp_matmul_ref)
from repro.kernels.sefp_matmul.sefp_matmul import (sefp_gemv_hetero_raw,
                                                   sefp_gemv_raw,
                                                   sefp_matmul_raw)

# fp32 sublane multiple: decode row blocks are padded up to this so the
# compiled gemv kernel always sees a legal tile (interpret mode would accept
# any M, but the two backends must run identical shapes to agree bitwise).
SUBLANE = 8


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def _pallas_call(x, mag, sign_bits, exp, m, block_m, block_n, block_k,
                 interpret):
    return sefp_matmul_raw(x, mag, sign_bits, exp, m, block_m=block_m,
                           block_n=block_n, block_k=block_k,
                           interpret=interpret)


def _pallas(x, mag, sign_bits, exp, m, block_m, block_n, block_k, *,
            interpret):
    m_rows, _ = x.shape
    k_dim, n_dim = mag.shape
    bm = pick_block(m_rows, block_m)
    bn = pick_block(n_dim, block_n)
    bk = pick_block(k_dim, block_k, multiple=64)
    if bk == 0:
        raise ValueError(f"K={k_dim} must allow a 64-divisible block")
    m_arr = jnp.asarray(m, jnp.int32).reshape((1,))
    return _pallas_call(x, mag, sign_bits, exp, m_arr, bm, bn, bk, interpret)


@dispatch.register("sefp_matmul", dispatch.PALLAS_TPU)
def _matmul_tpu(x, mag, sign_bits, exp, m, *, block_m=128,
                block_n=256, block_k=512):
    return _pallas(x, mag, sign_bits, exp, m, block_m, block_n, block_k,
                   interpret=False)


@dispatch.register("sefp_matmul", dispatch.PALLAS_INTERPRET)
def _matmul_interpret(x, mag, sign_bits, exp, m, *, block_m=128,
                      block_n=256, block_k=512):
    return _pallas(x, mag, sign_bits, exp, m, block_m, block_n, block_k,
                   interpret=True)


_ref_jit = jax.jit(sefp_matmul_ref)


@dispatch.register("sefp_matmul", dispatch.JAX_REF)
def _matmul_jax_ref(x, mag, sign_bits, exp, m, *, block_m=128, block_n=256,
                    block_k=512):
    del block_m, block_n, block_k  # single whole-array dot; no tiling
    return _ref_jit(x, mag, sign_bits, exp, jnp.asarray(m, jnp.int32))


# ---------------------------------------------------------------------------
# decode-shaped gemv: tall-skinny x, 2-D grid, whole row block resident
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_n", "block_k",
                                             "interpret"))
def _gemv_pallas_call(x, mag, sign_bits, exp, m, block_n, block_k,
                      interpret):
    return sefp_gemv_raw(x, mag, sign_bits, exp, m, block_n=block_n,
                         block_k=block_k, interpret=interpret)


def _gemv_blocks(k_dim: int, n_dim: int, block_n: int, block_k: int):
    bn = pick_block(n_dim, block_n)
    bk = pick_block(k_dim, block_k, multiple=64)
    if bk == 0:
        raise ValueError(f"K={k_dim} must allow a 64-divisible block")
    return bn, bk


def _gemv_pallas(x, mag, sign_bits, exp, m, block_n, block_k, *, interpret):
    k_dim, n_dim = mag.shape
    bn, bk = _gemv_blocks(k_dim, n_dim, block_n, block_k)
    m_arr = jnp.asarray(m, jnp.int32).reshape((1,))
    return _gemv_pallas_call(x, mag, sign_bits, exp, m_arr, bn, bk,
                             interpret)


@dispatch.register("sefp_matmul_gemv", dispatch.PALLAS_TPU)
def _gemv_tpu(x, mag, sign_bits, exp, m, *, block_n=256, block_k=512):
    return _gemv_pallas(x, mag, sign_bits, exp, m, block_n, block_k,
                        interpret=False)


@dispatch.register("sefp_matmul_gemv", dispatch.PALLAS_INTERPRET)
def _gemv_interpret(x, mag, sign_bits, exp, m, *, block_n=256, block_k=512):
    return _gemv_pallas(x, mag, sign_bits, exp, m, block_n, block_k,
                        interpret=True)


_gemv_ref_jit = jax.jit(sefp_matmul_gemv_ref,
                        static_argnames=("block_n", "block_k"))


@dispatch.register("sefp_matmul_gemv", dispatch.JAX_REF)
def _gemv_jax_ref(x, mag, sign_bits, exp, m, *, block_n=256, block_k=512):
    # the oracle applies the identical pick_block resolution internally, so
    # it walks the exact tile sequence of the Pallas kernel (bitwise).
    return _gemv_ref_jit(x, mag, sign_bits, exp, jnp.asarray(m, jnp.int32),
                         block_n=block_n, block_k=block_k)


def sefp_matmul(x: jax.Array, packed: PackedSEFP, m, *,
                block_m: int = 128, block_n: int = 256, block_k: int = 512,
                interpret: bool | None = None,
                backend: str | None = None) -> jax.Array:
    """``x @ dequantize(packed, m)`` with on-the-fly truncation to mantissa
    width ``m`` (python int or traced int32 scalar).

    x: [M, K] (or [..., K]; leading dims are flattened), packed: k-major
    PackedSEFP of a [K, N] weight grouped along axis 0.  Returns f32 [..., N].
    Backend resolution: ``backend=`` > ``REPRO_KERNEL_BACKEND`` > platform
    auto."""
    if backend is None and interpret is not None:
        backend = (dispatch.PALLAS_INTERPRET if interpret
                   else dispatch.PALLAS_TPU)
    if packed.group_axis != 0 or len(packed.shape) != 2:
        raise ValueError("sefp_matmul expects a 2-D weight packed along "
                         "axis 0 (k-major)")
    k_dim, n_dim = packed.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if x2.shape[1] != k_dim:
        raise ValueError(f"x K={x2.shape[1]} vs packed K={k_dim}")

    out = dispatch.dispatch(
        "sefp_matmul", x2, packed.mag, packed.sign_bits, packed.exp, m,
        block_m=block_m, block_n=block_n, block_k=block_k, backend=backend)
    return out.reshape(*lead, n_dim)


# ---------------------------------------------------------------------------
# width-heterogeneous gemv: per-output-row mantissa widths, one fused step
# ---------------------------------------------------------------------------


def normalize_widths(widths) -> tuple:
    """Validate and canonicalize a static candidate-width ladder: unique,
    sorted descending, every entry in 1..MASTER_M.  None means the full
    master ladder (MASTER_M down to 1)."""
    if widths is None:
        return tuple(range(MASTER_M, 0, -1))
    out = tuple(sorted({int(w) for w in widths}, reverse=True))
    if not out:
        raise ValueError("widths ladder must be non-empty")
    for w in out:
        if not 1 <= w <= MASTER_M:
            raise ValueError(f"width {w} outside 1..{MASTER_M}")
    return out


@functools.partial(jax.jit, static_argnames=("widths", "block_n", "block_k",
                                             "interpret"))
def _gemv_hetero_pallas_call(x, mag, sign_bits, exp, m_rows, widths, block_n,
                             block_k, interpret):
    return sefp_gemv_hetero_raw(x, mag, sign_bits, exp, m_rows,
                                widths=widths, block_n=block_n,
                                block_k=block_k, interpret=interpret)


def _gemv_hetero_pallas(x, mag, sign_bits, exp, m_rows, widths, block_n,
                        block_k, *, interpret):
    k_dim, n_dim = mag.shape
    bn, bk = _gemv_blocks(k_dim, n_dim, block_n, block_k)
    m_arr = jnp.asarray(m_rows, jnp.int32)
    return _gemv_hetero_pallas_call(x, mag, sign_bits, exp, m_arr, widths,
                                    bn, bk, interpret)


@dispatch.register("sefp_matmul_gemv_hetero", dispatch.PALLAS_TPU)
def _gemv_hetero_tpu(x, mag, sign_bits, exp, m_rows, *, widths, block_n=256,
                     block_k=512):
    return _gemv_hetero_pallas(x, mag, sign_bits, exp, m_rows, widths,
                               block_n, block_k, interpret=False)


@dispatch.register("sefp_matmul_gemv_hetero", dispatch.PALLAS_INTERPRET)
def _gemv_hetero_interpret(x, mag, sign_bits, exp, m_rows, *, widths,
                           block_n=256, block_k=512):
    return _gemv_hetero_pallas(x, mag, sign_bits, exp, m_rows, widths,
                               block_n, block_k, interpret=True)


_gemv_hetero_ref_jit = jax.jit(
    sefp_matmul_gemv_hetero_ref,
    static_argnames=("widths", "block_n", "block_k"))


@dispatch.register("sefp_matmul_gemv_hetero", dispatch.JAX_REF)
def _gemv_hetero_jax_ref(x, mag, sign_bits, exp, m_rows, *, widths,
                         block_n=256, block_k=512):
    # identical pick_block resolution and tile walk as the Pallas kernel,
    # with the same static width ladder swept per k-tile (bitwise).
    return _gemv_hetero_ref_jit(x, mag, sign_bits, exp,
                                jnp.asarray(m_rows, jnp.int32),
                                widths=widths, block_n=block_n,
                                block_k=block_k)


def sefp_matmul_gemv(x: jax.Array, packed: PackedSEFP, m, *,
                     block_n: int = 256, block_k: int = 512,
                     backend: str | None = None) -> jax.Array:
    """Decode-shaped ``x @ dequantize(packed, m)``: a handful of rows
    (decode batch) against a k-major [K, N] master, with on-the-fly
    truncation to mantissa width ``m`` (python int or traced int32 scalar).

    Row count is padded to the fp32 sublane multiple (8) and sliced back,
    so any decode batch hits a legal compiled tile; all backends see the
    padded operand, keeping pallas-interpret and jax-ref agreement bitwise.
    Returns f32 [..., N]."""
    if packed.group_axis != 0 or len(packed.shape) != 2:
        raise ValueError("sefp_matmul_gemv expects a 2-D weight packed "
                         "along axis 0 (k-major)")
    k_dim, n_dim = packed.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if x2.shape[1] != k_dim:
        raise ValueError(f"x K={x2.shape[1]} vs packed K={k_dim}")
    rows = x2.shape[0]
    pad = -rows % SUBLANE
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))

    out = dispatch.dispatch(
        "sefp_matmul_gemv", x2, packed.mag, packed.sign_bits, packed.exp, m,
        block_n=block_n, block_k=block_k, backend=backend)
    if pad:
        out = out[:rows]
    return out.reshape(*lead, n_dim)


def sefp_matmul_gemv_hetero(x: jax.Array, packed: PackedSEFP, m, *,
                            widths=None, block_n: int = 256,
                            block_k: int = 512,
                            backend: str | None = None) -> jax.Array:
    """Width-heterogeneous decode gemv: output row ``i`` of
    ``x @ dequantize(packed, .)`` is truncated at its OWN mantissa width
    ``m[i]`` (int32 [rows], traced or concrete), in one fused pass over
    the shared packed bytes.

    ``widths`` is the static candidate ladder the kernel is specialized
    for (default: the full MASTER_M..1 ladder); every ``m[i]`` must be a
    member or that row comes back zero — serve callers validate on the
    host.  Row count is padded to the fp32 sublane multiple (8) like the
    scalar gemv; padded rows reuse ``m[0]``'s width so padding never adds
    a ladder branch.  Row ``i`` is bitwise equal to row ``i`` of the
    scalar ``sefp_matmul_gemv`` run on the same padded batch at
    ``m = m[i]``.  Returns f32 [..., N]."""
    if packed.group_axis != 0 or len(packed.shape) != 2:
        raise ValueError("sefp_matmul_gemv_hetero expects a 2-D weight "
                         "packed along axis 0 (k-major)")
    k_dim, n_dim = packed.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if x2.shape[1] != k_dim:
        raise ValueError(f"x K={x2.shape[1]} vs packed K={k_dim}")
    rows = x2.shape[0]
    if rows == 0:
        raise ValueError("sefp_matmul_gemv_hetero needs at least one row")
    m_arr = jnp.asarray(m, jnp.int32)
    if m_arr.shape != (rows,):
        raise ValueError(f"m must be int32 [{rows}] (one width per row), "
                         f"got shape {m_arr.shape}")
    widths = normalize_widths(widths)
    pad = -rows % SUBLANE
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        m_arr = jnp.concatenate(
            [m_arr, jnp.broadcast_to(m_arr[:1], (pad,))])

    out = dispatch.dispatch(
        "sefp_matmul_gemv_hetero", x2, packed.mag, packed.sign_bits,
        packed.exp, m_arr, widths=widths, block_n=block_n, block_k=block_k,
        backend=backend)
    if pad:
        out = out[:rows]
    return out.reshape(*lead, n_dim)
