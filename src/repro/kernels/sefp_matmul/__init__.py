from repro.kernels.sefp_matmul.ops import (  # noqa: F401
    sefp_matmul,
    sefp_matmul_gemv,
)
