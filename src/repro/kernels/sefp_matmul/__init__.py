from repro.kernels.sefp_matmul.ops import sefp_matmul  # noqa: F401
