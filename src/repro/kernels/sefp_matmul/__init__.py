from repro.kernels.sefp_matmul.ops import (  # noqa: F401
    normalize_widths,
    sefp_matmul,
    sefp_matmul_gemv,
    sefp_matmul_gemv_hetero,
)
