"""Pure-jnp oracle for the sefp_quant kernel (standalone reimplementation of
the SEFP fake-quant semantics; intentionally does not import the kernel)."""

import jax.numpy as jnp

from repro.kernels.common import EXP_MAX, EXP_MIN, GROUP, exp2i


def sefp_quantize_ref(w, m):
    """w: [K, N], groups of 64 along axis 0, mantissa width m (int or traced
    scalar).  Returns the dequantized fake-quant of w."""
    k, n = w.shape
    wf = w.astype(jnp.float32).reshape(k // GROUP, GROUP, n)
    absmax = jnp.abs(wf).max(axis=1, keepdims=True)
    mant, e = jnp.frexp(absmax)
    e = jnp.where(absmax > 0, e.astype(jnp.int32) - 1, -127)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    m = jnp.asarray(m, jnp.int32)
    quantum = exp2i(e - (m - 1))
    maxmag = exp2i(m) - 1.0
    code = jnp.clip(jnp.round(wf / quantum), -maxmag, maxmag)
    return (code * quantum).reshape(k, n).astype(w.dtype)
