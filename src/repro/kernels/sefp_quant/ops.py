"""Public SEFP fake-quant op: backend implementations + dispatch wrapper."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.common import pick_block
from repro.kernels.sefp_quant.ref import sefp_quantize_ref
from repro.kernels.sefp_quant.sefp_quant import sefp_quant_raw


@functools.partial(jax.jit,
                   static_argnames=("block_k", "block_n", "interpret"))
def _pallas_call(w, m, block_k, block_n, interpret):
    return sefp_quant_raw(w, m, block_k=block_k, block_n=block_n,
                          interpret=interpret)


def _pallas(w, m, block_k, block_n, *, interpret):
    k_dim, n_dim = w.shape
    bk = pick_block(k_dim, block_k, multiple=64)
    if bk == 0:
        raise ValueError(f"K={k_dim} must allow a block divisible by 64")
    bn = pick_block(n_dim, block_n)
    m_arr = jnp.asarray(m, jnp.int32).reshape((1,))
    return _pallas_call(w, m_arr, bk, bn, interpret)


@dispatch.register("sefp_quant", dispatch.PALLAS_TPU)
def _quant_tpu(w, m, *, block_k=256, block_n=512):
    return _pallas(w, m, block_k, block_n, interpret=False)


@dispatch.register("sefp_quant", dispatch.PALLAS_INTERPRET)
def _quant_interpret(w, m, *, block_k=256, block_n=512):
    return _pallas(w, m, block_k, block_n, interpret=True)


_ref_jit = jax.jit(sefp_quantize_ref)


@dispatch.register("sefp_quant", dispatch.JAX_REF)
def _quant_jax_ref(w, m, *, block_k=256, block_n=512):
    del block_k, block_n  # whole-array oracle; no tiling
    return _ref_jit(w, jnp.asarray(m, jnp.int32))


def sefp_quantize_pallas(w: jax.Array, m, *, block_k: int = 256,
                         block_n: int = 512, interpret: bool | None = None,
                         backend: str | None = None):
    """SEFP fake-quantize a [K, N] weight (groups of 64 along K) at mantissa
    width ``m`` (python int or int32 scalar — dynamic, no recompile).

    Backend resolution: ``backend=`` > ``REPRO_KERNEL_BACKEND`` > platform
    auto.  ``interpret`` is the pre-dispatch spelling, kept for callers that
    pin the Pallas path explicitly."""
    if backend is None and interpret is not None:
        backend = (dispatch.PALLAS_INTERPRET if interpret
                   else dispatch.PALLAS_TPU)
    if w.shape[0] % 64:
        raise ValueError(f"K={w.shape[0]} must allow a block divisible "
                         "by 64")
    return dispatch.dispatch("sefp_quant", w, m, block_k=block_k,
                             block_n=block_n, backend=backend)
