"""Jitted public wrapper for the SEFP fake-quant kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import kernels
from repro.kernels.common import pick_block
from repro.kernels.sefp_quant.sefp_quant import sefp_quant_raw


@functools.partial(jax.jit,
                   static_argnames=("block_k", "block_n", "interpret"))
def _call(w, m, block_k, block_n, interpret):
    return sefp_quant_raw(w, m, block_k=block_k, block_n=block_n,
                          interpret=interpret)


def sefp_quantize_pallas(w: jax.Array, m, *, block_k: int = 256,
                         block_n: int = 512, interpret: bool | None = None):
    """SEFP fake-quantize a [K, N] weight (groups of 64 along K) at mantissa
    width ``m`` (python int or int32 scalar — dynamic, no recompile)."""
    if interpret is None:
        interpret = kernels.INTERPRET
    k_dim, n_dim = w.shape
    bk = pick_block(k_dim, block_k, multiple=64)
    if bk == 0:
        raise ValueError(f"K={k_dim} must allow a block divisible by 64")
    bn = pick_block(n_dim, block_n)
    m_arr = jnp.asarray(m, jnp.int32).reshape((1,))
    return _call(w, m_arr, bk, bn, interpret)
