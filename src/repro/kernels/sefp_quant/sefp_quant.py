"""Pallas TPU kernel: SEFP group-shared-exponent fake quantization.

Training hot path — every OTARo step fake-quantizes every weight matrix at
the BPS-selected mantissa width.  The width ``m`` arrives via scalar
prefetch, so one compiled kernel serves every precision E5M8..E5M3.

Layout: weights [K, N] grouped along axis 0 (the contraction axis, matching
PackedSEFP's k-major layout); one grid cell owns a (bk, bn) VMEM tile with
bk a multiple of the group size 64, so every group is resident in VMEM and
the group max-exponent reduction never crosses tiles.

TPU mapping notes:
  * the group reduction is a static python loop over bk//64 row-slices —
    each slice is a [64, bn] sublane-contiguous block (Mosaic-friendly, no
    dynamic shapes);
  * exponents are extracted from the fp32 bit pattern (VPU integer ops) —
    exact, unlike a log2 polynomial;
  * quanta 2^e are built by placing e in the exponent field — exact, and
    avoids the transcendental unit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.common import EXP_MAX, EXP_MIN, GROUP, exp2i, floor_log2_bits


def _quant_kernel(m_ref, w_ref, o_ref):
    m = m_ref[0]
    maxmag = exp2i(m) - 1.0
    bk = w_ref.shape[0]
    for g in range(bk // GROUP):
        sl = slice(g * GROUP, (g + 1) * GROUP)
        blk = w_ref[sl, :].astype(jnp.float32)
        absmax = jnp.max(jnp.abs(blk), axis=0, keepdims=True)
        e = floor_log2_bits(absmax)
        e = jnp.clip(e, EXP_MIN, EXP_MAX)
        quantum = exp2i(e - (m - 1))
        code = jnp.clip(jnp.round(blk / quantum), -maxmag, maxmag)
        o_ref[sl, :] = (code * quantum).astype(o_ref.dtype)


def sefp_quant_raw(w: jax.Array, m: jax.Array, *, block_k: int, block_n: int,
                   interpret: bool) -> jax.Array:
    """w: [K, N] (K % block_k == 0, N % block_n == 0, block_k % 64 == 0).
    m: int32[1] mantissa width. Returns dequantized fake-quant of w."""
    k_dim, n_dim = w.shape
    grid = (k_dim // block_k, n_dim // block_n)
    grid_spec = compat.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((block_k, block_n), lambda i, j, s: (i, j))],
        out_specs=pl.BlockSpec((block_k, block_n), lambda i, j, s: (i, j)),
    )
    return pl.pallas_call(
        _quant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
    )(m, w)
