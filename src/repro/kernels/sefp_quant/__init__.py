from repro.kernels.sefp_quant.ops import sefp_quantize_pallas  # noqa: F401
