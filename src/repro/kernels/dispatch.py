"""Kernel dispatch registry: one name per op, many backend implementations.

Every SEFP hot-path op (``sefp_quant``, ``sefp_pack``, ``sefp_matmul``,
``sefp_matmul_gemv``, ``sefp_matmul_gemv_hetero``) is registered here under
named backends:

  * ``PALLAS_TPU``        — compiled Mosaic kernel (real TPU);
  * ``PALLAS_INTERPRET``  — the same Pallas kernel body executed by the
                            interpreter (any backend; validates the kernel
                            logic itself on CPU);
  * ``JAX_REF``           — the jitted pure-jnp oracle (fast on CPU, and the
                            semantic contract the kernels are tested against).

Backend resolution precedence (see DESIGN.md §2):

  1. per-call override          — ``backend=JAX_REF`` kwarg;
  2. environment escape hatch   — ``REPRO_KERNEL_BACKEND=jax-ref``;
  3. platform auto-selection    — TPU -> ``PALLAS_TPU``, anything else ->
                                  ``PALLAS_INTERPRET``.

The registry is the seam for future backends (e.g. a GPU Pallas/Triton
lowering registers under a new name; nothing at the call sites changes).
The backend-name strings themselves live in compat.py (so the "no direct
Pallas-TPU references outside compat" invariant stays greppable).
"""

from __future__ import annotations

import os
from typing import Any, Callable, Dict

import jax

from repro.kernels.compat import (
    BACKEND_JAX_REF as JAX_REF,
    BACKEND_PALLAS_INTERPRET as PALLAS_INTERPRET,
    BACKEND_PALLAS_TPU as PALLAS_TPU,
)

ENV_VAR = "REPRO_KERNEL_BACKEND"

BACKENDS = (PALLAS_TPU, PALLAS_INTERPRET, JAX_REF)

_REGISTRY: Dict[str, Dict[str, Callable[..., Any]]] = {}
_OPS_IMPORTED = False


def register(op: str, backend: str):
    """Decorator: register ``fn`` as the ``backend`` implementation of
    ``op``.  Implementations of one op must be call-compatible.  Backend
    names are open — a new backend (e.g. a GPU lowering) registers under a
    new name and becomes resolvable with no other changes."""
    if not backend or not isinstance(backend, str):
        raise ValueError(f"backend name must be a non-empty string, "
                         f"got {backend!r}")

    def deco(fn):
        _REGISTRY.setdefault(op, {})[backend] = fn
        return fn

    return deco


def _ensure_ops_registered():
    # Importing an op package registers its backends; lazy so that importing
    # repro.kernels.dispatch alone stays cheap and cycle-free.  The flag is
    # set only after the imports succeed, so a failed import surfaces again
    # on the next call instead of being masked as "unknown kernel op".
    global _OPS_IMPORTED
    if _OPS_IMPORTED:
        return
    from repro.kernels.sefp_matmul import ops as _mm  # noqa: F401
    from repro.kernels.sefp_pack import ops as _pk    # noqa: F401
    from repro.kernels.sefp_quant import ops as _qt   # noqa: F401
    _OPS_IMPORTED = True


def _known_backends() -> set:
    known = set(BACKENDS)
    for impls in _REGISTRY.values():
        known.update(impls)
    return known


def registered_ops():
    _ensure_ops_registered()
    return sorted(_REGISTRY)


def backends_for(op: str):
    _ensure_ops_registered()
    if op not in _REGISTRY:
        raise KeyError(f"unknown kernel op {op!r}; registered ops: "
                       f"{sorted(_REGISTRY)}")
    return sorted(_REGISTRY[op])


def auto_backend(platform: str | None = None) -> str:
    """Platform-derived default: compiled Mosaic on real TPUs, interpreter
    everywhere else (the interpreter runs the same kernel bodies)."""
    if platform is None:
        platform = jax.default_backend()
    return PALLAS_TPU if platform == "tpu" else PALLAS_INTERPRET


def resolve_backend(backend: str | None = None) -> str:
    """Apply the per-call > env-var > platform-auto precedence chain."""
    _ensure_ops_registered()
    name = backend or os.environ.get(ENV_VAR) or auto_backend()
    if name not in _known_backends():
        source = ("per-call override" if backend
                  else f"environment variable {ENV_VAR}")
        raise ValueError(f"unknown kernel backend {name!r} (from {source}); "
                         f"expected one of {sorted(_known_backends())}")
    return name


def dispatch(op: str, *args, backend: str | None = None, **kwargs):
    """Run ``op`` on the resolved backend.  Raises with the list of
    registered alternatives when the op/backend pair is missing."""
    _ensure_ops_registered()
    impls = _REGISTRY.get(op)
    if not impls:
        raise KeyError(f"unknown kernel op {op!r}; registered ops: "
                       f"{sorted(_REGISTRY)}")
    name = resolve_backend(backend)
    impl = impls.get(name)
    if impl is None:
        raise ValueError(f"op {op!r} has no {name!r} implementation; "
                         f"available backends: {sorted(impls)}")
    return impl(*args, **kwargs)
