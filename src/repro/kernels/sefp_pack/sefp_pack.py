"""Pallas TPU kernel: pack weights into the deployable SEFP M8 master.

Deployment-preparation hot path: after (or during) OTARo fine-tuning the
master weights are packed ONCE into (mag uint8, bit-packed signs, group
exponents) — the representation every serving precision truncates from
(core/packed.py).  On-device packing matters for the paper's edge story:
an OTA-updated model is packed on the device itself, and periodic
re-packing during on-device fine-tuning must not stall training.

Layout matches PackedSEFP k-major: w [K, N] grouped along K (64/group),
outputs mag [K, N] u8, sign_bits [K//8, N] u8 (bit j of byte i -> row
8i+j), exp [K//64, N] i8.

TPU mapping: one grid cell owns a (bk, bn) tile with bk a multiple of 64;
group reductions are static row-slices; sign packing is 8 static masked
adds per group (VPU integer ops); exponents via fp32 bit tricks (exact).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.common import EXP_MAX, EXP_MIN, GROUP, exp2i, floor_log2_bits

MASTER_M = 8


def _pack_kernel(w_ref, mag_ref, sgn_ref, exp_ref):
    bk, bn = w_ref.shape
    for g in range(bk // GROUP):
        rows = slice(g * GROUP, (g + 1) * GROUP)
        blk = w_ref[rows, :].astype(jnp.float32)
        absmax = jnp.max(jnp.abs(blk), axis=0, keepdims=True)   # [1, bn]
        e = jnp.clip(floor_log2_bits(absmax), EXP_MIN, EXP_MAX)
        quantum = exp2i(e - (MASTER_M - 1))
        code = jnp.clip(jnp.round(blk / quantum), -255.0, 255.0)
        mag_ref[rows, :] = jnp.abs(code).astype(jnp.uint8)
        exp_ref[g:g + 1, :] = e.astype(jnp.int8)
        neg = (code < 0).astype(jnp.uint32)                     # [64, bn]
        for b in range(GROUP // 8):
            byte = jnp.zeros((1, bn), jnp.uint32)
            for j in range(8):
                byte = byte + (neg[b * 8 + j][None, :] << j)
            sgn_ref[g * 8 + b:g * 8 + b + 1, :] = byte.astype(jnp.uint8)


def sefp_pack_raw(w: jax.Array, *, block_k: int, block_n: int,
                  interpret: bool):
    k_dim, n_dim = w.shape
    grid = (k_dim // block_k, n_dim // block_n)
    out_shape = (
        jax.ShapeDtypeStruct((k_dim, n_dim), jnp.uint8),          # mag
        jax.ShapeDtypeStruct((k_dim // 8, n_dim), jnp.uint8),     # signs
        jax.ShapeDtypeStruct((k_dim // GROUP, n_dim), jnp.int8),  # exp
    )
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_k, block_n), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((block_k, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_k // 8, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_k // GROUP, block_n), lambda i, j: (i, j)),
        ),
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
    )(w)
