from repro.kernels.sefp_pack.ops import sefp_pack_pallas  # noqa: F401
