"""Pure-jnp oracle for the sefp_pack kernel (standalone; the framework-wide
reference is core/packed.pack — tests assert all three agree bitwise)."""

import jax.numpy as jnp

from repro.kernels.common import EXP_MAX, EXP_MIN, GROUP, exp2i

MASTER_M = 8


def sefp_pack_ref(w):
    k, n = w.shape
    g = w.astype(jnp.float32).reshape(k // GROUP, GROUP, n)
    absmax = jnp.abs(g).max(axis=1, keepdims=True)
    mant, e = jnp.frexp(absmax)
    e = jnp.where(absmax > 0, e.astype(jnp.int32) - 1, -127)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    quantum = exp2i(e - (MASTER_M - 1))
    code = jnp.clip(jnp.round(g / quantum), -255.0, 255.0)
    mag = jnp.abs(code).astype(jnp.uint8).reshape(k, n)
    sign = (code < 0).astype(jnp.uint32).reshape(k // 8, 8, n)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint32))[None, :, None]
    sign_bits = (sign * weights).sum(axis=1).astype(jnp.uint8)
    exp = e.reshape(k // GROUP, n).astype(jnp.int8)
    return mag, sign_bits, exp
