"""Public SEFP master-pack op: backend implementations + dispatch wrapper."""

from __future__ import annotations

import functools

import jax

from repro.core.packed import PackedSEFP
from repro.kernels import dispatch
from repro.kernels.common import pick_block
from repro.kernels.sefp_pack.ref import sefp_pack_ref
from repro.kernels.sefp_pack.sefp_pack import sefp_pack_raw


@functools.partial(jax.jit,
                   static_argnames=("block_k", "block_n", "interpret"))
def _pallas_call(w, block_k, block_n, interpret):
    return sefp_pack_raw(w, block_k=block_k, block_n=block_n,
                         interpret=interpret)


def _pallas(w, block_k, block_n, *, interpret):
    k_dim, n_dim = w.shape
    bk = pick_block(k_dim, block_k, multiple=64)
    if bk == 0:
        raise ValueError(f"K={k_dim} must allow a 64-divisible block")
    bn = pick_block(n_dim, block_n)
    return _pallas_call(w, bk, bn, interpret)


@dispatch.register("sefp_pack", dispatch.PALLAS_TPU)
def _pack_tpu(w, *, block_k=256, block_n=512):
    return _pallas(w, block_k, block_n, interpret=False)


@dispatch.register("sefp_pack", dispatch.PALLAS_INTERPRET)
def _pack_interpret(w, *, block_k=256, block_n=512):
    return _pallas(w, block_k, block_n, interpret=True)


_ref_jit = jax.jit(sefp_pack_ref)


@dispatch.register("sefp_pack", dispatch.JAX_REF)
def _pack_jax_ref(w, *, block_k=256, block_n=512):
    del block_k, block_n  # whole-array oracle; no tiling
    return _ref_jit(w)


def sefp_pack_pallas(w: jax.Array, *, block_k: int = 256,
                     block_n: int = 512, interpret: bool | None = None,
                     backend: str | None = None) -> PackedSEFP:
    """Pack a [K, N] weight (K % 64 == 0) into the E5M8 master, k-major.

    Backend resolution: ``backend=`` > ``REPRO_KERNEL_BACKEND`` > platform
    auto."""
    if backend is None and interpret is not None:
        backend = (dispatch.PALLAS_INTERPRET if interpret
                   else dispatch.PALLAS_TPU)
    if w.shape[0] % 64:
        raise ValueError(f"K={w.shape[0]} must allow a 64-divisible block")
    mag, sign_bits, exp = dispatch.dispatch(
        "sefp_pack", w, block_k=block_k, block_n=block_n, backend=backend)
    return PackedSEFP(mag=mag, sign_bits=sign_bits, exp=exp,
                      shape=tuple(w.shape), group_axis=0, group_size=64)
