"""Jitted public wrapper: pack a weight into a PackedSEFP master on-device."""

from __future__ import annotations

import functools

import jax

from repro import kernels
from repro.core.packed import PackedSEFP
from repro.kernels.common import pick_block
from repro.kernels.sefp_pack.sefp_pack import sefp_pack_raw


@functools.partial(jax.jit,
                   static_argnames=("block_k", "block_n", "interpret"))
def _call(w, block_k, block_n, interpret):
    return sefp_pack_raw(w, block_k=block_k, block_n=block_n,
                         interpret=interpret)


def sefp_pack_pallas(w: jax.Array, *, block_k: int = 256,
                     block_n: int = 512,
                     interpret: bool | None = None) -> PackedSEFP:
    """Pack a [K, N] weight (K % 64 == 0) into the E5M8 master, k-major."""
    if interpret is None:
        interpret = kernels.INTERPRET
    k_dim, n_dim = w.shape
    bk = pick_block(k_dim, block_k, multiple=64)
    if bk == 0:
        raise ValueError(f"K={k_dim} must allow a 64-divisible block")
    bn = pick_block(n_dim, block_n)
    mag, sign_bits, exp = _call(w, bk, bn, interpret)
    return PackedSEFP(mag=mag, sign_bits=sign_bits, exp=exp,
                      shape=(k_dim, n_dim), group_axis=0, group_size=64)
