"""Shared in-kernel numeric helpers (exact power-of-two and exponent ops)."""

import jax
import jax.numpy as jnp
from jax import lax

EXP_MIN = -14
EXP_MAX = 15
GROUP = 64


def exp2i(e):
    """Exact 2**e for integer e in [-126, 127] via fp32 exponent-field
    construction (jnp.exp2 is not exact on every backend)."""
    bits = (jnp.asarray(e, jnp.int32) + 127) << 23
    return lax.bitcast_convert_type(bits, jnp.float32)


def floor_log2_bits(x_abs):
    """floor(log2 x) for x > 0 via the fp32 exponent field.  Exact for
    normals; subnormals return <= -127 which the E5 clamp absorbs."""
    bits = lax.bitcast_convert_type(x_abs.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def pick_block(dim: int, preferred: int, multiple: int = 1) -> int:
    """Largest divisor of ``dim`` that is <= preferred and a multiple of
    ``multiple`` (keeps grids exact without padding for the shapes used in
    this repo).  Returns 0 if no such block exists."""
    b = min(preferred, dim)
    b -= b % multiple
    while b >= multiple:
        if dim % b == 0:
            return b
        b -= multiple
    return 0
