"""JAX version-compatibility shim — the single owner of every
version-sensitive JAX symbol in this repo.

The supported range is JAX 0.4.37 .. 0.7.x (see DESIGN.md §2).  Across that
range several APIs this codebase relies on were renamed or introduced:

  ============================  ======================  =====================
  stable name here              old JAX (<= 0.4.x)      new JAX (>= 0.5/0.6)
  ============================  ======================  =====================
  ``tpu_compiler_params``       pltpu.TPUCompilerParams pltpu.CompilerParams
  ``make_mesh``                 jax.make_mesh           jax.make_mesh
                                (no axis_types kwarg)   (+ axis_types=...)
  ``set_mesh``                  ``with mesh:``          jax.set_mesh(mesh)
  ``get_abstract_mesh``         thread-resources        jax.sharding.
                                physical mesh           get_abstract_mesh()
  ``shard_map``                 jax.experimental.       jax.shard_map
                                shard_map (auto=,       (axis_names=,
                                check_rep=)             check_vma=)
  ============================  ======================  =====================

Everything is feature-detected ONCE at import time and exposed under stable
names.  No other module in the repo may import ``jax.experimental.pallas.tpu``
or touch version-gated ``jax.sharding`` attributes directly — that invariant
is what keeps the next JAX upgrade a one-file change (enforced by
tests/test_kernel_backends.py::test_compat_is_sole_owner).
"""

from __future__ import annotations

import inspect
import re

import jax
from jax.experimental import mesh_utils as _mesh_utils
from jax.experimental import pallas as pl  # noqa: F401  (re-export surface)
from jax.experimental.pallas import tpu as _pltpu
from jax.sharding import Mesh

def _parse_version(v: str) -> tuple:
    out = []
    for part in v.split(".")[:3]:
        digits = re.match(r"\d+", part)
        out.append(int(digits.group()) if digits else 0)
    return tuple(out)


# Informational (not used for feature gates — those are all detected by
# probing the symbols themselves).  Tolerates dev/rc suffixes.
JAX_VERSION = _parse_version(jax.__version__)

# JAX < 0.5 defaults to the legacy non-partitionable threefry, whose values
# silently CHANGE when a vmapped random init is compiled with sharded outputs
# on the 0.4.x SPMD partitioner (observed on CPU: jit(vmap(normal),
# out_shardings=...) differs from the unsharded result by O(1)).  The
# partitionable stream — the default from JAX 0.5 on — is sharding-invariant
# by construction; align older JAX with it.
try:
    jax.config.update("jax_threefry_partitionable", True)
except Exception:  # pragma: no cover - flag retired in a future version
    pass

# --------------------------------------------------------------------------
# Pallas TPU: compiler params + scalar-prefetch grid spec
# --------------------------------------------------------------------------

# Renamed TPUCompilerParams -> CompilerParams in jax 0.6.
_CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")

PrefetchScalarGridSpec = _pltpu.PrefetchScalarGridSpec


def tpu_compiler_params(*, dimension_semantics):
    """Mosaic compiler params with the given grid dimension semantics
    (ignored in interpret mode)."""
    return _CompilerParams(dimension_semantics=dimension_semantics)


# Canonical dispatch backend names.  Defined here (not in dispatch.py) so the
# repo invariant "the string pallas[-.]tpu appears only in compat.py" stays
# greppable; dispatch.py re-exports them.
BACKEND_PALLAS_TPU = "pallas-tpu"
BACKEND_PALLAS_INTERPRET = "pallas-interpret"
BACKEND_JAX_REF = "jax-ref"


# --------------------------------------------------------------------------
# Mesh construction
# --------------------------------------------------------------------------

AxisType = getattr(jax.sharding, "AxisType", None)

_make_mesh = getattr(jax, "make_mesh", None)
_MAKE_MESH_AXIS_TYPES = (
    _make_mesh is not None and AxisType is not None
    and "axis_types" in inspect.signature(_make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, axis_types="auto"):
    """Device mesh over all local devices.

    ``axis_types="auto"`` requests GSPMD-auto axes where the installed JAX
    supports explicit axis types; on older JAX (where every axis is
    implicitly auto) the kwarg is simply omitted.  Falls back to
    ``Mesh(mesh_utils.create_device_mesh(...))`` when ``jax.make_mesh``
    itself is absent."""
    if _make_mesh is None:
        return Mesh(_mesh_utils.create_device_mesh(tuple(axis_shapes)),
                    tuple(axis_names))
    if axis_types is None or not _MAKE_MESH_AXIS_TYPES:
        return _make_mesh(tuple(axis_shapes), tuple(axis_names))
    if axis_types == "auto":
        axis_types = (AxisType.Auto,) * len(tuple(axis_names))
    return _make_mesh(tuple(axis_shapes), tuple(axis_names),
                      axis_types=axis_types)


# --------------------------------------------------------------------------
# Ambient mesh: set + query
# --------------------------------------------------------------------------

_set_mesh = getattr(jax, "set_mesh", None)


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient for everything traced inside.
    New JAX: ``jax.set_mesh``.  Old JAX: ``Mesh`` is itself a context manager
    that installs the thread-resources physical mesh, which is what
    :func:`get_abstract_mesh` reads back."""
    if _set_mesh is not None:
        return _set_mesh(mesh)
    return mesh


_get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh", None)


def _thread_resources():
    try:
        from jax._src import mesh as mesh_lib
        return mesh_lib.thread_resources
    except Exception:  # pragma: no cover - very old layouts
        from jax.interpreters import pxla
        return pxla.thread_resources


def get_abstract_mesh():
    """The mesh ambient at trace time, or None when no mesh is active.

    New JAX returns the abstract mesh installed by ``jax.set_mesh``; old JAX
    degrades to the explicit physical mesh installed by ``with mesh:``."""
    if _get_abstract_mesh is not None:
        mesh = _get_abstract_mesh()
        if mesh is None or mesh.empty:
            return None
        return mesh
    try:
        mesh = _thread_resources().env.physical_mesh
    except Exception:  # pragma: no cover
        return None
    if mesh is None or mesh.empty:
        return None
    return mesh


# Stable alias: call sites outside compat use this name (keeps the
# "version-gated jax.sharding attributes only in compat" invariant greppable).
ambient_mesh = get_abstract_mesh


def mesh_axis_types(mesh):
    """Per-axis AxisType tuple, or None when the installed JAX predates
    explicit axis types (every axis is implicitly GSPMD-auto then)."""
    return getattr(mesh, "axis_types", None)


def manual_axis_names(mesh) -> frozenset:
    """Names of mesh axes that are manual at the current trace point; such
    axes must not appear in sharding constraints.

    New JAX marks them on the (abstract) mesh's axis_types; old JAX has no
    axis types, but every axis a shard_map made manual is bound in the trace
    axis env, so the union of both views is correct on either version."""
    manual = set(bound_axis_names())
    types = mesh_axis_types(mesh)
    if types:
        try:
            manual.update(a for a, t in zip(mesh.axis_names, types)
                          if "Manual" in str(t))
        except Exception:  # pragma: no cover
            pass
    return frozenset(manual)


def bound_axis_names() -> frozenset:
    """Axis names bound in the ambient trace (inside shard_map/pmap)."""
    try:
        from jax._src import core as jcore
        return frozenset(jcore.get_axis_env().axis_sizes)
    except Exception:
        return frozenset()


def cost_analysis(compiled) -> dict:
    """Flat {metric: value} from a compiled executable.  Old JAX returns a
    one-element list of dicts from ``compiled.cost_analysis()``; new JAX
    returns the dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


# --------------------------------------------------------------------------
# shard_map
# --------------------------------------------------------------------------

_new_shard_map = getattr(jax, "shard_map", None)

# jax.shard_map's keywords changed while it migrated out of experimental
# (check_rep/auto -> check_vma/axis_names); probe the signature rather than
# assuming the spelling from any one release.
_SM_CHECK_KW = None
_SM_MANUAL_KW = None
if _new_shard_map is not None:
    try:
        _sm_params = inspect.signature(_new_shard_map).parameters
        _SM_CHECK_KW = next((k for k in ("check_vma", "check_rep")
                             if k in _sm_params), None)
        _SM_MANUAL_KW = next((k for k in ("axis_names", "auto")
                              if k in _sm_params), None)
    except (TypeError, ValueError):  # pragma: no cover - unusual wrappers
        _SM_CHECK_KW, _SM_MANUAL_KW = "check_vma", "axis_names"


def shard_map(f, mesh, *, in_specs, out_specs, manual_axes=None,
              check=False):
    """Partial-manual shard_map: ``manual_axes`` become manual inside ``f``;
    every other mesh axis stays GSPMD-auto.  ``manual_axes=None`` means all
    axes manual (plain shard_map).

    Old-JAX degradation: the partial-auto partitioner (``auto=``) hard-fails
    in XLA on 0.4.x CPU (``Check failed: sharding.IsManualSubgroup()``), so
    every axis goes manual there instead.  Results are identical — specs that
    only mention ``manual_axes`` leave the other axes' shards replicated, so
    devices along would-be-auto axes compute redundantly rather than
    cooperatively (fine for the CPU test substrate; real partial-auto
    resumes on new JAX)."""
    if _new_shard_map is not None:
        kwargs = {}
        if _SM_CHECK_KW:
            kwargs[_SM_CHECK_KW] = check
        if manual_axes is not None and _SM_MANUAL_KW:
            kwargs[_SM_MANUAL_KW] = (
                set(manual_axes) if _SM_MANUAL_KW == "axis_names"
                else frozenset(mesh.axis_names) - frozenset(manual_axes))
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _old_shard_map
    return _old_shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=check)
