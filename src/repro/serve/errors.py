"""Structured error taxonomy for the serve subsystem (DESIGN.md §12).

Every failure the serving stack can signal to a caller is a named exception
below, replacing the bare ``ValueError``/``KeyError`` leaks of the early
scheduler.  The split that matters operationally:

  * **admission-time errors** are raised from ``submit``/``try_submit`` —
    the request never entered the system (``QueueFull``, ``BadDeadline``,
    ``UnknownRequestClass``);
  * **in-flight failures** are *terminal statuses* on ``FinishedRequest``
    (``evicted`` / ``deadline`` / ``poisoned``), never exceptions: a
    continuous batch must keep stepping for its healthy co-residents, so a
    mid-stream failure retires one slot and surfaces through the normal
    drain path.  The exception classes ``DeadlineExceeded``/``SlotPoisoned``
    exist for callers that *choose* to re-raise a failed result
    (``FinishedRequest.raise_for_status()``).

``QueueFull`` carries ``retry_after_steps`` — the scheduler's estimate (in
decode steps, its native clock) of when a slot or queue seat frees — so a
client can implement honest backoff instead of hammering ``submit``.

``UnknownRequestClass`` subclasses ``KeyError`` (the pre-taxonomy leak) so
existing ``except KeyError`` call sites keep working; its message names the
registered classes, turning a routing typo into a one-glance fix.
"""

from __future__ import annotations

from typing import Optional, Sequence


class ServeError(Exception):
    """Base of every serve-layer error (catch-all for callers that only
    care that the serving stack, not their own code, failed)."""


class QueueFull(ServeError):
    """Admission rejected: the bounded request queue is at capacity.

    ``retry_after_steps`` is the scheduler's backoff hint in decode steps
    (>= 1); convert with your observed step latency for a wall-clock
    retry-after."""

    def __init__(self, depth: int, max_queue: int, retry_after_steps: int):
        self.depth = int(depth)
        self.max_queue = int(max_queue)
        self.retry_after_steps = max(1, int(retry_after_steps))
        super().__init__(
            f"request queue is full ({depth}/{max_queue} pending); "
            f"retry in ~{self.retry_after_steps} decode steps")


class BadDeadline(ServeError):
    """Admission rejected: the request's deadline can never be met (already
    expired, or shorter than the work it asks for)."""


class DeadlineExceeded(ServeError):
    """A request missed its deadline in flight.  Surfaced as terminal
    status ``deadline`` (partial tokens kept) or ``evicted`` (never
    admitted); raised only by ``FinishedRequest.raise_for_status()``."""


class SlotPoisoned(ServeError):
    """A slot's decode step produced non-finite logits (or tripped the
    repetition guard) and was quarantined.  Surfaced as terminal status
    ``poisoned``; co-resident slots are unaffected by construction
    (DESIGN.md §12).  Raised only by ``raise_for_status()``."""


class UnknownRequestClass(ServeError, KeyError):
    """Request-class routing failed: the PrecisionPolicy defines no plan
    for this class.  Names the registered classes so the fix is evident.

    Also a ``KeyError`` for backward compatibility with pre-taxonomy
    callers (the class lookup used to leak the policy's bare KeyError)."""

    def __init__(self, request_class: str,
                 registered: Optional[Sequence[str]] = None):
        self.request_class = request_class
        self.registered = sorted(registered or [])
        msg = (f"unknown request class {request_class!r}; policy defines "
               f"{self.registered if self.registered else 'no classes'}")
        # KeyError renders args[0] with repr(); keep the readable message.
        ServeError.__init__(self, msg)

    def __str__(self) -> str:  # undo KeyError's repr-quoting
        return self.args[0]


# terminal statuses a FinishedRequest can carry, and the exception each one
# maps to under raise_for_status() (None = success, nothing to raise)
TERMINAL_STATUSES = {
    "ok": None,
    "evicted": DeadlineExceeded,
    "deadline": DeadlineExceeded,
    "poisoned": SlotPoisoned,
}
