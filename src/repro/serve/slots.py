"""Slot-level state for continuous batching: request/result records, host
bookkeeping, and the device-side cache slot operations.

A continuous batch is a fixed set of ``n_slots`` rows of one shared decode
cache (``lm_init_cache(..., per_slot=True)``: every row carries its OWN
position counter ``pos: int32[B]``).  A request occupies one slot from
admission to finish; the row's lifecycle is

    free -> admitted (batch-1 prefill written into the row, first token
    sampled from the prefill logits) -> decoding (committed on the steps
    its width group is served) -> finished (EOS or max_new) -> free again,
    immediately re-admittable — no waiting for batch neighbours.

Two device operations define the slot discipline, both pure tree maps keyed
on the one structural fact of the cache layout (``pos`` is per-slot at axis
0; every other leaf is stacked ``[layers, B, ...]`` with batch at axis 1):

  * ``write_slot(cache, slot_cache, idx)`` — install a batch-1 prefill
    cache into row ``idx``.  ``idx`` is traced, so one compiled write
    serves every slot.
  * ``select_slots(mask, new, old)`` — per-row commit of a decode step:
    rows with ``mask[b]`` take the stepped cache, the rest keep their
    previous state byte-for-byte.  This is what makes a batched step safe
    for rows that are free or whose width group was not scheduled this
    step: their KV rows, recurrent (Mamba2/RWKV6) states and positions are
    untouched, so a stalled request resumes exactly where it stopped.

The scheduling logic that drives these lives in repro/serve/scheduler.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# host-side records
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One queued generation request.  ``request_class`` routes through the
    scheduler's PrecisionPolicy (named class -> width plan); sampling
    params are per-request (the vectorized sampler serves any mix);
    ``stream`` is an optional ``stream(rid, token, done)`` callback fired
    as each token is committed.  Resilience fields (DESIGN.md §12):
    ``deadline`` is the step-clock budget from submit to finish (None =
    none; missing it retires the request with status ``deadline``, or
    ``evicted`` if it expires while still queued) and ``min_width`` is the
    degradation floor — the slo-degrade policy never serves this request
    below it (resolved through the policy's per-class floors at submit)."""
    rid: int
    prompt: np.ndarray
    max_new: int
    request_class: Optional[str] = None
    temperature: float = 0.0
    top_k: int = 0
    eos_id: Optional[int] = None
    seed: int = 0
    stream: Optional[Callable[[int, int, bool], None]] = None
    submit_step: int = 0        # scheduler step clock at submit()
    deadline: Optional[int] = None   # steps from submit to finish
    min_width: int = 1               # degradation floor (resolved)


@dataclasses.dataclass
class FinishedRequest:
    """A completed request with its realized precision trace and step-clock
    latency accounting (submit -> admit is queue wait; admit -> finish is
    service time, both in scheduler decode steps).

    ``status`` is the terminal outcome (DESIGN.md §12): ``ok`` (finished by
    EOS or length), ``evicted`` (expired in the queue, never decoded),
    ``deadline`` (missed its deadline mid-decode; partial tokens kept) or
    ``poisoned`` (quarantined after non-finite logits / runaway
    repetition; tokens up to the last healthy step kept).
    ``finish_reason`` stays the finer-grained cause ("eos", "length",
    "evicted", "deadline", "poisoned", "repetition")."""
    rid: int
    tokens: np.ndarray          # [n] int32, n <= max_new (incl. eos if hit)
    prompt_len: int
    finish_reason: str          # "eos" | "length" | failure cause
    prefill_precision: int      # width the prompt ran at
    decode_widths: List[int]    # realized width of each committed step
    request_class: Optional[str]
    submit_step: int
    admit_step: int
    finish_step: int
    status: str = "ok"          # ok | evicted | deadline | poisoned
    spec: Optional[Dict[str, int]] = None  # speculative accounting, if any
    # wall-clock latency (DESIGN.md §16), recorded host-side only when the
    # scheduler runs with telemetry enabled (None under NullTelemetry):
    # submit_s / first_token_s / finish_s (seconds since the tracer epoch),
    # ttft_s, itl_mean_s.  Step-clock accounting above is always present.
    wall: Optional[Dict[str, Optional[float]]] = None

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def raise_for_status(self) -> "FinishedRequest":
        """Return self if the request succeeded, else raise the taxonomy
        error matching the terminal status (serve/errors.py)."""
        from repro.serve import errors as errors_lib
        exc = errors_lib.TERMINAL_STATUSES.get(self.status)
        if exc is None:
            return self
        raise exc(f"request {self.rid} finished with status "
                  f"{self.status!r} ({self.finish_reason}) after "
                  f"{len(self.tokens)} tokens")

    def width_counts(self) -> Dict[int, int]:
        """Committed tokens per realized decode width, e.g. ``{8: 5, 4: 3}``.
        Summing this over finished requests reproduces the scheduler's
        ``tokens_by_width`` stat for the drained portion of the run."""
        counts: Dict[int, int] = {}
        for w in self.decode_widths:
            counts[w] = counts.get(w, 0) + 1
        return counts

    def oracle_schedule(self) -> tuple:
        """(precision_schedule, prefill_precision) that reproduces this
        request bitwise on the lockstep engine:
        ``server.generate(prompt[None], max_new=len(tokens),
        precision_schedule=sched, prefill_precision=pm)``.  Step i of a
        lockstep generation consumes token i at schedule[i]; the last
        step's logits are never sampled from, so its width is padded with
        the final realized width (it cannot affect the tokens)."""
        n = len(self.tokens)
        if n == 0:
            return [], self.prefill_precision
        pad = (self.decode_widths[-1] if self.decode_widths
               else self.prefill_precision)
        return list(self.decode_widths) + [pad], self.prefill_precision


@dataclasses.dataclass
class SlotState:
    """Host view of one occupied slot.  Under the paged cache a slot also
    carries its page accounting: ``pages`` is the slot's block-table row
    (physical pages in logical order, reused prefix pages first),
    ``n_reused`` of which are ref-counted prefix-cache hits the slot reads
    but never writes; ``inserted_pages`` are the pages this slot published
    to the prefix cache after its own prefill.  ``phase`` is "prefill"
    while chunked prefill is still running (``prefill_pos`` = next prompt
    position to compute) and "decode" once the first token is sampled."""
    req: Request
    schedule: List[int]         # wanted per-step widths (len == max_new)
    emitted: List[int]          # committed tokens (first from prefill)
    decode_widths: List[int]    # realized width per committed decode step
    prefill_precision: int
    admit_step: int
    repeat_run: int = 0         # consecutive identical committed tokens
    phase: str = "decode"       # "prefill" | "decode"
    prefill_pos: int = 0        # next prompt position to prefill
    pages: List[int] = dataclasses.field(default_factory=list)
    n_reused: int = 0           # leading shared (read-only) pages
    inserted_pages: List[int] = dataclasses.field(default_factory=list)
    spec_draft_width: Optional[int] = None  # draft width (None = plain)
    spec_drafted: int = 0       # draft tokens proposed for this slot
    spec_accepted: int = 0      # draft tokens accepted by the verifier
    spec_rejected: int = 0      # draft tokens rejected (rolled back)

    @property
    def wanted(self) -> int:
        """Width this slot wants for its next decode step — the schedule
        entry of the token that step consumes (active slots always have
        1 <= len(emitted) < max_new, so the index is in range)."""
        return self.schedule[len(self.emitted) - 1]


class SlotTable:
    """Fixed-size slot occupancy map (host side)."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self._slots: List[Optional[SlotState]] = [None] * n_slots

    def __len__(self) -> int:
        return len(self._slots)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    def free_idx(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def admit(self, idx: int, state: SlotState) -> None:
        if self._slots[idx] is not None:
            raise ValueError(f"slot {idx} is occupied (rid="
                             f"{self._slots[idx].req.rid})")
        self._slots[idx] = state

    def get(self, idx: int) -> SlotState:
        s = self._slots[idx]
        if s is None:
            raise KeyError(f"slot {idx} is free")
        return s

    def retire(self, idx: int) -> SlotState:
        s = self.get(idx)
        self._slots[idx] = None
        return s

    def active(self) -> list:
        """[(idx, SlotState)] for occupied slots, in slot order."""
        return [(i, s) for i, s in enumerate(self._slots) if s is not None]


# ---------------------------------------------------------------------------
# device-side slot operations
# ---------------------------------------------------------------------------

def init_slot_cache(cfg: ModelConfig, n_slots: int, max_len: int,
                    dtype=jnp.bfloat16) -> Any:
    """The shared continuous-batching cache: per-slot ``pos: int32[B]``."""
    from repro.models import transformer as T
    return T.lm_init_cache(cfg, n_slots, max_len, dtype, per_slot=True)


def _is_pos(path) -> bool:
    last = path[-1]
    return getattr(last, "key", None) == "pos"


def write_slot(cache: Any, slot_cache: Any, idx) -> Any:
    """Install a batch-1 prefill cache (leaves ``[L, 1, ...]``, scalar
    ``pos``) into row ``idx`` of the shared cache.  ``idx`` is traced —
    one compiled write serves every slot."""
    def wr(path, c, s):
        if _is_pos(path):
            return c.at[idx].set(jnp.asarray(s, c.dtype))
        return lax.dynamic_update_slice_in_dim(c, s.astype(c.dtype), idx,
                                               axis=1)
    return jax.tree_util.tree_map_with_path(wr, cache, slot_cache)


def select_slots(mask, new_cache: Any, old_cache: Any) -> Any:
    """Commit the stepped cache only for rows where ``mask`` is True;
    stalled/free rows keep their previous state byte-for-byte (KV rows,
    recurrent states, positions)."""
    def sel(path, n, o):
        ax = 0 if _is_pos(path) else 1
        shape = [1] * n.ndim
        shape[ax] = mask.shape[0]
        return jnp.where(mask.reshape(shape), n, o)
    return jax.tree_util.tree_map_with_path(sel, new_cache, old_cache)


# ---------------------------------------------------------------------------
# paged cache operations (serve/pages.py owns the host-side accounting)
# ---------------------------------------------------------------------------

def _is_pages(path) -> bool:
    return any(getattr(k, "key", None) == "pages" for k in path)


def init_paged_slot_cache(cfg: ModelConfig, n_slots: int, n_pages: int,
                          page_size: int, dtype=jnp.bfloat16,
                          kv_dtype=None) -> Any:
    """The shared continuous-batching cache with attention KV paged; see
    transformer.lm_init_paged_cache for the per-family layout."""
    from repro.models import transformer as T
    return T.lm_init_paged_cache(cfg, n_slots, n_pages, page_size, dtype,
                                 kv_dtype=kv_dtype)


def select_paged(eff, new_cache: Any, old_cache: Any, block_table,
                 page_size: int) -> Any:
    """Page-granular commit of one decode step: a decode step writes
    exactly ONE (page, offset) cell per row — the cell addressed by the
    row's pre-step position through its block table — so restoring a
    non-committed row means restoring that single cell, not ``where``-ing
    the entire cache tree (the dense ``select_slots`` cost this replaces).
    Rows never collide: an active row's write page is exclusive by the
    sharing rule (only full, immutable pages are shared) and free rows all
    target null page 0, where every restore carries the identical old
    value.  Recurrent state and positions stay row-masked (they are dense
    per-slot and every row's step rewrites its whole row)."""
    pos = old_cache["pos"]
    pg = jnp.take_along_axis(block_table, (pos // page_size)[:, None],
                             axis=1)[:, 0]
    off = pos % page_size

    def sel(path, n, o):
        if _is_pages(path):
            keep = jnp.where(eff[None, :, None, None],
                             n[:, pg, off], o[:, pg, off])
            return n.at[:, pg, off].set(keep)
        ax = 0 if _is_pos(path) else 1
        shape = [1] * n.ndim
        shape[ax] = eff.shape[0]
        return jnp.where(eff.reshape(shape), n, o)

    return jax.tree_util.tree_map_with_path(sel, new_cache, old_cache)


def install_prefill_pages(cache: Any, slot_cache: Any, idx, block_row,
                          plen: int, page_size: int) -> Any:
    """Install a batch-1 WHOLE prefill into the paged cache: dense leaves
    (recurrent state, pos) row-write exactly like ``write_slot``; the
    attention KV (``slot_cache["attn"]``, hybrid's dense ``[n_inv, 1,
    max_len, KV, hd]``) is scattered through ``block_row`` into the
    slot's pages.  This is the recurrent families' admission path —
    Mamba2/RWKV6 state cannot be chunked or prefix-skipped, so they
    prefill whole and only their attention KV is paged.  ``plen`` is
    static (one executable per prompt length, as with any prefill)."""
    pos_arr = jnp.arange(plen, dtype=jnp.int32)
    pg = block_row[pos_arr // page_size]
    off = pos_arr % page_size

    def wr(path, c, s):
        if _is_pos(path):
            return c.at[idx].set(jnp.asarray(s, c.dtype))
        return lax.dynamic_update_slice_in_dim(c, s.astype(c.dtype), idx,
                                               axis=1)

    dense_new = {k: v for k, v in slot_cache.items() if k != "attn"}
    dense_old = {k: v for k, v in cache.items() if k != "pages"}
    new = jax.tree_util.tree_map_with_path(wr, dense_old, dense_new)
    new["pages"] = {
        "k": cache["pages"]["k"].at[:, pg, off].set(
            slot_cache["attn"]["k"][:, 0, :plen].astype(
                cache["pages"]["k"].dtype)),
        "v": cache["pages"]["v"].at[:, pg, off].set(
            slot_cache["attn"]["v"][:, 0, :plen].astype(
                cache["pages"]["v"].dtype)),
    }
    return new


def rollback_paged(cache: Any, block_table, keep, n_written,
                   page_size: int, s_max: int) -> Any:
    """Settle a speculative macro-step: advance each row's position by its
    accepted token count and zero the rejected-tail KV cells.

    ``keep`` int32[B] — tokens committed this macro-step (accepted drafts
    + the verifier's bonus token; 0 for rows that did not speculate);
    ``n_written`` int32[B] — cells the draft+verify pass wrote for the row
    (``k_eff + 1``; 0 for non-participants); ``s_max`` static — the
    compiled upper bound (``k_max + 1``).  Row b's cells ``pos[b] +
    [keep[b], n_written[b])`` are zeroed through its block table — a
    byte-exact restore, because decode-region cells are exclusive to the
    slot (only full immutable prompt pages are ever shared) and were zero
    before the draft wrote them (scrub-at-retirement discipline), so a
    rejected draft never leaks bytes into a later resident's gathered
    view.  Inactive (row, i) pairs are routed to null page 0, where
    writing zeros is always harmless.  ``pos`` moves to the next write
    cell: ``pos + keep``."""
    pos = cache["pos"]
    offs = jnp.arange(s_max, dtype=jnp.int32)[None, :]        # [1,S]
    cellpos = pos[:, None] + offs                             # [B,S]
    active = (offs >= keep[:, None]) & (offs < n_written[:, None])
    logical = jnp.minimum(cellpos // page_size,
                          block_table.shape[1] - 1)
    pg = jnp.where(active, jnp.take_along_axis(block_table, logical,
                                               axis=1), 0)
    off = jnp.where(active, cellpos % page_size, 0)

    def rb(path, c):
        if _is_pages(path):
            return c.at[:, pg, off].set(jnp.zeros((), c.dtype))
        return c

    new = jax.tree_util.tree_map_with_path(rb, cache)
    new["pos"] = pos + keep
    return new


def scrub_pages(cache: Any, page_idxs) -> Any:
    """Zero the given physical pages in every paged leaf — run on freed
    pages at retirement so recycled pages never leak a prior request's
    bytes (and, after a quarantine, never leak its NaNs) into a future
    resident's masked-but-gathered view.  ``page_idxs`` is padded with 0:
    scrubbing the null page is always harmless."""
    def sc(path, c):
        if not _is_pages(path):
            return c
        return c.at[:, page_idxs].set(jnp.zeros((), c.dtype))
    return jax.tree_util.tree_map_with_path(sc, cache)
