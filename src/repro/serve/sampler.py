"""Token samplers (greedy / temperature / top-k), jit- and scan-body-safe.

``temperature`` and ``top_k`` are STATIC python numbers, not traced values:
the branches below resolve at trace time, so the function can sit inside a
jitted ``lax.scan`` decode body (repro/serve/engine.py) without introducing
data-dependent control flow.  Callers that jit a wrapper must mark both as
static arguments (the engine does); passing a tracer here raises a
TracerBoolConversionError by design — sampling *strategy* is a compile-time
property of a generation, unlike the SEFP mantissa width, which is traced.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, key, temperature: float = 0.0,
                 top_k: int = 0) -> jax.Array:
    """logits: [B, V] -> token ids [B].  temperature <= 0 is greedy argmax
    (``key`` is ignored); top_k > 0 restricts sampling to the k largest
    logits per row."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        top_k = min(int(top_k), logits.shape[-1])
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        # finfo.min, not an ad-hoc -1e30 literal: exactly representable in
        # the logits dtype and still the identity for max/softmax masking.
        neg = jnp.finfo(logits.dtype).min
        logits = jnp.where(logits < cutoff, neg, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
