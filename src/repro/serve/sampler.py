"""Token samplers (greedy / temperature / top-k), jit- and scan-body-safe.

Two entry points:

``sample_token`` — the scalar fast path.  ``temperature`` and ``top_k`` are
STATIC python numbers, not traced values: the branches below resolve at
trace time, so the function can sit inside a jitted ``lax.scan`` decode body
(repro/serve/engine.py) without introducing data-dependent control flow.
Callers that jit a wrapper must mark both as static arguments (the engine
does); passing a tracer here raises a TracerBoolConversionError by design —
for a lockstep batch, sampling *strategy* is a compile-time property of a
generation, unlike the SEFP mantissa width, which is traced.

``sample_token_vec`` — the per-slot path for mixed continuous batches
(repro/serve/scheduler.py): every argument is TRACED, including per-row
``temperature: f32[B]`` and ``top_k: int32[B]`` and one PRNG key per row, so
ONE compiled step serves any mix of greedy/temperature/top-k requests and a
request joining or leaving a slot never retraces.  Per-row semantics match
``sample_token`` applied to that row alone with that row's key
(tests/test_scheduler.py property-tests the agreement): the traced top-k
cutoff is the same k-th largest value ``lax.top_k`` produces, the same
``finfo.min`` masking, and a row's categorical draw uses the row's own key
over a [V] logit vector — the identical threefry stream a [1, V] lockstep
call consumes.  The scalar path is untouched (bitwise-stable fast path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, key, temperature: float = 0.0,
                 top_k: int = 0) -> jax.Array:
    """logits: [B, V] -> token ids [B].  temperature <= 0 is greedy argmax
    (``key`` is ignored); top_k > 0 restricts sampling to the k largest
    logits per row."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        top_k = min(int(top_k), logits.shape[-1])
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        # finfo.min, not an ad-hoc -1e30 literal: exactly representable in
        # the logits dtype and still the identity for max/softmax masking.
        neg = jnp.finfo(logits.dtype).min
        logits = jnp.where(logits < cutoff, neg, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_token_vec(logits: jax.Array, keys, temperature: jax.Array,
                     top_k: jax.Array) -> jax.Array:
    """Per-slot sampling for mixed batches: logits [B, V], keys [B] PRNG
    keys (or [B, 2] uint32), temperature f32[B], top_k int32[B] -> ids [B].

    All parameters traced — one executable serves every request mix.  Rows
    with ``temperature <= 0`` are greedy argmax (their key is not consumed);
    rows with ``top_k > 0`` sample only among their k largest logits.  Each
    row's draw depends only on that row's (logits, key, temperature, top_k),
    so a request's token stream is independent of its batch neighbours."""
    B, V = logits.shape
    temperature = jnp.asarray(temperature, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    greedy = temperature <= 0.0
    scaled = logits / jnp.where(greedy, 1.0, temperature)[:, None]
    # traced per-row top-k: the k-th largest value via a descending sort
    # (same value lax.top_k's vals[:, -1] yields for a static k)
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth_idx = jnp.clip(top_k, 1, V) - 1
    cutoff = jnp.take_along_axis(desc, kth_idx[:, None], axis=-1)
    neg = jnp.finfo(scaled.dtype).min
    masked = jnp.where((top_k > 0)[:, None] & (scaled < cutoff), neg, scaled)
    sampled = jax.vmap(
        lambda k, row: jax.random.categorical(k, row, axis=-1))(keys, masked)
    out = jnp.where(greedy, jnp.argmax(logits, axis=-1), sampled)
    return out.astype(jnp.int32)
