"""Continuous-batching scheduler with precision-aware width selection.

The lockstep engine (repro/serve/engine.py) serves equal-length batches in
lockstep: one scalar position, no EOS exit, and a new request waits for the
whole batch.  This module turns the same compiled executables into a
continuous batcher: requests enter a FIFO queue, are admitted into free
slots of a shared per-slot cache (repro/serve/slots.py) via batch-1
prefill, decode together in ONE jitted step with per-slot positions,
sampling params and PRNG streams, and leave on EOS or ``max_new`` — their
slot is re-admitted on the very next step.

Precision is where this batcher differs from a vanilla one.  Each request
carries a class/width plan (PrecisionPolicy), and because SEFP precision
switching is O(1) — the step width is a *traced* int32 of the one compiled
step, switching moves zero bytes and repacks nothing — the scheduler can
choose a different weight width EVERY step with no cost.  Width selection
is therefore pure scheduling policy over the active slots' wanted widths:

  * ``max-width``  — every active slot commits every step; the step runs at
    the maximum wanted width (nobody is served below their requested
    fidelity; low-width requests ride along at higher quality).
  * ``width-rr``   — round-robin over width GROUPS with aging: each step
    serves exactly the slots whose wanted width is the chosen group's, at
    exactly that width; unserved groups accumulate wait, and the group
    with the largest wait wins next (ties broken by cyclic rotation), so
    no width class can starve.  Max observed waits are reported as the
    ``starvation`` stat.

Commitment discipline: the batched step computes all rows, but only the
scheduled ("committed") rows take effect — ``select_slots`` keeps stalled
and free rows' cache/position/PRNG state byte-for-byte, so a request's
token stream depends only on its own (prompt, seed, realized widths), never
on its batch neighbours.  That yields the oracle property the tests pin
down: a finished request replayed on the lockstep engine with its realized
schedule (``FinishedRequest.oracle_schedule``) reproduces the SAME tokens
bitwise, at every width.

Host/device split per decode step: one jitted dispatch and ONE host sync
(the committed tokens) — the continuous analogue of the per-token loop's
cadence; admission adds one batch-1 prefill per request (retraced per
distinct prompt length, as with any shape-bucketed server).
"""

from __future__ import annotations

import collections
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.policy import PrecisionPolicy
from repro.serve import slots as slots_lib
from repro.serve.sampler import sample_token, sample_token_vec
from repro.serve.slots import FinishedRequest, Request, SlotState, SlotTable


# ---------------------------------------------------------------------------
# width-selection policies
# ---------------------------------------------------------------------------

class WidthPolicy:
    """Selects (step width, committed slot set) from the active slots'
    wanted widths; stateful across steps (fairness accounting)."""

    name = "abstract"

    def select(self, wanted: Dict[int, int]) -> tuple:
        """wanted: {slot_idx: wanted_width} (non-empty).  Returns
        (m, committed_idxs)."""
        raise NotImplementedError

    @property
    def starvation(self) -> Dict[int, int]:
        """Max steps any width group waited while active (empty for
        policies that never stall a slot)."""
        return {}


class MaxWidthPolicy(WidthPolicy):
    """Serve everyone, every step, at the maximum wanted width — zero
    stalls; low-width requests are upgraded, never degraded."""

    name = "max-width"

    def select(self, wanted: Dict[int, int]) -> tuple:
        return max(wanted.values()), set(wanted)


class WidthRoundRobinPolicy(WidthPolicy):
    """Width-group round-robin with aging.  Each step serves exactly one
    width group AT its wanted width (classes get their requested
    precision, unlike max-width's upgrade).  Fairness: every unserved
    group's wait counter grows each step and the largest wait wins, so a
    group waits at most (#groups - 1) consecutive steps under a steady
    mix; ties rotate cyclically through the width order.  ``starvation``
    reports the largest wait each width ever accumulated."""

    name = "width-rr"

    def __init__(self):
        self._wait: Dict[int, int] = {}
        self._starvation: Dict[int, int] = {}
        self._last: Optional[int] = None

    def _rotation_key(self, w: int, present: list) -> int:
        """Cyclic preference after the last served width (next width in
        sorted order first; repeating the same group is least preferred)."""
        if self._last is None or self._last not in present:
            return w  # first step: prefer higher widths
        n = len(present)
        d = (present.index(w) - present.index(self._last)) % n
        return n - d if d else 0

    def select(self, wanted: Dict[int, int]) -> tuple:
        present = sorted(set(wanted.values()))
        # drop groups that emptied out (their requests finished)
        self._wait = {w: c for w, c in self._wait.items() if w in present}
        for w in present:
            self._wait.setdefault(w, 0)
        pick = max(present,
                   key=lambda w: (self._wait[w],
                                  self._rotation_key(w, present)))
        for w in present:
            if w == pick:
                self._wait[w] = 0
            else:
                self._wait[w] += 1
                self._starvation[w] = max(self._starvation.get(w, 0),
                                          self._wait[w])
        self._last = pick
        return pick, {i for i, w in wanted.items() if w == pick}

    @property
    def starvation(self) -> Dict[int, int]:
        return dict(self._starvation)


WIDTH_POLICIES = {
    MaxWidthPolicy.name: MaxWidthPolicy,
    WidthRoundRobinPolicy.name: WidthRoundRobinPolicy,
}


def make_width_policy(spec) -> WidthPolicy:
    if isinstance(spec, WidthPolicy):
        return spec
    try:
        return WIDTH_POLICIES[spec]()
    except KeyError:
        raise ValueError(f"unknown width policy {spec!r}; registered: "
                         f"{sorted(WIDTH_POLICIES)}") from None


# ---------------------------------------------------------------------------
# the jitted continuous decode step
# ---------------------------------------------------------------------------

def _make_continuous_step(serve_step):
    """One continuous decode step: batched serve at traced width m, per-slot
    sampling, masked commit.  Non-committed rows (stalled width groups,
    free slots) keep token/cache/PRNG state unchanged, so their streams are
    exactly as if the step never ran for them.

    ``commit_all`` (static, two compiled variants) is the no-stall fast
    path: when every ACTIVE slot commits — always under max-width, and
    under width-rr whenever a single width group is active — the cache
    select is skipped entirely.  Free slots then do take the step's
    garbage writes, which is safe by the admission contract: ``write_slot``
    overwrites a row's every leaf (KV, recurrent state, pos) before the
    slot is used again, and row independence keeps garbage rows from
    perturbing active ones (token/PRNG state is still mask-gated)."""

    def step(master, cache, toks, m, keys, temps, topks, mask, commit_all):
        logits, new_cache = serve_step(master, cache, toks, m)
        if not commit_all:
            new_cache = slots_lib.select_slots(mask, new_cache, cache)
        pair = jax.vmap(jax.random.split)(keys)        # [B, 2, 2]
        new_keys, subs = pair[:, 0], pair[:, 1]
        new_keys = jnp.where(mask[:, None], new_keys, keys)
        nxt = sample_token_vec(logits, subs, temps, topks)
        nxt = jnp.where(mask, nxt, toks)
        return nxt, new_cache, new_keys

    return jax.jit(step, static_argnames=("commit_all",))


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

class ContinuousScheduler:
    """Continuous batcher over a SwitchableServer (use
    ``server.continuous(...)`` or ``Artifact.server(...).continuous(...)``).

    ``submit()`` enqueues a request and returns its rid; ``step()`` runs
    one scheduler step (admissions + one batched decode at the selected
    width), returning False once queue and slots are empty; ``drain()``
    steps to completion and returns {rid: FinishedRequest}.  Streaming:
    per-request ``stream(rid, token, done)`` callbacks and/or a
    scheduler-wide ``on_token``.  Time is counted in decode steps
    (``clock``); latency accounting lives on each FinishedRequest.
    """

    def __init__(self, server, slots: int = 8, width_policy="max-width",
                 policy: Optional[PrecisionPolicy] = None,
                 eos_id: Optional[int] = None,
                 on_token: Optional[Callable[[int, int, bool], None]] = None):
        self._srv = server
        self.cfg = server.cfg
        self.n_slots = int(slots)
        self.max_len = server.max_len
        self._policy = (policy if policy is not None
                        else (server.policy
                              or PrecisionPolicy.all_widths(
                                  default=server.precision)))
        self._width_policy = make_width_policy(width_policy)
        self.default_eos_id = eos_id
        self.on_token = on_token

        self._table = SlotTable(self.n_slots)
        self._queue: collections.deque = collections.deque()
        self._finished: Dict[int, FinishedRequest] = {}
        self._next_rid = 0
        self.clock = 0  # decode-step clock

        # device-side per-slot state
        self._cache = slots_lib.init_slot_cache(
            self.cfg, self.n_slots, self.max_len, server.cache_dtype)
        self._tok = jnp.zeros((self.n_slots,), jnp.int32)
        self._keys = jnp.zeros((self.n_slots, 2), jnp.uint32)
        self._temps = np.zeros((self.n_slots,), np.float32)
        self._topks = np.zeros((self.n_slots,), np.int32)
        # the jitted step/write executables are cached ON the server, so
        # constructing a fresh scheduler over the same server (new workload,
        # different width policy) reuses the compiled code — scheduler state
        # is host data, the executables are shape-keyed only.
        if not hasattr(server, "_continuous_step_fn"):
            server._continuous_step_fn = _make_continuous_step(server._serve)
            server._write_slot_fn = jax.jit(slots_lib.write_slot)
        self._step_fn = server._continuous_step_fn
        self._write_slot = server._write_slot_fn

        self._counts = {"steps": 0, "committed_tokens": 0,
                        "slot_steps_active": 0, "slot_steps_committed": 0,
                        "admitted": 0, "finished": 0,
                        "width_steps": collections.Counter()}

    # -- queueing -----------------------------------------------------------
    def submit(self, prompt, max_new: int,
               request_class: Optional[str] = None,
               temperature: float = 0.0, top_k: int = 0,
               eos_id: Optional[int] = None, seed: int = 0,
               stream: Optional[Callable[[int, int, bool], None]] = None
               ) -> int:
        """Enqueue a request; returns its rid.  Validates length and class
        routing here (fail fast), admission happens inside ``step()``."""
        prompt = np.ascontiguousarray(np.asarray(prompt, np.int32).ravel())
        max_new = int(max_new)
        if max_new < 0:
            raise ValueError(f"max_new must be >= 0, got {max_new}")
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt_len {prompt.size} + max_new {max_new} exceeds the "
                f"server max_len {self.max_len}")
        # resolves class > plan > default; unknown classes raise KeyError
        schedule = self._policy.request_schedule(max_new, request_class)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      request_class=request_class,
                      temperature=float(temperature), top_k=int(top_k),
                      eos_id=(self.default_eos_id if eos_id is None
                              else int(eos_id)),
                      seed=int(seed), stream=stream,
                      submit_step=self.clock)
        self._queue.append((req, schedule))
        return rid

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        return self._table.n_active

    # -- admission ----------------------------------------------------------
    def _admit_one(self, req: Request, schedule, idx: int) -> None:
        pm = schedule[0]
        logits, slot_cache = self._srv._prefill(
            self._srv.master, jnp.asarray(req.prompt[None, :]),
            jnp.int32(pm), max_len=self.max_len)
        k0 = jax.random.PRNGKey(req.seed)
        tok0 = int(sample_token(logits, k0, req.temperature, req.top_k)[0])
        self._cache = self._write_slot(self._cache, slot_cache,
                                       jnp.int32(idx))
        self._tok = self._tok.at[idx].set(tok0)
        self._keys = self._keys.at[idx].set(k0)
        self._temps[idx] = req.temperature
        self._topks[idx] = req.top_k
        state = SlotState(req=req, schedule=schedule, emitted=[tok0],
                          decode_widths=[], prefill_precision=pm,
                          admit_step=self.clock)
        self._table.admit(idx, state)
        self._counts["admitted"] += 1
        done = (tok0 == req.eos_id if req.eos_id is not None
                else False) or req.max_new <= 1
        self._emit(req, tok0, done)
        if done:
            self._retire(idx, "eos" if (req.eos_id is not None
                                        and tok0 == req.eos_id)
                         else "length")

    def _admit(self) -> None:
        while self._queue:
            req, schedule = self._queue[0]
            if req.max_new == 0:
                # prefill-only: nothing to decode, no slot needed — finish
                # at the queue head without waiting for (or blocking on) a
                # free slot.  No prefill actually runs; the recorded width
                # is the one the request's class would have prefilled at.
                self._queue.popleft()
                self._finished[req.rid] = FinishedRequest(
                    rid=req.rid, tokens=np.zeros((0,), np.int32),
                    prompt_len=req.prompt.size, finish_reason="length",
                    prefill_precision=self._policy.request_schedule(
                        1, req.request_class)[0],
                    decode_widths=[], request_class=req.request_class,
                    submit_step=req.submit_step, admit_step=self.clock,
                    finish_step=self.clock)
                self._counts["admitted"] += 1
                self._counts["finished"] += 1
                continue
            idx = self._table.free_idx()
            if idx is None:
                return
            self._queue.popleft()
            self._admit_one(req, schedule, idx)

    # -- stepping -----------------------------------------------------------
    def step(self) -> bool:
        """One scheduler step: admit from the queue, pick the step width
        from the active slots' wanted widths, run one batched decode,
        commit the scheduled rows, retire finished requests.  Returns
        False when there is nothing left to do."""
        self._admit()
        wanted = {idx: s.wanted for idx, s in self._table.active()}
        if not wanted:
            return False
        m, commit = self._width_policy.select(wanted)
        mask = np.zeros((self.n_slots,), bool)
        mask[sorted(commit)] = True
        nxt, cache, keys = self._step_fn(
            self._srv.master, self._cache, self._tok, jnp.int32(m),
            self._keys, jnp.asarray(self._temps), jnp.asarray(self._topks),
            jnp.asarray(mask), commit_all=len(commit) == len(wanted))
        self._cache, self._keys, self._tok = cache, keys, nxt
        toks = np.asarray(nxt)  # ONE host sync per continuous step
        self.clock += 1
        self._counts["steps"] += 1
        self._counts["slot_steps_active"] += len(wanted)
        self._counts["slot_steps_committed"] += len(commit)
        self._counts["committed_tokens"] += len(commit)
        self._counts["width_steps"][int(m)] += 1
        for idx in sorted(commit):
            slot = self._table.get(idx)
            t = int(toks[idx])
            slot.decode_widths.append(int(m))
            slot.emitted.append(t)
            eos = slot.req.eos_id
            hit_eos = eos is not None and t == eos
            done = hit_eos or len(slot.emitted) >= slot.req.max_new
            self._emit(slot.req, t, done)
            if done:
                self._retire(idx, "eos" if hit_eos else "length")
        return True

    def drain(self) -> Dict[int, FinishedRequest]:
        """Step until queue and slots are empty; returns (and clears) every
        request finished since the last drain, keyed by rid."""
        while self.step():
            pass
        out, self._finished = self._finished, {}
        return out

    def replay(self, requests) -> Dict[int, FinishedRequest]:
        """Drive the scheduler over an arrival-ordered workload and drain:
        each request is a dict of ``submit()`` kwargs plus an optional
        ``arrival`` (step-clock tick at which it becomes visible).  Idle
        gaps before the next arrival tick the clock once, so latency stats
        count real waiting.  This is THE replay loop — the serve CLI's
        JSONL mode and benchmarks/bench_serving.py both run through it, so
        the clock/idle semantics (which define the latency metrics) cannot
        diverge between them.  Returns ``drain()``'s {rid: FinishedRequest}."""
        reqs = sorted(requests, key=lambda r: int(r.get("arrival", 0)))
        i = 0
        while i < len(reqs) or self.pending or self.active:
            while (i < len(reqs)
                   and int(reqs[i].get("arrival", 0)) <= self.clock):
                kw = {k: v for k, v in reqs[i].items() if k != "arrival"}
                self.submit(**kw)
                i += 1
            if not self.step() and i < len(reqs):
                self.clock += 1  # idle gap before the next arrival
        return self.drain()

    # -- internals ----------------------------------------------------------
    def _emit(self, req: Request, token: int, done: bool) -> None:
        if req.stream is not None:
            req.stream(req.rid, token, done)
        if self.on_token is not None:
            self.on_token(req.rid, token, done)

    def _retire(self, idx: int, reason: str) -> None:
        slot = self._table.retire(idx)
        self._temps[idx] = 0.0
        self._topks[idx] = 0
        self._counts["finished"] += 1
        self._finished[slot.req.rid] = FinishedRequest(
            rid=slot.req.rid,
            tokens=np.asarray(slot.emitted, np.int32),
            prompt_len=slot.req.prompt.size,
            finish_reason=reason,
            prefill_precision=slot.prefill_precision,
            decode_widths=list(slot.decode_widths),
            request_class=slot.req.request_class,
            submit_step=slot.req.submit_step,
            admit_step=slot.admit_step,
            finish_step=self.clock)

    # -- accounting ---------------------------------------------------------
    @property
    def stats(self) -> dict:
        c = self._counts
        steps = max(c["steps"], 1)
        return {
            "steps": c["steps"],
            "committed_tokens": c["committed_tokens"],
            "admitted": c["admitted"],
            "finished": c["finished"],
            "pending": self.pending,
            "active": self.active,
            # mean fraction of slots occupied / committed per step
            "occupancy": c["slot_steps_active"] / (steps * self.n_slots),
            "commit_rate": (c["slot_steps_committed"]
                            / max(c["slot_steps_active"], 1)),
            "width_steps": dict(c["width_steps"]),
            "starvation": self._width_policy.starvation,
            "width_policy": self._width_policy.name,
        }
