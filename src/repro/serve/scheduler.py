"""Continuous-batching scheduler with precision-aware width selection and
an overload/failure resilience layer (DESIGN.md §11–§12).

The lockstep engine (repro/serve/engine.py) serves equal-length batches in
lockstep: one scalar position, no EOS exit, and a new request waits for the
whole batch.  This module turns the same packed master into a continuous
batcher: requests enter a FIFO queue, are admitted into free slots, decode
together in ONE jitted step with per-slot positions, sampling params and
PRNG streams, and leave on EOS or ``max_new`` — their slot is re-admitted
on the very next step.

The attention KV cache is PAGED (repro/serve/pages.py, DESIGN.md §13):
slots share a pool of fixed-size pages addressed through per-slot block
tables, so admission is gated on the *page* budget a request actually
needs (prompt + max_new positions), not on a dense ``max_len`` row.
Three scheduler behaviours ride on the paging:

  * **chunked prefill** — with ``prefill_chunk`` set, a long prompt is
    prefilled ``prefill_chunk`` tokens at a time, one chunk per scheduler
    step, *in the same step as* the batched decode — the decode clock
    never stalls behind a long document (``decode_stall_steps`` stays 0
    by construction).  A prefilling slot's block-table row is installed
    into the decode step's table only when its first token is sampled,
    so its pages are invisible to (and untouchable by) the decode step
    until the prefill commits.
  * **prefix reuse** — prompt prefixes are hashed page-aligned (chained,
    keyed on the prefill width: K/V bytes differ per SEFP width) and full
    prompt pages are published to a ref-counted PrefixCache; a later
    request whose prompt shares the prefix adopts the hit pages and skips
    their prefill compute entirely.  Shared pages are read-only by
    construction — only FULL immutable pages are published, the partial
    tail and all decode pages are freshly allocated per slot (copy-on-
    write without copying); the last prompt token is always prefilled in
    an exclusive page so first-token logits never depend on the cache.
  * **page-granular commit** — the decode step's masked commit restores
    only the one (page, offset) cell each non-committed row wrote
    (``select_paged``), keeping the quarantine/stall discipline of the
    dense batcher at page granularity.

Mamba2/RWKV6 recurrent state is O(1) per slot and position-free — it
stays dense per-slot; paging applies to attention KV only (the rwkv
family runs the uniform paged step signature with an ignored block
table; hybrid's attention KV is paged via a whole-prompt prefill
installed into pages, without chunking/reuse).

Precision is where this batcher differs from a vanilla one.  Each request
carries a class/width plan (PrecisionPolicy), and because SEFP precision
switching is O(1) — the step width is a *traced* int32 of the one compiled
step, switching moves zero bytes and repacks nothing — the scheduler can
choose a different weight width EVERY step with no cost.  Width selection
is therefore pure scheduling policy over the active slots' wanted widths:

  * ``max-width``   — every active slot commits every step; the step runs
    at the maximum wanted width (nobody is served below their requested
    fidelity; low-width requests ride along at higher quality).
  * ``width-rr``    — round-robin over width GROUPS with aging: each step
    serves exactly the slots whose wanted width is the chosen group's, at
    exactly that width; unserved groups accumulate wait, and the group
    with the largest wait wins next (ties broken by cyclic rotation), so
    no width class can starve.  Max observed waits are reported as the
    ``starvation`` stat.
  * ``slo-degrade`` — graceful degradation (§12): behaves as width-rr
    while healthy; under pressure (queue depth, full slots, step-latency
    EWMA over an SLO budget) it abandons per-class fidelity and steps the
    WHOLE batch every step at a downshifted width (8→6→4…), upshifting
    hysteretically when pressure relents.  Per-request ``min_width``
    floors (resolved through the PrecisionPolicy) are never crossed — a
    floored request keeps the step width at or above its floor.
  * ``heterogeneous`` — per-row widths in ONE step (§14): the scheduler
    builds an int32[n_slots] width vector from the wanted dict and runs
    the fused per-row-width decode step
    (packed_step.make_master_serve_step_hetero_paged), so EVERY active
    slot commits EVERY step at its own width — commit rate 1.0 and zero
    starvation by construction, each row bitwise its lockstep run.
    Composes with slo-degrade by clamping the vector per slot.

Resilience (§12) on top of the width policies:

  * **admission control** — a bounded queue (``max_queue``) with explicit
    backpressure: ``submit`` raises ``QueueFull`` carrying a retry-after
    hint, ``try_submit`` returns an ``Admission`` verdict instead of
    raising; per-request deadlines and a queue TTL evict requests that can
    no longer be served in time (terminal statuses ``evicted`` /
    ``deadline``), so an overloaded scheduler sheds load instead of
    growing an unbounded backlog.
  * **per-slot quarantine** — the jitted step computes a traced per-slot
    health mask (``isfinite`` over each row's logits); an unhealthy row is
    NOT committed (its cache/token/PRNG state stays at the last healthy
    step, exactly as if the step never ran for it) and the host retires
    only that slot with status ``poisoned``.  Row independence of the
    batched step means co-resident slots' streams are bitwise unaffected.
    A host-side repetition guard (``repetition_limit``) additionally
    retires slots emitting the same token unboundedly.
  * **fault injection** — deterministic injectors (repro/serve/faults.py)
    plug in via ``faults=[...]``/``inject()``: NaN logits on slot k at
    step t (a traced poison mask, zero-cost when clean), slot-cache bit
    corruption, artificial step stalls, arrival floods.  Tests and
    ``benchmarks/bench_serving.py --faults`` drive them.

Commitment discipline: the batched step computes all rows, but only the
scheduled-AND-healthy ("committed") rows take effect — ``select_slots``
keeps stalled, free and quarantined rows' cache/position/PRNG state
byte-for-byte, so a request's token stream depends only on its own
(prompt, seed, realized widths), never on its batch neighbours.  That
yields the oracle property the tests pin down: a finished request replayed
on the lockstep engine with its realized schedule
(``FinishedRequest.oracle_schedule``) reproduces the SAME tokens bitwise,
at every width — including degraded and partially-poisoned requests.

Host/device split per decode step: one jitted dispatch and ONE host
round-trip (the committed tokens + the per-slot health mask) — the
continuous analogue of the per-token loop's cadence; admission adds one
batch-1 prefill per request (retraced per distinct prompt length, as with
any shape-bucketed server).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.packed import MASTER_M
from repro.policy import PrecisionPolicy
from repro.serve import errors as errors_lib
from repro.serve import packed_step as packed_step_lib
from repro.serve import pages as pages_lib
from repro.serve import slots as slots_lib
from repro.serve import speculative as spec_lib
from repro.serve import telemetry as telemetry_lib
from repro.serve.errors import BadDeadline, QueueFull, UnknownRequestClass
from repro.serve.pages import PageAllocator, PrefixCache
from repro.serve.sampler import sample_token, sample_token_vec
from repro.serve.slots import FinishedRequest, Request, SlotState, SlotTable

KV_DTYPES = {"bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
             "int8": jnp.float8_e4m3fn, "f8": jnp.float8_e4m3fn,
             "kv8": jnp.float8_e4m3fn, "float8_e4m3fn": jnp.float8_e4m3fn}


def resolve_kv_dtype(kv_dtype, default):
    """Page storage dtype: None -> the server's cache dtype; strings name
    the supported storage formats ("int8"/"f8"/"kv8" all select the f8
    E4M3 byte format — the int8-class KV cache, DESIGN.md §10)."""
    if kv_dtype is None:
        return default
    if isinstance(kv_dtype, str):
        try:
            return KV_DTYPES[kv_dtype.lower()]
        except KeyError:
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}; named "
                             f"formats: {sorted(KV_DTYPES)}") from None
    return jnp.dtype(kv_dtype).type


# ---------------------------------------------------------------------------
# width-selection policies
# ---------------------------------------------------------------------------

class WidthPolicy:
    """Selects (step width, committed slot set) from the active slots'
    wanted widths; stateful across steps (fairness accounting)."""

    name = "abstract"

    def select(self, wanted: Dict[int, int]) -> tuple:
        """wanted: {slot_idx: wanted_width} (non-empty).  Returns
        (m, committed_idxs)."""
        raise NotImplementedError

    def observe(self, signals: dict) -> None:
        """Pressure telemetry, delivered by the scheduler once per step
        BEFORE ``select``: ``clock``, ``queue_depth``, ``active``,
        ``slots``, ``step_seconds`` (previous step's wall time, None on
        the first step), ``floors`` ({slot_idx: min_width}) and ``widths``
        (the policy ladder).  Stateless policies ignore it."""

    @property
    def starvation(self) -> Dict[int, int]:
        """Max steps any width group waited while active (empty for
        policies that never stall a slot)."""
        return {}

    @property
    def degradation(self) -> dict:
        """Degradation accounting (slo-degrade only; empty elsewhere)."""
        return {}


class MaxWidthPolicy(WidthPolicy):
    """Serve everyone, every step, at the maximum wanted width — zero
    stalls; low-width requests are upgraded, never degraded."""

    name = "max-width"

    def select(self, wanted: Dict[int, int]) -> tuple:
        return max(wanted.values()), set(wanted)


class WidthRoundRobinPolicy(WidthPolicy):
    """Width-group round-robin with aging.  Each step serves exactly one
    width group AT its wanted width (classes get their requested
    precision, unlike max-width's upgrade).  Fairness: every unserved
    group's wait counter grows each step and the largest wait wins, so a
    group waits at most (#groups - 1) consecutive steps under a steady
    mix; ties rotate cyclically through the width order.

    Two starvation views with deliberately different lifetimes:

      * ``current_waits`` — the LIVE consecutive-steps-unserved streak per
        active width group.  Serving a group resets its streak to 0, and a
        group that drains (all its requests finished) is dropped; if the
        width reappears later its streak restarts at 0 — a streak never
        carries across a drain.
      * ``starvation`` — the lifetime HIGH-WATER of those streaks: the
        largest wait each width ever accumulated.  It is intentionally
        never reset — not when the group is served, not when it drains —
        because it is the bound the fairness claim is audited against
        ("no group ever waited more than N consecutive steps").  A width
        group that drained mid-wait keeps its high-water entry."""

    name = "width-rr"

    def __init__(self):
        self._wait: Dict[int, int] = {}
        self._starvation: Dict[int, int] = {}
        self._last: Optional[int] = None

    def _rotation_key(self, w: int, present: list) -> int:
        """Cyclic preference after the last served width (next width in
        sorted order first; repeating the same group is least preferred)."""
        if self._last is None or self._last not in present:
            return w  # first step: prefer higher widths
        n = len(present)
        d = (present.index(w) - present.index(self._last)) % n
        return n - d if d else 0

    def select(self, wanted: Dict[int, int]) -> tuple:
        present = sorted(set(wanted.values()))
        # drop groups that emptied out (their requests finished)
        self._wait = {w: c for w, c in self._wait.items() if w in present}
        for w in present:
            self._wait.setdefault(w, 0)
        pick = max(present,
                   key=lambda w: (self._wait[w],
                                  self._rotation_key(w, present)))
        for w in present:
            if w == pick:
                self._wait[w] = 0
            else:
                self._wait[w] += 1
                self._starvation[w] = max(self._starvation.get(w, 0),
                                          self._wait[w])
        self._last = pick
        return pick, {i for i, w in wanted.items() if w == pick}

    @property
    def starvation(self) -> Dict[int, int]:
        return dict(self._starvation)

    @property
    def current_waits(self) -> Dict[int, int]:
        return dict(self._wait)


class SLODegradePolicy(WidthPolicy):
    """SLO-aware graceful degradation (DESIGN.md §12).

    A small hysteretic state machine over a degradation level ``shift``:

      * ``shift == 0`` (healthy): exact width-rr fidelity — every class is
        served AT its wanted width, groups rotate with aging.
      * ``shift == k > 0`` (degraded): per-class fidelity is abandoned;
        every active slot commits EVERY step at the single width
        ``max_i max(floor_i, down(wanted_i, k))`` where ``down`` steps k
        positions lower on the policy's width ladder.  Committing the
        whole batch removes the width-rr rotation tax (one step per token
        for everyone) and the downshifted width cuts the bytes a real
        accelerator streams per step ((m+1.125)/16 of bf16 — DESIGN.md
        §7); per-request ``min_width`` floors are never crossed, because
        the step width is the max over the floored effective widths.

    Escalation (one level per observation) triggers on any of: queue depth
    at/above ``queue_high``; all slots busy with a backlog; step-latency
    EWMA above ``slo_step_seconds``.  De-escalation is hysteretic: only
    after ``hold_steps`` consecutive calm observations (queue at/below
    ``queue_low`` and EWMA back under ``upshift_ratio * slo``), one level
    at a time — so the policy does not oscillate at the SLO boundary.

    All pressure signals arrive via ``observe``; ``select`` stays a pure
    function of (wanted, current level), so this remains *scheduling* over
    the traced SEFP width — no recompile, no repack, per-step switching.
    """

    name = "slo-degrade"

    def __init__(self, slo_step_seconds: Optional[float] = None,
                 queue_high: int = 4, queue_low: int = 0,
                 ewma_alpha: float = 0.25, hold_steps: int = 6,
                 upshift_ratio: float = 0.7,
                 max_shift: Optional[int] = None,
                 trace_len: int = 4096):
        if queue_low > queue_high:
            raise ValueError(f"queue_low {queue_low} > queue_high "
                             f"{queue_high}")
        if trace_len < 1:
            raise ValueError(f"trace_len must be >= 1, got {trace_len}")
        self.slo_step_seconds = slo_step_seconds
        self.queue_high = int(queue_high)
        self.queue_low = int(queue_low)
        self.ewma_alpha = float(ewma_alpha)
        self.hold_steps = int(hold_steps)
        self.upshift_ratio = float(upshift_ratio)
        self._max_shift = max_shift
        self._rr = WidthRoundRobinPolicy()
        self._ladder: Tuple[int, ...] = ()
        self._floors: Dict[int, int] = {}
        self._shift = 0
        self._relief = 0
        self._clock = 0
        self._ewma: Optional[float] = None
        self._escalations = 0
        self._downshifted_slot_steps = 0
        self._degraded_steps = 0
        # bounded ring of (clock, new shift) transitions: a long-running
        # server's shift history must not grow without bound, so overflow
        # drops the OLDEST transitions; max_shift_seen stays exact via the
        # running max below, which never forgets
        self._trace: collections.deque = collections.deque(
            maxlen=int(trace_len))
        self._max_shift_seen = 0
        self.last_shift_cause: Optional[str] = None

    # -- pressure state machine --------------------------------------------
    def observe(self, signals: dict) -> None:
        self._clock = int(signals.get("clock", self._clock))
        self._floors = dict(signals.get("floors") or {})
        widths = signals.get("widths")
        if widths:
            self._ladder = tuple(sorted(widths, reverse=True))
        dt = signals.get("step_seconds")
        if dt is not None:
            self._ewma = (dt if self._ewma is None else
                          self.ewma_alpha * dt
                          + (1.0 - self.ewma_alpha) * self._ewma)
        qd = int(signals.get("queue_depth", 0))
        full = (signals.get("active", 0) >= signals.get("slots", 1))
        lat_breach = (self.slo_step_seconds is not None
                      and self._ewma is not None
                      and self._ewma > self.slo_step_seconds)
        breach = (qd >= self.queue_high
                  or (full and qd > max(self.queue_low, 0))
                  or lat_breach)
        if breach:
            self._relief = 0
            if self._shift < self._shift_cap():
                self._shift += 1
                self._escalations += 1
                self._max_shift_seen = max(self._max_shift_seen,
                                           self._shift)
                self.last_shift_cause = (
                    "queue_depth" if qd >= self.queue_high
                    else "slots_full_backlog"
                    if (full and qd > max(self.queue_low, 0))
                    else "latency_ewma")
                self._trace.append((self._clock, self._shift))
            return
        lat_calm = (self.slo_step_seconds is None or self._ewma is None
                    or self._ewma <= self.upshift_ratio
                    * self.slo_step_seconds)
        if qd <= self.queue_low and not full and lat_calm:
            self._relief += 1
            if self._relief >= self.hold_steps and self._shift > 0:
                self._shift -= 1
                self._relief = 0
                self.last_shift_cause = "relief"
                self._trace.append((self._clock, self._shift))
        else:
            self._relief = 0

    def _shift_cap(self) -> int:
        if self._max_shift is not None:
            return self._max_shift
        return max(len(self._ladder) - 1, 1)

    def _down(self, w: int, k: int) -> int:
        """k positions lower on the ladder, from the first rung <= w."""
        ladder = self._ladder or (w,)
        i = next((j for j, r in enumerate(ladder) if r <= w),
                 len(ladder) - 1)
        return ladder[min(i + k, len(ladder) - 1)]

    # -- selection ----------------------------------------------------------
    def select(self, wanted: Dict[int, int]) -> tuple:
        if self._shift == 0:
            return self._rr.select(wanted)
        lowest = self._ladder[-1] if self._ladder else min(wanted.values())
        m = max(max(self._floors.get(i, lowest),
                    self._down(w, self._shift))
                for i, w in wanted.items())
        self._degraded_steps += 1
        self._downshifted_slot_steps += sum(
            1 for w in wanted.values() if m < w)
        return m, set(wanted)

    # -- reporting ----------------------------------------------------------
    @property
    def shift(self) -> int:
        return self._shift

    @property
    def starvation(self) -> Dict[int, int]:
        return self._rr.starvation

    @property
    def degradation(self) -> dict:
        return {
            "shift": self._shift,
            "max_shift_seen": self._max_shift_seen,
            "escalations": self._escalations,
            "degraded_steps": self._degraded_steps,
            "downshifted_slot_steps": self._downshifted_slot_steps,
            "latency_ewma_seconds": self._ewma,
            "trace": list(self._trace),
        }


class HeterogeneousPolicy(WidthPolicy):
    """Width-heterogeneous serving: EVERY active slot commits EVERY step
    at its own wanted width, in one fused decode (the per-row-width step,
    repro/serve/packed_step.py make_master_serve_step_hetero_paged).

    This dissolves the max-width/width-rr tradeoff structurally:

      * commit rate is 1.0 BY CONSTRUCTION — ``select`` returns the whole
        wanted set, so no slot ever stalls for a width turn;
      * starvation is structurally zero — there is no width rotation to
        wait on, so ``starvation`` is always empty;
      * per-class fidelity is exact — slot i decodes at ``wanted[i]``,
        bitwise its lockstep run at that width (tests/test_hetero.py),
        never upgraded (max-width) or turn-taken (width-rr).

    ``select`` returns a PER-SLOT width dict ``{slot_idx: width}`` as the
    ``m`` element instead of one scalar — the scheduler detects the
    ``heterogeneous`` flag and builds the int32[n_slots] width vector the
    fused step consumes.

    SLO composition: pass ``degrade=SLODegradePolicy(...)`` and its
    pressure state machine (escalation/hysteresis, DESIGN.md §12) runs
    unchanged — but instead of forcing one batch-wide width, a breach
    CLAMPS the vector per slot to ``max(floor_i, down(wanted_i, shift))``:
    everyone still commits every step, the degraded widths just shed
    bytes.  Per-request ``min_width`` floors are enforced per slot (not
    via a batch max), so one high-floor request no longer pins the whole
    batch's degraded width."""

    name = "heterogeneous"
    heterogeneous = True

    def __init__(self, degrade: Optional[SLODegradePolicy] = None):
        self._slo = degrade
        self._floors: Dict[int, int] = {}

    def observe(self, signals: dict) -> None:
        self._floors = dict(signals.get("floors") or {})
        if self._slo is not None:
            self._slo.observe(signals)

    def select(self, wanted: Dict[int, int]) -> tuple:
        if self._slo is None or self._slo.shift == 0:
            return dict(wanted), set(wanted)
        k = self._slo.shift
        out = {i: max(self._floors.get(i) or 0, self._slo._down(w, k))
               for i, w in wanted.items()}
        self._slo._degraded_steps += 1
        self._slo._downshifted_slot_steps += sum(
            1 for i, w in wanted.items() if out[i] < w)
        return out, set(wanted)

    @property
    def shift(self) -> int:
        return 0 if self._slo is None else self._slo.shift

    @property
    def last_shift_cause(self) -> Optional[str]:
        return None if self._slo is None else self._slo.last_shift_cause

    @property
    def degradation(self) -> dict:
        return {} if self._slo is None else self._slo.degradation


WIDTH_POLICIES = {
    MaxWidthPolicy.name: MaxWidthPolicy,
    WidthRoundRobinPolicy.name: WidthRoundRobinPolicy,
    SLODegradePolicy.name: SLODegradePolicy,
    HeterogeneousPolicy.name: HeterogeneousPolicy,
}


def make_width_policy(spec) -> WidthPolicy:
    if isinstance(spec, WidthPolicy):
        return spec
    try:
        return WIDTH_POLICIES[spec]()
    except KeyError:
        raise ValueError(f"unknown width policy {spec!r}; registered: "
                         f"{sorted(WIDTH_POLICIES)}") from None


# ---------------------------------------------------------------------------
# the jitted continuous decode step
# ---------------------------------------------------------------------------

def _make_continuous_step(serve_step, page_size: int):
    """One continuous decode step against the paged cache: batched serve at
    traced width m through per-slot block tables, per-slot sampling,
    page-granular masked commit, traced per-slot health.  Non-committed
    rows (stalled width groups, free slots, quarantined slots) keep
    token/cache/PRNG state unchanged — ``select_paged`` restores exactly
    the one (page, offset) cell each such row wrote — so their streams are
    exactly as if the step never ran for them.

    Health (§12): ``ok[b] = isfinite(logits[b]).all()`` is computed
    in-graph — logits never visit the host, so NaN/Inf detection must live
    inside the step — and gates the commit (``mask & ok``): a poisoned
    row's device state stays at its last healthy step while the host
    retires it.  ``poison`` is the fault-injection hook, also traced: rows
    flagged there get their logits overwritten with NaN *before* the
    health check, simulating upstream numerical corruption at zero cost
    when clean (an all-False select is the identity, bitwise).

    ``commit_all`` (static, two compiled variants) is the no-stall fast
    path: when every ACTIVE slot commits — always under max-width and
    degraded slo-degrade, and under width-rr whenever a single width group
    is active — the cache select is skipped via a ``lax.cond`` that only
    falls back to the masked select when a committed row is unhealthy.
    Free slots then do take the step's garbage writes, which is safe
    under paging because a free row's block-table row is all-zero: its
    write lands on the NULL page (never read unmasked, scrubbed-to-finite
    contents) and row independence keeps garbage rows from perturbing
    active ones (token/PRNG state is still mask-gated).  The scheduler
    forces ``commit_all=False`` while ANY slot is mid-chunked-prefill —
    a prefilling row's garbage write must be restored even though the row
    points at the null page, because its stale ``pos`` is meaningless
    (the restore is what keeps the invariant local instead of a cross-
    layer proof obligation)."""

    def step(master, cache, block_table, toks, m, keys, temps, topks,
             mask, poison, commit_all):
        logits, new_cache = serve_step(master, cache, toks, m, block_table)
        logits = jnp.where(poison[:, None],
                           jnp.asarray(jnp.nan, logits.dtype), logits)
        ok = jnp.all(jnp.isfinite(logits), axis=-1)
        eff = mask & ok
        if commit_all:
            new_cache = lax.cond(
                jnp.any(mask & ~ok),
                lambda nc: slots_lib.select_paged(eff, nc, cache,
                                                  block_table, page_size),
                lambda nc: nc, new_cache)
        else:
            new_cache = slots_lib.select_paged(eff, new_cache, cache,
                                               block_table, page_size)
        pair = jax.vmap(jax.random.split)(keys)        # [B, 2, 2]
        new_keys, subs = pair[:, 0], pair[:, 1]
        new_keys = jnp.where(eff[:, None], new_keys, keys)
        nxt = sample_token_vec(logits, subs, temps, topks)
        nxt = jnp.where(eff, nxt, toks)
        return nxt, new_cache, new_keys, ok

    return jax.jit(step, static_argnames=("commit_all",))


def _make_spec_macro(draft_fn, verify_step, page_size, s_max):
    """The whole speculative macro-step as ONE jitted dispatch
    (DESIGN.md §15): the fused k-step low-width draft scan, the batched
    full-width verify over the feed token + the k drafts, and — all
    in-graph — the greedy argmax, per-row health, accept length, the
    rejected-tail rollback and the next feed token.  The host's single
    round-trip is bookkeeping-only: by the time it sees the accept
    lengths, the cache is already rolled back and the feed tokens for
    the next step are already on device.

    Draft writes are provisional (``pos`` is restored before the verify
    re-derives every cell at full width; the rollback owns the position
    advance).  A row is healthy when every USED position's verify logits
    are finite (padded positions are don't-cares — they were null-routed
    on write).  The accept length is the longest draft prefix matching
    the verifier's argmax (``cumprod`` of the per-position match); an
    unhealthy row keeps 0 cells, which makes the rollback an exact
    restore of its pre-macro-step bytes."""
    def run(master, cache, tok, m_rows, m_verify, block_table, k_eff):
        draft_toks, dcache = draft_fn(master, cache, tok, m_rows,
                                      block_table, k_eff)
        dcache = {**dcache, "pos": cache["pos"]}
        n_used = jnp.where(k_eff > 0, k_eff + 1, 0).astype(jnp.int32)
        toks = jnp.concatenate([tok[:, None], draft_toks], axis=1)
        logits, vcache = verify_step(master, dcache, toks, m_verify,
                                     block_table, n_used)
        finite = jnp.all(jnp.isfinite(logits), axis=-1)
        used = (jnp.arange(logits.shape[1], dtype=jnp.int32)[None, :]
                < n_used[:, None])
        ok = jnp.all(finite | ~used, axis=-1)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        drafted = (jnp.arange(draft_toks.shape[1], dtype=jnp.int32)[None, :]
                   < k_eff[:, None])
        match = (draft_toks == pred[:, :-1]) & drafted
        accept = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                         axis=1)
        live = (k_eff > 0) & ok
        keep = jnp.where(live, accept + 1, 0)
        vcache = slots_lib.rollback_paged(vcache, block_table, keep,
                                          n_used, page_size=page_size,
                                          s_max=s_max)
        bonus = jnp.take_along_axis(pred, accept[:, None], axis=1)[:, 0]
        nxt = jnp.where(live, bonus, tok)
        return draft_toks, pred, ok, accept, nxt, vcache
    return jax.jit(run)


# ---------------------------------------------------------------------------
# admission verdicts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Admission:
    """``try_submit``'s verdict: either the request is queued (``rid``
    set) or it was rejected with backpressure (``retry_after_steps`` is
    the backoff hint in decode steps)."""
    accepted: bool
    rid: Optional[int]
    queue_depth: int
    retry_after_steps: int = 0
    reason: str = "queued"


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

class ContinuousScheduler:
    """Continuous batcher over a SwitchableServer (use
    ``server.continuous(...)`` or ``Artifact.server(...).continuous(...)``).

    ``submit()`` enqueues a request and returns its rid; ``step()`` runs
    one scheduler step (admissions + one batched decode at the selected
    width), returning False once queue and slots are empty; ``drain()``
    steps to completion and returns {rid: FinishedRequest}.  Streaming:
    per-request ``stream(rid, token, done)`` callbacks and/or a
    scheduler-wide ``on_token``.  Time is counted in decode steps
    (``clock``); latency accounting lives on each FinishedRequest.

    Resilience knobs (DESIGN.md §12; all off by default so a plain
    scheduler behaves exactly as before):

      * ``max_queue`` — bounded queue: ``submit`` past capacity raises
        ``QueueFull`` (with ``retry_after_steps``); ``try_submit`` returns
        an ``Admission`` verdict instead of raising.
      * ``queue_ttl`` — queued requests older than this many steps are
        evicted (status ``evicted``) instead of waiting forever.
      * per-request ``deadline`` (submit kwarg) — total step budget from
        submit to finish; missed in queue → ``evicted``, missed mid-decode
        → ``deadline`` with partial tokens.
      * ``repetition_limit`` — quarantine a slot that commits the same
        non-EOS token this many times in a row (status ``poisoned``).
      * ``faults`` — fault injectors (repro/serve/faults.py), also
        addable later via ``inject()``.

    Paged-KV knobs (DESIGN.md §13): ``page_size`` (must divide the server
    max_len), ``n_pages`` (pool size incl. the null page; default sizes
    every slot for a max_len request), ``prefill_chunk`` (None = whole
    prompt in one chunk at admission; an int splits long prefills into
    chunks interleaved with decode), ``kv_dtype`` ("bf16" or
    "int8"/"f8"/"kv8" for byte-wide pages — a tolerance regime: the
    bitwise oracle property holds for bf16 pages), and
    ``prefix_cache=False`` to disable cross-request prefix KV reuse.

    Telemetry (DESIGN.md §16): the scheduler always owns a
    ``MetricsRegistry`` (``sched.metrics``) — every counter in ``stats``
    is a registry child, exposable via ``metrics.render_prometheus()`` or
    ``repro.serve.telemetry.serve_metrics``.  ``telemetry=True`` (or a
    ``Telemetry`` instance) additionally records per-request trace spans
    (Chrome trace_event / JSONL export via ``sched.telemetry.tracer``)
    and wall-clock TTFT/ITL histograms per precision class, with the
    wall times mirrored onto each ``FinishedRequest.wall``.  All
    recording is host-side; the jitted step is untouched.
    """

    def __init__(self, server, slots: int = 8, width_policy="max-width",
                 policy: Optional[PrecisionPolicy] = None,
                 eos_id: Optional[int] = None,
                 on_token: Optional[Callable[[int, int, bool], None]] = None,
                 max_queue: Optional[int] = None,
                 queue_ttl: Optional[int] = None,
                 repetition_limit: Optional[int] = None,
                 faults: Optional[list] = None,
                 page_size: int = 16,
                 n_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 kv_dtype=None,
                 prefix_cache: bool = True,
                 spec_decode=None,
                 telemetry=None):
        self._srv = server
        self.cfg = server.cfg
        self.n_slots = int(slots)
        self.max_len = server.max_len
        self._policy = (policy if policy is not None
                        else (server.policy
                              or PrecisionPolicy.all_widths(
                                  default=server.precision)))
        self._width_policy = make_width_policy(width_policy)
        # width-heterogeneous policies return per-slot width dicts from
        # select() and are served by the per-row-width fused step, which
        # is compiled for the precision policy's static width ladder
        self._hetero = bool(getattr(self._width_policy, "heterogeneous",
                                    False))
        self._hetero_widths = tuple(sorted(
            {int(w) for w in self._policy.widths}, reverse=True))
        self.default_eos_id = eos_id
        self.on_token = on_token
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if queue_ttl is not None and queue_ttl < 1:
            raise ValueError(f"queue_ttl must be >= 1, got {queue_ttl}")
        if repetition_limit is not None and repetition_limit < 2:
            raise ValueError(f"repetition_limit must be >= 2, got "
                             f"{repetition_limit}")
        self.max_queue = max_queue
        self.queue_ttl = queue_ttl
        self.repetition_limit = repetition_limit
        self._faults = list(faults or [])

        # -- paged KV geometry (DESIGN.md §13) -----------------------------
        # rwkv has no attention KV at all; hybrid pages its attention KV
        # but prefills whole (no chunking/reuse: its recurrent state cannot
        # be checkpointed mid-prompt at page granularity).
        self._paged = self.cfg.family != "rwkv"
        self._chunkable = self.cfg.family in ("dense", "moe", "vlm")
        self.page_size = int(page_size)
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if self._paged and self.max_len % self.page_size != 0:
            # the decode gather reads a [max_pages * page_size] view per
            # row; page_size | max_len keeps that view == max_len, which
            # is what makes the paged step bitwise-equal to the dense
            # lockstep oracle (no extra padded kv columns)
            raise ValueError(
                f"page_size {self.page_size} must divide the server "
                f"max_len {self.max_len}")
        self.max_pages_per_slot = (self.max_len // self.page_size
                                   if self._paged else 1)
        if n_pages is None:
            # every slot can hold a full max_len request, plus the null page
            n_pages = self.n_slots * self.max_pages_per_slot + 1
        self.n_pages = int(n_pages)
        if prefill_chunk is not None:
            prefill_chunk = int(prefill_chunk)
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got "
                                 f"{prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        self.kv_dtype = resolve_kv_dtype(kv_dtype, server.cache_dtype)
        self._allocator = (PageAllocator(self.n_pages) if self._paged
                           else None)
        self._prefix = (PrefixCache(self._allocator)
                        if self._paged and self._chunkable and prefix_cache
                        else None)
        # host-side block tables; the device copy is rebuilt lazily after
        # any row mutation (admission install / retire)
        self._block_table = np.zeros(
            (self.n_slots, self.max_pages_per_slot), np.int32)
        self._bt_dev = None

        self._table = SlotTable(self.n_slots)
        self._queue: collections.deque = collections.deque()
        self._finished: Dict[int, FinishedRequest] = {}
        self._next_rid = 0
        self.clock = 0  # decode-step clock
        self._last_step_seconds: Optional[float] = None

        # device-side per-slot state
        self._cache = slots_lib.init_paged_slot_cache(
            self.cfg, self.n_slots, self.n_pages, self.page_size,
            server.cache_dtype, kv_dtype=self.kv_dtype)
        self._tok = jnp.zeros((self.n_slots,), jnp.int32)
        self._keys = jnp.zeros((self.n_slots, 2), jnp.uint32)
        self._temps = np.zeros((self.n_slots,), np.float32)
        self._topks = np.zeros((self.n_slots,), np.int32)
        self._no_poison = jnp.zeros((self.n_slots,), bool)
        # the jitted step/prefill/write executables are cached ON the
        # server, so constructing a fresh scheduler over the same server
        # (new workload, different width policy) reuses the compiled code —
        # scheduler state is host data, the executables are shape-keyed
        # (and here page_size-keyed: it is baked into the paged closures).
        if getattr(server, "_paged_exec_key", None) != self.page_size:
            serve_paged = packed_step_lib.make_master_serve_step_paged(
                self.cfg, server.kernel_backend, server.layer_unroll,
                page_size=self.page_size)
            server._continuous_step_fn = _make_continuous_step(
                serve_paged, self.page_size)
            server._paged_prefill_fn = jax.jit(
                packed_step_lib.make_master_prefill_paged(
                    self.cfg, server.kernel_backend,
                    page_size=self.page_size))
            server._install_pages_fn = jax.jit(
                slots_lib.install_prefill_pages,
                static_argnames=("plen", "page_size"))
            server._write_slot_fn = jax.jit(slots_lib.write_slot)
            server._scrub_pages_fn = jax.jit(slots_lib.scrub_pages)
            server._set_pos_fn = jax.jit(
                lambda cache, idx, value:
                {**cache, "pos": cache["pos"].at[idx].set(value)})
            server._paged_exec_key = self.page_size
        self._step_fn = server._continuous_step_fn
        if self._hetero:
            # the hetero step is additionally keyed on the static width
            # ladder it was compiled for (the ladder is baked into the
            # per-width lax.cond sweep)
            hkey = (self.page_size, self._hetero_widths)
            if getattr(server, "_hetero_exec_key", None) != hkey:
                serve_h = packed_step_lib.make_master_serve_step_hetero_paged(
                    self.cfg, self._hetero_widths, server.kernel_backend,
                    server.layer_unroll, page_size=self.page_size)
                server._hetero_step_fn = _make_continuous_step(
                    serve_h, self.page_size)
                server._hetero_exec_key = hkey
            self._step_fn = server._hetero_step_fn
        self._prefill_chunk_fn = server._paged_prefill_fn
        self._install_pages = server._install_pages_fn
        self._write_slot = server._write_slot_fn
        self._scrub_pages_fn = server._scrub_pages_fn
        self._set_pos = server._set_pos_fn

        # -- self-speculative decoding (DESIGN.md §15) ---------------------
        # spec_decode=None inherits the precision policy's speculation
        # spec (PrecisionPolicy.speculative); an explicit True/int/dict/
        # SpeculativeConfig overrides it, False disables it outright.
        spec = spec_lib.as_spec(spec_decode)
        if spec_decode is None:
            spec = spec_lib.as_spec(getattr(self._policy, "speculative",
                                            None))
            if spec is not None and not self._chunkable:
                spec = None  # recurrent state cannot be rolled back
        elif spec is not None and not self._chunkable:
            raise ValueError(
                f"spec_decode requires a chunkable attention family "
                f"(dense/moe/vlm) — {self.cfg.family} carries recurrent "
                f"state that cannot be rolled back after a rejected draft")
        self._spec = spec
        self._spec_acct = spec_lib.SpecAccounting()
        if spec is not None:
            self._spec_est = spec_lib.make_estimator(spec)
            self._bps_stats = getattr(server, "bps_stats", None)
            # spec executables are keyed on (page_size, draft ladder, k):
            # the ladder is baked into the draft scan's lax.cond sweep and
            # k is its static scan length
            skey = (self.page_size, spec.ladder, int(spec.k))
            if getattr(server, "_spec_exec_key", None) != skey:
                draft_scan = packed_step_lib.make_master_draft_scan_paged(
                    self.cfg, spec.ladder, int(spec.k),
                    server.kernel_backend, server.layer_unroll,
                    page_size=self.page_size)
                server._spec_macro_fn = _make_spec_macro(
                    draft_scan,
                    packed_step_lib.make_master_verify_step_paged(
                        self.cfg, server.kernel_backend,
                        server.layer_unroll, page_size=self.page_size),
                    self.page_size, int(spec.k) + 1)
                server._spec_exec_key = skey
            self._spec_macro = server._spec_macro_fn
            self._spec_vw = jnp.int32(spec.verify_width)
            self._spec_arg_cache: Dict[tuple, tuple] = {}

        # -- telemetry (DESIGN.md §16) -------------------------------------
        # The metrics registry is ALWAYS on: its children are the storage
        # behind every scheduler counter, and ``stats`` is a thin view over
        # them (one source of truth).  What the telemetry object gates is
        # the EXPENSIVE layer — trace events and wall-clock TTFT/ITL — and
        # NullTelemetry (the default) no-ops all of it, so an
        # uninstrumented scheduler pays only the same increment-per-event
        # cost the old _counts dict did.  telemetry=True builds a full
        # Telemetry (trace + latency histograms).
        if telemetry is None or telemetry is False:
            telemetry = telemetry_lib.NullTelemetry()
        elif telemetry is True:
            telemetry = telemetry_lib.Telemetry()
        self.telemetry = self._tel = telemetry
        self.metrics = (getattr(telemetry, "registry", None)
                        or telemetry_lib.MetricsRegistry())
        self._m = telemetry_lib.SchedulerMetrics(self.metrics)
        telemetry.attach(self.metrics)
        self._m.register_gauges(self)

    # -- fault injection ----------------------------------------------------
    def inject(self, fault) -> "ContinuousScheduler":
        """Install a fault injector (repro/serve/faults.py); returns self
        so injections chain."""
        self._faults.append(fault)
        return self

    # -- queueing -----------------------------------------------------------
    def submit(self, prompt, max_new: int,
               request_class: Optional[str] = None,
               temperature: float = 0.0, top_k: int = 0,
               eos_id: Optional[int] = None, seed: int = 0,
               stream: Optional[Callable[[int, int, bool], None]] = None,
               deadline: Optional[int] = None,
               min_width: Optional[int] = None) -> int:
        """Enqueue a request; returns its rid.  Validates length, deadline
        and class routing here (fail fast), admission happens inside
        ``step()``.  With a bounded queue (``max_queue``) an over-capacity
        submit raises ``QueueFull`` with a ``retry_after_steps`` hint —
        use ``try_submit`` for a non-raising verdict.  ``deadline`` is the
        total step budget from submit to finish; ``min_width`` is the
        degradation floor (defaults to the request class's policy floor),
        which the slo-degrade policy never crosses."""
        prompt = np.ascontiguousarray(np.asarray(prompt, np.int32).ravel())
        max_new = int(max_new)
        if max_new < 0:
            raise ValueError(f"max_new must be >= 0, got {max_new}")
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt_len {prompt.size} + max_new {max_new} exceeds the "
                f"server max_len {self.max_len}")
        if self._paged and max_new > 0:
            need = pages_lib.request_pages(prompt.size, max_new,
                                           self.page_size)
            if need > self.n_pages - 1:
                # would never fit even with every page free: rejecting at
                # submit prevents a permanent head-of-line deadlock
                raise ValueError(
                    f"request needs {need} KV pages but the pool has "
                    f"{self.n_pages - 1} (page_size {self.page_size}) — "
                    f"raise n_pages or shrink the request")
        if deadline is not None:
            deadline = int(deadline)
            if deadline < 1:
                raise BadDeadline(f"deadline must be >= 1 step, got "
                                  f"{deadline}")
        # resolves class > plan > default; unknown classes fail with the
        # registered set named (errors.py taxonomy, not a bare KeyError)
        try:
            schedule = self._policy.request_schedule(max_new, request_class)
        except KeyError:
            raise UnknownRequestClass(request_class,
                                      self._policy.classes) from None
        if min_width is None:
            min_width = self._policy.min_width_for(request_class)
        else:
            min_width = int(min_width)
            if not 1 <= min_width <= MASTER_M:
                raise ValueError(f"min_width must be in 1..{MASTER_M}, "
                                 f"got {min_width}")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self._m.rejected.inc()
            self._tel.request_rejected(len(self._queue), self.clock)
            raise QueueFull(len(self._queue), self.max_queue,
                            self._retry_after())
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      request_class=request_class,
                      temperature=float(temperature), top_k=int(top_k),
                      eos_id=(self.default_eos_id if eos_id is None
                              else int(eos_id)),
                      seed=int(seed), stream=stream,
                      submit_step=self.clock, deadline=deadline,
                      min_width=min_width)
        self._queue.append((req, schedule))
        self._tel.request_submitted(rid, request_class, prompt.size,
                                    max_new, self.clock)
        return rid

    def try_submit(self, prompt, max_new: int, **kw) -> Admission:
        """Backpressure-aware ``submit``: returns an ``Admission`` verdict
        instead of raising ``QueueFull``.  Argument validation errors
        (bad lengths, unknown classes, bad deadlines) still raise — those
        are caller bugs, not load."""
        try:
            rid = self.submit(prompt, max_new, **kw)
        except QueueFull as e:
            return Admission(accepted=False, rid=None,
                             queue_depth=e.depth,
                             retry_after_steps=e.retry_after_steps,
                             reason="queue-full")
        return Admission(accepted=True, rid=rid,
                         queue_depth=len(self._queue))

    def _retry_after(self) -> int:
        """Backoff hint in decode steps: the soonest any active slot can
        free (its remaining max_new, ignoring early EOS) plus the queue
        drain behind it.  A heuristic, not a promise — documented as such
        on QueueFull."""
        rem = [s.req.max_new - len(s.emitted)
               for _, s in self._table.active()]
        base = min(rem) if rem else 1
        return max(1, base + len(self._queue) // max(self.n_slots, 1))

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        return self._table.n_active

    # -- admission ----------------------------------------------------------
    def _finish_unadmitted(self, req: Request, reason: str,
                           status: str) -> None:
        """Terminal record for a request that never reached a slot
        (queue-TTL / deadline eviction): no tokens, ``admit_step == -1``."""
        self._finished[req.rid] = FinishedRequest(
            rid=req.rid, tokens=np.zeros((0,), np.int32),
            prompt_len=req.prompt.size, finish_reason=reason,
            prefill_precision=self._policy.request_schedule(
                1, req.request_class)[0],
            decode_widths=[], request_class=req.request_class,
            submit_step=req.submit_step, admit_step=-1,
            finish_step=self.clock, status=status,
            wall=self._tel.finish_request(req.rid, req.request_class,
                                          status, reason, self.clock, 0))
        self._m.finished.inc()
        self._m.evicted.inc()

    def _evict_expired(self) -> None:
        """Shed queued requests that can no longer be served in time:
        queue TTL and already-expired per-request deadlines."""
        if self.queue_ttl is None and not any(
                req.deadline is not None for req, _ in self._queue):
            return
        keep: collections.deque = collections.deque()
        for req, schedule in self._queue:
            waited = self.clock - req.submit_step
            if req.deadline is not None and waited >= req.deadline:
                self._finish_unadmitted(req, "evicted", "evicted")
            elif self.queue_ttl is not None and waited >= self.queue_ttl:
                self._finish_unadmitted(req, "evicted", "evicted")
            else:
                keep.append((req, schedule))
        self._queue = keep

    def _bt(self):
        """Device copy of the block tables, rebuilt lazily after host-side
        row mutations (admission install / retire)."""
        if self._bt_dev is None:
            self._bt_dev = jnp.asarray(self._block_table)
        return self._bt_dev

    def _scrub(self, freed: List[int]) -> None:
        """Zero freed pages on device so a recycled page's garbage can
        never alias into a later reader's masked positions as NaN (masked
        columns are bitwise-neutral only for FINITE garbage).  The index
        vector is padded with 0 — scrubbing the null page is harmless and
        keeps one executable per pad width."""
        if not freed:
            return
        width = self.max_pages_per_slot
        for i in range(0, len(freed), width):
            batch = freed[i:i + width]
            idxs = np.zeros((width,), np.int32)
            idxs[:len(batch)] = batch
            self._cache = self._scrub_pages_fn(self._cache,
                                               jnp.asarray(idxs))

    def _finalize_prefill(self, idx: int, logits) -> None:
        """Prefill finished for slot ``idx``: sample the first token from
        the last chunk's logits (identical PRNG discipline to the dense
        admission), commit the slot's position, install its block-table
        row into the decode step's table, publish its full prompt pages to
        the prefix cache, and flip the slot to decode phase."""
        slot = self._table.get(idx)
        req = slot.req
        plen = req.prompt.size
        k0 = jax.random.PRNGKey(req.seed)
        tok0 = int(sample_token(logits, k0, req.temperature, req.top_k)[0])
        self._cache = self._set_pos(self._cache, jnp.int32(idx),
                                    jnp.int32(plen))
        self._block_table[idx, :] = 0
        self._block_table[idx, :len(slot.pages)] = slot.pages
        self._bt_dev = None
        self._tok = self._tok.at[idx].set(tok0)
        self._keys = self._keys.at[idx].set(k0)
        self._temps[idx] = req.temperature
        self._topks[idx] = req.top_k
        slot.phase = "decode"
        slot.prefill_pos = plen
        slot.emitted.append(tok0)
        slot.repeat_run = 1
        self._tel.first_token(req.rid, idx, slot.prefill_precision,
                              self.clock)
        if self._prefix is not None:
            keys = pages_lib.prefix_keys(req.prompt, self.page_size,
                                         slot.prefill_precision)
            for i in range(slot.n_reused, len(keys)):
                if self._prefix.insert(keys[i], slot.pages[i]):
                    slot.inserted_pages.append(slot.pages[i])
        done = (tok0 == req.eos_id if req.eos_id is not None
                else False) or req.max_new <= 1
        self._emit(req, tok0, done)
        if done:
            self._retire(idx, "eos" if (req.eos_id is not None
                                        and tok0 == req.eos_id)
                         else "length")

    def _run_prefill_chunk(self, idx: int, chunk: Optional[int]) -> None:
        """One prefill chunk for slot ``idx`` (``chunk=None`` = the whole
        remaining prompt); finalizes the slot when the prompt is done.
        The chunk writes K/V through the slot's OWN block-table row
        (passed directly — the row is not yet visible to the decode
        step), attending over the reused prefix pages + everything the
        slot prefilled so far."""
        slot = self._table.get(idx)
        req = slot.req
        plen = req.prompt.size
        start = slot.prefill_pos
        n = plen - start if chunk is None else min(chunk, plen - start)
        tokens = jnp.asarray(req.prompt[None, start:start + n])
        row = np.zeros((self.max_pages_per_slot,), np.int32)
        row[:len(slot.pages)] = slot.pages
        logits, new_pages = self._prefill_chunk_fn(
            self._srv.master, tokens, jnp.int32(slot.prefill_precision),
            self._cache["pages"], jnp.asarray(row), jnp.int32(start))
        self._cache = {**self._cache, "pages": new_pages}
        slot.prefill_pos = start + n
        self._m.prefill_chunks.inc()
        self._tel.prefill_chunk(req.rid, idx, start, n,
                                slot.prefill_precision, self.clock)
        if slot.prefill_pos >= plen:
            self._finalize_prefill(idx, logits)

    def _advance_prefill(self) -> bool:
        """Advance the OLDEST-admitted prefilling slot by one chunk (FIFO
        over chunks keeps first-token order deterministic).  At most one
        chunk per scheduler step: the decode batch in the same step is
        what bounds a long document's impact on decode latency."""
        cands = [(s.admit_step, idx)
                 for idx, s in self._table.active() if s.phase == "prefill"]
        if not cands:
            return False
        _, idx = min(cands)
        self._run_prefill_chunk(idx, self.prefill_chunk)
        return True

    def _any_prefilling(self) -> bool:
        return any(s.phase == "prefill" for _, s in self._table.active())

    def _admit_dense(self, req: Request, schedule, idx: int) -> None:
        """rwkv admission: no attention KV to page — the dense whole-prompt
        prefill + write_slot path, unchanged."""
        pm = schedule[0]
        logits, slot_cache = self._srv._prefill(
            self._srv.master, jnp.asarray(req.prompt[None, :]),
            jnp.int32(pm), max_len=self.max_len)
        k0 = jax.random.PRNGKey(req.seed)
        tok0 = int(sample_token(logits, k0, req.temperature, req.top_k)[0])
        self._cache = self._write_slot(self._cache, slot_cache,
                                       jnp.int32(idx))
        self._tok = self._tok.at[idx].set(tok0)
        self._keys = self._keys.at[idx].set(k0)
        self._temps[idx] = req.temperature
        self._topks[idx] = req.top_k
        state = SlotState(req=req, schedule=schedule, emitted=[tok0],
                          decode_widths=[], prefill_precision=pm,
                          admit_step=self.clock, repeat_run=1)
        self._table.admit(idx, state)
        self._tel.first_token(req.rid, idx, pm, self.clock)
        done = (tok0 == req.eos_id if req.eos_id is not None
                else False) or req.max_new <= 1
        self._emit(req, tok0, done)
        if done:
            self._retire(idx, "eos" if (req.eos_id is not None
                                        and tok0 == req.eos_id)
                         else "length")

    def _claim_pages(self, req: Request, pm: int):
        """Reserve the full page budget for ``req`` upfront (prefill +
        decode — reservation at admission is what makes PageBudgetExceeded
        impossible mid-request): prefix-cache hits are adopted (incref'd)
        first, the shortfall is allocated fresh, evicting LRU unreferenced
        cache entries if needed.  Returns (pages, n_reused) or None when
        the budget cannot be met — the FIFO head then blocks admission."""
        plen = req.prompt.size
        need = pages_lib.request_pages(plen, req.max_new, self.page_size)
        hits: List[int] = []
        if self._prefix is not None:
            # cap: the LAST prompt token always prefills into an exclusive
            # page, so its logits (-> first token) come from live compute
            # and a fully-cached prompt still produces them
            cap = (plen - 1) // self.page_size
            keys = pages_lib.prefix_keys(req.prompt, self.page_size, pm)
            hits = self._prefix.lookup(keys[:cap])
            for p in hits:     # adopt BEFORE evict_for: a hit whose only
                self._allocator.incref(p)  # ref is the cache must not be
                                           # evicted out from under us
        if hits:
            self._tel.prefix_hit(req.rid, len(hits), self.clock)
        n_fresh = need - len(hits)
        if not self._allocator.can_alloc(n_fresh):
            if self._prefix is not None:
                evicted = self._prefix.evict_for(n_fresh)
                if evicted:
                    self._tel.prefix_evicted(len(evicted), self.clock)
                self._scrub(evicted)
            if not self._allocator.can_alloc(n_fresh):
                freed = [p for p in hits if self._allocator.decref(p)]
                self._scrub(freed)  # cache entry still holds a ref, so
                                    # nothing frees in practice
                self._m.page_blocked_admissions.inc()
                self._tel.page_blocked(req.rid, self.clock)
                return None
        pages = hits + self._allocator.alloc(n_fresh)
        return pages, len(hits)

    def _spec_pick(self, req: Request) -> Optional[int]:
        """Draft width for ``req`` (chosen ONCE, at admission), or None
        when the request decodes plain: speculation needs greedy sampling
        (the accept rule compares argmaxes), at least two decode tokens to
        ever draft ahead of, and an allowed request class."""
        spec = self._spec
        if spec is None or req.temperature > 0 or req.max_new < 3:
            return None
        if spec.classes is not None and req.request_class not in spec.classes:
            return None
        w = int(self._spec_est.draft_width(spec, self._bps_stats,
                                           self._policy.widths))
        if w not in spec.ladder:
            raise RuntimeError(
                f"estimator {self._spec_est.name!r} chose draft width {w} "
                f"outside the compiled ladder {spec.ladder}")
        return w

    def _admit_one(self, req: Request, schedule, idx: int) -> bool:
        """Admit ``req`` into slot ``idx``; False when the page budget
        blocks it (the request stays at the queue head)."""
        if not self._paged:
            self._m.admitted.inc()
            self._tel.request_admitted(req.rid, idx, self.clock, 0, 0)
            self._admit_dense(req, schedule, idx)
            return True
        pm = schedule[0]
        claim = self._claim_pages(req, pm)
        if claim is None:
            return False
        pages, n_reused = claim
        state = SlotState(req=req, schedule=schedule, emitted=[],
                          decode_widths=[], prefill_precision=pm,
                          admit_step=self.clock, phase="prefill",
                          prefill_pos=n_reused * self.page_size,
                          pages=pages, n_reused=n_reused,
                          spec_draft_width=self._spec_pick(req))
        self._table.admit(idx, state)
        self._m.admitted.inc()
        self._m.reused_pages.inc(n_reused)
        self._tel.request_admitted(req.rid, idx, self.clock, n_reused,
                                   len(pages))
        if not self._chunkable:
            # hybrid: whole dense prefill, attention KV scattered into the
            # slot's pages, recurrent state written dense — then the slot
            # finalizes immediately (no chunking for recurrent families)
            plen = req.prompt.size
            logits, slot_cache = self._srv._prefill(
                self._srv.master, jnp.asarray(req.prompt[None, :]),
                jnp.int32(pm), max_len=self.max_len)
            row = np.zeros((self.max_pages_per_slot,), np.int32)
            row[:len(pages)] = pages
            self._cache = self._install_pages(
                self._cache, slot_cache, jnp.int32(idx), jnp.asarray(row),
                plen=plen, page_size=self.page_size)
            self._finalize_prefill(idx, logits)
        elif self.prefill_chunk is None:
            # unchunked: the whole remaining prompt (minus reused prefix
            # pages) is one chunk, run at admission — first token lands
            # the same step, matching the dense batcher's latency shape
            self._run_prefill_chunk(idx, None)
        return True

    def _admit(self) -> None:
        while self._queue:
            req, schedule = self._queue[0]
            if req.max_new == 0:
                # prefill-only: nothing to decode, no slot needed — finish
                # at the queue head without waiting for (or blocking on) a
                # free slot.  No prefill actually runs; the recorded width
                # is the one the request's class would have prefilled at.
                self._queue.popleft()
                self._tel.request_admitted(req.rid, -1, self.clock, 0, 0)
                self._finished[req.rid] = FinishedRequest(
                    rid=req.rid, tokens=np.zeros((0,), np.int32),
                    prompt_len=req.prompt.size, finish_reason="length",
                    prefill_precision=self._policy.request_schedule(
                        1, req.request_class)[0],
                    decode_widths=[], request_class=req.request_class,
                    submit_step=req.submit_step, admit_step=self.clock,
                    finish_step=self.clock,
                    wall=self._tel.finish_request(
                        req.rid, req.request_class, "ok", "length",
                        self.clock, 0))
                self._m.admitted.inc()
                self._m.finished.inc()
                continue
            idx = self._table.free_idx()
            if idx is None:
                return
            if not self._admit_one(req, schedule, idx):
                return  # page budget blocks the FIFO head
            self._queue.popleft()

    # -- stepping -----------------------------------------------------------
    def step(self) -> bool:
        """One scheduler step: run fault injectors, evict expired queue
        entries, admit from the queue, pick the step width from the active
        slots' wanted widths, run one batched decode, commit the
        scheduled-and-healthy rows, retire finished / quarantined /
        deadline-missed requests.  Returns False when there is nothing
        left to do."""
        t0 = time.perf_counter()
        for f in self._faults:
            f.before_step(self)
        self._evict_expired()
        self._admit()
        # one prefill chunk per step, IN THE SAME step as the batched
        # decode below — a long document's prefill interleaves with the
        # decode clock instead of stalling it
        prefilled = self._advance_prefill()
        wanted = {idx: s.wanted for idx, s in self._table.active()
                  if s.phase == "decode"}
        if not wanted:
            if prefilled or self._any_prefilling():
                # prefill made progress but nobody is decoding yet — the
                # clock still ticks (deadlines and latency stats count
                # prefill time)
                self.clock += 1
                self._m.steps.inc()
                self._m.prefill_only_steps.inc()
                self._deadline_sweep()
                self._last_step_seconds = dt = time.perf_counter() - t0
                self._tel.step_done(self.clock, dt)
                return True
            return False
        prev_shift = (getattr(self._width_policy, "shift", 0)
                      if self._tel.enabled else 0)
        self._width_policy.observe({
            "clock": self.clock,
            "queue_depth": len(self._queue),
            "active": len(wanted),
            "slots": self.n_slots,
            "step_seconds": self._last_step_seconds,
            "floors": {idx: s.req.min_width
                       for idx, s in self._table.active()
                       if s.phase == "decode"},
            "widths": self._policy.widths,
        })
        if self._tel.enabled:
            new_shift = getattr(self._width_policy, "shift", 0)
            if new_shift != prev_shift:
                self._tel.slo_shift(
                    self.clock, new_shift, prev_shift,
                    getattr(self._width_policy, "last_shift_cause", None))
        m, commit = self._width_policy.select(wanted)
        if self._hetero:
            # per-slot width dict -> int32[n_slots] vector for the fused
            # per-row-width step.  Widths are host ints here, so ladder
            # membership is checked per step with a clear error instead of
            # a silent zero row inside the kernel sweep.
            m_by_slot = dict(m)
            bad = {i: w for i, w in m_by_slot.items()
                   if w not in self._hetero_widths}
            if bad:
                raise RuntimeError(
                    f"heterogeneous step selected widths {bad} outside the "
                    f"compiled ladder {self._hetero_widths} (the precision "
                    f"policy's widths)")
            # free / prefilling slots ride along the most common active
            # width so padding never adds a ladder branch to the sweep
            fill = collections.Counter(
                m_by_slot.values()).most_common(1)[0][0]
            m_vec = np.full((self.n_slots,), fill, np.int32)
            for i, w in m_by_slot.items():
                m_vec[i] = w
            m_arg = jnp.asarray(m_vec)
        else:
            m_by_slot = None
            m_arg = jnp.int32(m)
        poison = np.zeros((self.n_slots,), bool)
        for f in self._faults:
            f.poison_slots(self, poison)
        # speculative rows this step (§15): spec-enabled slots whose
        # REALIZED width is the verify width — a degraded or sub-full-
        # width row silently decodes plain, which is the whole SLO /
        # heterogeneous composition rule — with draft budget left before
        # max_new.  Fault-poisoned rows demote to the plain path so the
        # §12 quarantine machinery applies unchanged.
        spec_rows: Dict[int, int] = {}
        if self._spec is not None:
            vw = int(self._spec.verify_width)
            for idx in commit:
                s = self._table.get(idx)
                w = int(m_by_slot[idx]) if self._hetero else int(m)
                if (s.spec_draft_width is not None and w == vw
                        and not poison[idx]):
                    k_eff = min(int(self._spec.k),
                                s.req.max_new - len(s.emitted) - 1)
                    if k_eff >= 1:
                        spec_rows[idx] = k_eff
        self.clock += 1
        self._m.steps.inc()
        self._m.slot_steps_active.inc(len(wanted))
        if self._hetero:
            # one fused step serves several widths at once: count each
            # distinct width present this step (so width_steps sums to
            # more than `steps` under mixed batches — it answers "how
            # many steps touched width w", same as the scalar policies)
            for w in set(m_by_slot.values()):
                self._m.width_step(int(w))
        else:
            self._m.width_step(int(m))
        if spec_rows:
            self._spec_step(set(commit) - set(spec_rows), spec_rows,
                            m_arg, m_by_slot, m, poison)
        else:
            mask = np.zeros((self.n_slots,), bool)
            mask[sorted(commit)] = True
            nxt, cache, keys, ok = self._step_fn(
                self._srv.master, self._cache, self._bt(), self._tok,
                m_arg,
                self._keys, jnp.asarray(self._temps),
                jnp.asarray(self._topks),
                jnp.asarray(mask),
                jnp.asarray(poison) if poison.any() else self._no_poison,
                # the fast path must stay off while any slot prefills: its
                # garbage decode write needs the masked restore (see
                # _make_continuous_step)
                commit_all=(len(commit) == len(wanted)
                            and not self._any_prefilling()))
            self._cache, self._keys, self._tok = cache, keys, nxt
            # ONE host round-trip per continuous step (tokens + health)
            toks, ok = jax.device_get((nxt, ok))
            for idx in sorted(commit):
                slot = self._table.get(idx)
                if not bool(ok[idx]):
                    # quarantine: the row did NOT commit (traced health
                    # gate), so its device state is still the last healthy
                    # step — retire just this slot, neighbours untouched
                    # (§12)
                    self._retire(idx, "poisoned", status="poisoned")
                    self._m.poisoned.inc()
                    continue
                self._m.slot_steps_committed.inc()
                realized = int(m_by_slot[idx]) if self._hetero else int(m)
                self._commit_token(idx, slot, int(toks[idx]), realized)
        self._deadline_sweep()
        self._last_step_seconds = dt = time.perf_counter() - t0
        self._tel.step_done(self.clock, dt)
        return True

    def _commit_token(self, idx: int, slot: SlotState, t: int,
                      realized: int) -> bool:
        """Book ONE committed token on slot ``idx``: width accounting,
        stream emit, repetition quarantine, EOS / length retirement.
        Returns True when the slot retired (the speculative commit walk
        stops there — tokens after an EOS are discarded host-side; the
        slot's device state is torn down by the retire anyway)."""
        self._m.committed_tokens.inc()
        self._m.token_at_width(realized)
        self._tel.token_committed(slot.req.rid, idx, realized, self.clock)
        slot.decode_widths.append(realized)
        prev = slot.emitted[-1]
        slot.emitted.append(t)
        slot.repeat_run = slot.repeat_run + 1 if t == prev else 1
        eos = slot.req.eos_id
        hit_eos = eos is not None and t == eos
        if (self.repetition_limit is not None and not hit_eos
                and slot.repeat_run >= self.repetition_limit):
            self._emit(slot.req, t, True)
            self._retire(idx, "repetition", status="poisoned")
            self._m.poisoned.inc()
            return True
        done = hit_eos or len(slot.emitted) >= slot.req.max_new
        self._emit(slot.req, t, done)
        if done:
            self._retire(idx, "eos" if hit_eos else "length")
        return done

    def _spec_step(self, plain_commit, spec_rows: Dict[int, int],
                   m_arg, m_by_slot, m, poison) -> None:
        """One speculative macro-step (DESIGN.md §15): ONE fused spec
        dispatch (plus a plain sub-step when plain rows are mixed in) and
        ONE bookkeeping-only host round-trip.

          1. plain rows decode exactly as before (masked commit — spec
             rows ride along restored, so mixing costs them nothing);
          2. the fused macro dispatch drafts k tokens per spec row at its
             per-slot draft width (argmax feedback on-device), verifies
             all k+1 candidate positions at full width in one batched
             pass, computes argmax + health + accept length in-graph,
             rolls back the rejected tail (cells zeroed through the
             block table — byte-exact, decode cells are slot-exclusive
             and scrubbed-at-retirement; position += committed count)
             and selects the next feed token per row.

        The host only sees (plain token, draft tokens, verify argmax,
        health, accept length) and updates the books — by the time it
        looks, the cache is already rolled back and the next feed tokens
        are already on device."""
        spec = self._spec
        bt = self._bt()
        plain_out = None
        if plain_commit:
            mask = np.zeros((self.n_slots,), bool)
            mask[sorted(plain_commit)] = True
            nxt, cache, keys, ok = self._step_fn(
                self._srv.master, self._cache, bt, self._tok, m_arg,
                self._keys, jnp.asarray(self._temps),
                jnp.asarray(self._topks), jnp.asarray(mask),
                jnp.asarray(poison) if poison.any() else self._no_poison,
                commit_all=False)
            self._cache, self._keys, self._tok = cache, keys, nxt
            plain_out = (nxt, ok)
        # -- draft + verify + accept + rollback: ONE fused dispatch --------
        # non-spec rows ride along at the modal draft width (k_eff 0 — the
        # scan restores their cells) so padding never adds a ladder branch
        fill = collections.Counter(
            self._table.get(i).spec_draft_width
            for i in spec_rows).most_common(1)[0][0]
        m_draft = np.full((self.n_slots,), fill, np.int32)
        k_eff_vec = np.zeros((self.n_slots,), np.int32)
        for idx, ke in spec_rows.items():
            m_draft[idx] = self._table.get(idx).spec_draft_width
            k_eff_vec[idx] = ke
        # steady-state macro-steps reuse the same (widths, budgets) vectors
        # step after step — cache the device copies so the hot path pays
        # zero per-step uploads (the cache stays tiny: one entry per
        # distinct draft-width mix / end-of-request budget taper)
        key = (m_draft.tobytes(), k_eff_vec.tobytes())
        dev = self._spec_arg_cache.get(key)
        if dev is None:
            if len(self._spec_arg_cache) >= 64:
                self._spec_arg_cache.clear()
            dev = (jnp.asarray(m_draft), jnp.asarray(k_eff_vec))
            self._spec_arg_cache[key] = dev
        draft_toks, pred, vok, acc, nxt_all, cache = self._spec_macro(
            self._srv.master, self._cache, self._tok,
            dev[0], self._spec_vw, bt, dev[1])
        self._cache = cache
        self._tok = nxt_all  # stays on device; the get below is books-only
        # ONE host round-trip for the whole macro-step
        if plain_out is not None:
            toks, ok, draft_h, pred_h, vok_h, acc_h = jax.device_get(
                (plain_out[0], plain_out[1], draft_toks, pred, vok, acc))
        else:
            ok = None
            toks, draft_h, pred_h, vok_h, acc_h = jax.device_get(
                (nxt_all, draft_toks, pred, vok, acc))
        accepts: Dict[int, Optional[int]] = {
            idx: (int(acc_h[idx]) if bool(vok_h[idx]) else None)
            for idx in spec_rows}  # None: keep-0 = exact restore happened
        # -- commit --------------------------------------------------------
        for idx in sorted(plain_commit):
            slot = self._table.get(idx)
            if not bool(ok[idx]):
                self._retire(idx, "poisoned", status="poisoned")
                self._m.poisoned.inc()
                continue
            self._m.slot_steps_committed.inc()
            realized = int(m_by_slot[idx]) if self._hetero else int(m)
            self._commit_token(idx, slot, int(toks[idx]), realized)
        for idx in sorted(spec_rows):
            slot = self._table.get(idx)
            ke = spec_rows[idx]
            j = accepts[idx]
            if j is None:
                # non-finite verify logits: the rollback above already
                # restored the slot to its pre-macro-step bytes (keep=0),
                # so quarantine proceeds exactly as a plain poisoned row
                self._retire(idx, "poisoned", status="poisoned")
                self._m.poisoned.inc()
                continue
            self._m.slot_steps_committed.inc()
            slot.spec_drafted += ke
            slot.spec_accepted += j
            slot.spec_rejected += ke - j
            committed = [int(draft_h[idx][i]) for i in range(j)]
            committed.append(int(pred_h[idx][j]))  # the bonus token
            realized = int(spec.verify_width)
            self._tel.spec_macro(slot.req.rid, idx, slot.spec_draft_width,
                                 ke, j, len(committed), self.clock)
            n_done = 0
            for t in committed:
                n_done += 1
                if self._commit_token(idx, slot, t, realized):
                    break  # retired; the device-side feed token is moot
            self._spec_acct.record(slot.spec_draft_width, ke, j, n_done)

    def _deadline_sweep(self) -> None:
        """Retire slots (decoding OR still prefilling) whose step budget is
        spent — partial tokens are kept."""
        for idx, slot in self._table.active():
            dl = slot.req.deadline
            if dl is not None and self.clock - slot.req.submit_step >= dl:
                self._retire(idx, "deadline", status="deadline")
                self._m.deadline_missed.inc()

    def drain(self, max_steps: Optional[int] = None
              ) -> Dict[int, FinishedRequest]:
        """Step until queue and slots are empty; returns (and clears) every
        request finished since the last drain, keyed by rid.  ``max_steps``
        is a watchdog for fault-injection harnesses: exceeding it raises
        RuntimeError instead of hanging (every injected fault must still
        terminate — the bench's no-hang check)."""
        n = 0
        while self.step():
            n += 1
            if max_steps is not None and n > max_steps:
                raise RuntimeError(
                    f"drain exceeded {max_steps} steps with {self.active} "
                    f"active / {self.pending} pending requests — "
                    f"scheduler hang?")
        out, self._finished = self._finished, {}
        return out

    def replay(self, requests,
               max_steps: Optional[int] = None) -> Dict[int, FinishedRequest]:
        """Drive the scheduler over an arrival-ordered workload and drain:
        each request is a dict of ``submit()`` kwargs plus an optional
        ``arrival`` (step-clock tick at which it becomes visible).  Idle
        gaps before the next arrival tick the clock once, so latency stats
        count real waiting.  This is THE replay loop — the serve CLI's
        JSONL mode and benchmarks/bench_serving.py both run through it, so
        the clock/idle semantics (which define the latency metrics) cannot
        diverge between them.  With a bounded queue, arrivals that
        overflow it are *rejected* (counted in ``stats['rejected']``) —
        replay models an open-loop arrival process, not a client that
        retries.  Returns ``drain()``'s {rid: FinishedRequest}."""
        reqs = sorted(requests, key=lambda r: int(r.get("arrival", 0)))
        i = 0
        n = 0
        while i < len(reqs) or self.pending or self.active:
            while (i < len(reqs)
                   and int(reqs[i].get("arrival", 0)) <= self.clock):
                kw = {k: v for k, v in reqs[i].items() if k != "arrival"}
                self.try_submit(**kw)
                i += 1
            if not self.step() and i < len(reqs):
                self.clock += 1  # idle gap before the next arrival
            n += 1
            if max_steps is not None and n > max_steps:
                raise RuntimeError(
                    f"replay exceeded {max_steps} steps with {self.active} "
                    f"active / {self.pending} pending — scheduler hang?")
        return self.drain()

    # -- internals ----------------------------------------------------------
    def _emit(self, req: Request, token: int, done: bool) -> None:
        if req.stream is not None:
            req.stream(req.rid, token, done)
        if self.on_token is not None:
            self.on_token(req.rid, token, done)

    def _retire(self, idx: int, reason: str, status: str = "ok") -> None:
        slot = self._table.retire(idx)
        self._temps[idx] = 0.0
        self._topks[idx] = 0
        if self._paged and slot.pages:
            freed: List[int] = []
            if status == "poisoned" and self._prefix is not None \
                    and slot.inserted_pages:
                # a quarantined producer's published pages may carry the
                # corruption — purge them from the prefix cache before
                # dropping the slot's own references
                freed.extend(self._prefix.purge_pages(slot.inserted_pages))
            for pid in slot.pages:
                if self._allocator.decref(pid):
                    freed.append(pid)
            self._block_table[idx, :] = 0
            self._bt_dev = None
            self._scrub(freed)
        self._m.finished.inc()
        if status == "poisoned":
            self._tel.quarantine(slot.req.rid, idx, reason, self.clock)
        spec_info = None
        if slot.spec_draft_width is not None:
            spec_info = {"draft_width": int(slot.spec_draft_width),
                         "drafted": slot.spec_drafted,
                         "accepted": slot.spec_accepted,
                         "rejected": slot.spec_rejected}
        self._finished[slot.req.rid] = FinishedRequest(
            rid=slot.req.rid,
            tokens=np.asarray(slot.emitted, np.int32),
            prompt_len=slot.req.prompt.size,
            finish_reason=reason,
            prefill_precision=slot.prefill_precision,
            decode_widths=list(slot.decode_widths),
            request_class=slot.req.request_class,
            submit_step=slot.req.submit_step,
            admit_step=slot.admit_step,
            finish_step=self.clock,
            status=status,
            spec=spec_info,
            wall=self._tel.finish_request(
                slot.req.rid, slot.req.request_class, status, reason,
                self.clock, len(slot.emitted)))

    # -- accounting ---------------------------------------------------------
    @property
    def stats(self) -> dict:
        """The scheduler's counters, as the dict shape the benches and
        tests have always consumed — now a thin VIEW over the metrics
        registry (DESIGN.md §16): every value below reads the same
        registry child ``render_prometheus()`` exposes, so the two
        surfaces cannot drift.  The snapshot is strictly
        JSON-serializable (``json_sanitize`` coerces any stray numpy
        scalar from a device readback)."""
        m = self._m
        steps = int(m.steps.value)
        active_ss = int(m.slot_steps_active.value)
        return telemetry_lib.json_sanitize({
            "steps": steps,
            "committed_tokens": int(m.committed_tokens.value),
            "admitted": int(m.admitted.value),
            "finished": int(m.finished.value),
            "pending": self.pending,
            "active": self.active,
            "rejected": int(m.rejected.value),
            "evicted": int(m.evicted.value),
            "deadline_missed": int(m.deadline_missed.value),
            "poisoned": int(m.poisoned.value),
            # mean fraction of slots occupied / committed per step
            "occupancy": active_ss / (max(steps, 1) * self.n_slots),
            "commit_rate": (int(m.slot_steps_committed.value)
                            / max(active_ss, 1)),
            "width_steps": m.width_steps_dict(),
            # committed TOKENS per realized width — the fairness tax in
            # tokens rather than batch-steps (a width-rr group can have
            # many width_steps but few tokens if its slots are sparse)
            "tokens_by_width": m.tokens_by_width_dict(),
            "starvation": self._width_policy.starvation,
            "width_policy": self._width_policy.name,
            "degradation": self._width_policy.degradation,
            "prefill_chunks": int(m.prefill_chunks.value),
            "prefill_only_steps": int(m.prefill_only_steps.value),
            "decode_stall_steps": int(m.decode_stall_steps.value),
            "pages": self._page_stats(),
            "speculative": self._spec_stats(),
        })

    def _spec_stats(self) -> Optional[dict]:
        if self._spec is None:
            return None
        return {"k": int(self._spec.k),
                "verify_width": int(self._spec.verify_width),
                "estimator": self._spec_est.name,
                **self._spec_acct.summary()}

    def _page_stats(self) -> Optional[dict]:
        if not self._paged:
            return None
        return {
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "pages_in_use": self._allocator.pages_in_use,
            "high_water": self._allocator.high_water,
            "reused_pages": int(self._m.reused_pages.value),
            "page_blocked_admissions":
                int(self._m.page_blocked_admissions.value),
            "prefix_cache": (self._prefix.stats
                             if self._prefix is not None else None),
        }

    def memory_report(self) -> dict:
        """The server's weight-memory report plus the paged KV cache's:
        bytes per page (across every stacked layer's K and V leaves),
        pages allocated now / at the high-water mark, and the bytes each
        implies — the figure the ≥2x concurrency-per-byte claim of the
        long-context bench is measured against."""
        rep = dict(self._srv.memory_report())
        if not self._paged:
            rep["kv_cache"] = {"paged": False,
                               "family": self.cfg.family}
            return rep
        per_page = sum(int(leaf.nbytes) // self.n_pages
                       for leaf in jax.tree_util.tree_leaves(
                           self._cache["pages"]))
        rep["kv_cache"] = {
            "paged": True,
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "kv_dtype": jnp.dtype(self.kv_dtype).name,
            "bytes_per_page": per_page,
            "pages_in_use": self._allocator.pages_in_use,
            "high_water": self._allocator.high_water,
            "total_bytes": per_page * self.n_pages,
            "in_use_bytes": per_page * self._allocator.pages_in_use,
            "high_water_bytes": per_page * self._allocator.high_water,
        }
        return rep
