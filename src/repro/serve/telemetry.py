"""Serving telemetry (DESIGN.md §16): one metrics registry + per-request
trace timelines for the whole serve stack.

Before this module the serve stack explained itself through scattered
ad-hoc dicts — ``scheduler.stats``, ``stats["degradation"]``, the page /
prefix-cache / speculative accounting — with no unified export and no way
to see *when and why* a slot's SEFP width changed.  This module gives the
stack three layers, all zero-dependency (stdlib only):

  * **MetricsRegistry** — counters, gauges and fixed-bucket histograms
    with label support.  Incrementally-owned metrics (the scheduler's
    step/token/admission counters) live IN the registry — registry
    children are the storage, ``scheduler.stats`` is a thin view over
    them — while live state (queue depth, pages in use, SLO shift, the
    prefix-cache and speculative accounting) is exposed through collect
    callbacks that read the owning object at scrape time, Prometheus-
    collector style.  Either way there is ONE source of truth per value.
    ``render_prometheus()`` emits text exposition format 0.0.4;
    ``serve_metrics(registry, port)`` serves it from a stdlib
    ``http.server`` daemon thread (``launch/serve.py --metrics-port``).

  * **Tracer** — a bounded ring of structured events: per-request
    timelines (submit → admission verdict → prefill chunks → decode /
    speculative macro-steps → retire, each carrying the realized SEFP
    width, slot id and page counts) plus scheduler-level events (SLO
    escalation/relief with trigger cause, quarantine, page-blocked
    admission, prefix-cache hit/evict, speculative accept/reject
    lengths).  Exportable as JSONL (one event per line) and as Chrome
    ``trace_event`` JSON — open the file in Perfetto (ui.perfetto.dev)
    and every request is a named track.

  * **Telemetry / NullTelemetry** — the facade the scheduler calls.
    ``NullTelemetry`` (the default) no-ops every hook, so an
    uninstrumented scheduler pays only the cost of its own registry
    counters (the same dict-increment class of work the old ``_counts``
    dict did).  ``Telemetry`` additionally records trace events and
    WALL-CLOCK latency: TTFT and inter-token-latency histograms per
    precision class, observed host-side from the one host sync the
    scheduler already performs per step — recording never enters the
    jitted step.  The overhead contract is pinned by
    ``benchmarks/bench_serving.py --telemetry``: tokens/s with telemetry
    on must stay >= 0.95x telemetry off.
"""

from __future__ import annotations

import json
import re
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "LATENCY_BUCKETS",
    "MetricsRegistry",
    "NullTelemetry",
    "Telemetry",
    "Tracer",
    "json_sanitize",
    "parse_prometheus",
    "render_report",
    "serve_metrics",
]


def json_sanitize(obj):
    """Coerce a stats tree to strictly JSON-serializable Python types:
    numpy/jax scalars -> int/float, arrays -> lists, Counters -> plain
    dicts, non-primitive dict keys -> their Python scalar.  Device
    readbacks must never leak numpy scalars into a ``stats`` snapshot —
    ``json.dumps(sched.stats)`` always succeeds."""
    if isinstance(obj, dict):
        out = {}
        for k, v in obj.items():
            if not isinstance(k, (str, int, float, bool, type(None))):
                k = k.item() if hasattr(k, "item") else str(k)
            out[k] = json_sanitize(v)
        return out
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    if isinstance(obj, (str, bool, int, float, type(None))):
        return obj
    nd = getattr(obj, "ndim", None)
    if nd is not None:  # numpy/jax array or scalar
        return json_sanitize(obj.item() if nd == 0 else obj.tolist())
    if hasattr(obj, "item"):
        return json_sanitize(obj.item())
    return str(obj)

# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# wall-clock latency buckets (seconds): spans interactive TTFT (~ms) out
# to CPU-bound CI decode steps; fixed at registration per the exposition
# contract (bucket sets never change across a process lifetime)
LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _escape_help(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if f != f:
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


class _Child:
    """One (metric, label-values) series.  Counters/gauges hold a scalar
    ``value``; histograms hold per-bucket counts plus sum/count.  A gauge
    child may instead carry a zero-arg callback (``set_function``) read
    at collect time — the Prometheus-collector idiom for live state whose
    source of truth is another object."""

    __slots__ = ("value", "_fn", "buckets", "bucket_counts", "sum", "count")

    def __init__(self, buckets: Optional[Tuple[float, ...]] = None):
        self.value = 0
        self._fn: Optional[Callable[[], float]] = None
        self.buckets = buckets
        if buckets is not None:
            self.bucket_counts = [0] * (len(buckets) + 1)  # +Inf last
            self.sum = 0.0
            self.count = 0

    def inc(self, n=1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v

    def set_function(self, fn: Callable[[], float]) -> None:
        self._fn = fn

    def get(self):
        return self._fn() if self._fn is not None else self.value

    def observe(self, x: float) -> None:
        x = float(x)
        self.sum += x
        self.count += 1
        for i, le in enumerate(self.buckets):
            if x <= le:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1


class MetricFamily:
    """A named metric plus its labeled children.  ``labels(**kv)`` returns
    (creating on first use) the child for those label values;
    ``child()`` is the unlabeled singleton.  ``set_collect`` installs a
    family-level callback returning ``{label_values_tuple: value}`` — used
    for dynamically-labeled live state (e.g. per-draft-width speculative
    counters) where the children are not known upfront."""

    def __init__(self, name: str, help: str, kind: str,
                 label_names: Tuple[str, ...] = (),
                 buckets: Optional[Tuple[float, ...]] = None):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r} "
                             f"(must match {_NAME_RE.pattern})")
        for ln in label_names:
            if not _LABEL_RE.match(ln) or ln.startswith("__"):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        if kind == "histogram":
            if buckets is None or not buckets:
                raise ValueError(f"histogram {name} needs fixed buckets")
            bs = tuple(float(b) for b in buckets)
            if list(bs) != sorted(set(bs)):
                raise ValueError(f"histogram {name} buckets must be "
                                 f"strictly increasing, got {buckets}")
            if "le" in label_names:
                raise ValueError(f"histogram {name}: 'le' is reserved")
            buckets = bs
        self.name = name
        self.help = help
        self.kind = kind
        self.label_names = tuple(label_names)
        self.buckets = buckets
        self._children: Dict[Tuple[str, ...], _Child] = {}
        self._collect_fn: Optional[Callable[[], Dict[tuple, float]]] = None

    def labels(self, **kv) -> _Child:
        if set(kv) != set(self.label_names):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.label_names}, got {tuple(kv)}")
        key = tuple(str(kv[ln]) for ln in self.label_names)
        ch = self._children.get(key)
        if ch is None:
            ch = _Child(self.buckets)
            self._children[key] = ch
        return ch

    def child(self) -> _Child:
        if self.label_names:
            raise ValueError(f"{self.name} is labeled; use labels()")
        return self.labels()

    def set_collect(self, fn: Callable[[], Dict[tuple, float]]) -> None:
        self._collect_fn = fn

    def samples(self) -> List[Tuple[Tuple[str, ...], object]]:
        """[(label_values, value_or_child)] — collect callbacks win."""
        if self._collect_fn is not None:
            out = []
            for key, v in sorted(self._collect_fn().items()):
                key = (key,) if isinstance(key, str) else tuple(
                    str(k) for k in key)
                out.append((key, v))
            return out
        return [(k, (ch if self.kind == "histogram" else ch.get()))
                for k, ch in sorted(self._children.items())]


class MetricsRegistry:
    """Zero-dependency metric registry with Prometheus text exposition."""

    def __init__(self):
        self._families: Dict[str, MetricFamily] = {}

    def _register(self, name, help, kind, labels, buckets=None
                  ) -> MetricFamily:
        fam = self._families.get(name)
        if fam is not None:
            if (fam.kind, fam.label_names) != (kind, tuple(labels)):
                raise ValueError(
                    f"metric {name} re-registered as {kind}{tuple(labels)} "
                    f"(was {fam.kind}{fam.label_names})")
            return fam
        fam = MetricFamily(name, help, kind, tuple(labels), buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "", labels=()) -> MetricFamily:
        return self._register(name, help, "counter", labels)

    def gauge(self, name: str, help: str = "", labels=()) -> MetricFamily:
        return self._register(name, help, "gauge", labels)

    def histogram(self, name: str, help: str = "",
                  buckets=LATENCY_BUCKETS, labels=()) -> MetricFamily:
        return self._register(name, help, "histogram", labels, buckets)

    def get(self, name: str) -> Optional[MetricFamily]:
        return self._families.get(name)

    def families(self) -> List[MetricFamily]:
        return [self._families[n] for n in sorted(self._families)]

    def value(self, name: str, **kv):
        """Current value of one series (None when absent) — the accessor
        the stats views read through."""
        fam = self._families.get(name)
        if fam is None:
            return None
        key = tuple(str(kv[ln]) for ln in fam.label_names)
        for k, v in fam.samples():
            if k == key:
                return v
        return 0 if not kv else None

    def series(self, name: str) -> Dict[Tuple[str, ...], object]:
        """{label_values: value} for every child of one family."""
        fam = self._families.get(name)
        return dict(fam.samples()) if fam is not None else {}

    def render_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        for fam in self.families():
            lines.append(f"# HELP {fam.name} {_escape_help(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, v in fam.samples():
                lbl = ",".join(
                    f'{ln}="{_escape_label(lv)}"'
                    for ln, lv in zip(fam.label_names, key))
                if fam.kind != "histogram":
                    lines.append(f"{fam.name}{{{lbl}}} {_fmt(v)}"
                                 if lbl else f"{fam.name} {_fmt(v)}")
                    continue
                ch = v
                acc = 0
                pre = lbl + "," if lbl else ""
                for le, n in zip(ch.buckets, ch.bucket_counts):
                    acc += n
                    lines.append(f'{fam.name}_bucket{{{pre}le="{_fmt(le)}"}}'
                                 f" {acc}")
                lines.append(f'{fam.name}_bucket{{{pre}le="+Inf"}} '
                             f"{ch.count}")
                lines.append(f"{fam.name}_sum{{{lbl}}} {_fmt(ch.sum)}"
                             if lbl else f"{fam.name}_sum {_fmt(ch.sum)}")
                lines.append(f"{fam.name}_count{{{lbl}}} {ch.count}"
                             if lbl else f"{fam.name}_count {ch.count}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-serializable dump: {name: {type, samples: [{labels,
        value | (sum, count, buckets)}]}}."""
        out = {}
        for fam in self.families():
            rows = []
            for key, v in fam.samples():
                labels = dict(zip(fam.label_names, key))
                if fam.kind == "histogram":
                    rows.append({"labels": labels, "sum": float(v.sum),
                                 "count": int(v.count),
                                 "buckets": dict(zip(
                                     map(_fmt, v.buckets),
                                     v.bucket_counts))})
                else:
                    rows.append({"labels": labels,
                                 "value": (float(v) if isinstance(v, float)
                                           else int(v))})
            out[fam.name] = {"type": fam.kind, "samples": rows}
        return out


def parse_prometheus(text: str) -> dict:
    """Parse a text exposition back into {metric: {type, samples:
    [(labels_dict, value)]}} — the validator the tests, the bench
    telemetry checks and the CLI's self-scrape share.  Raises ValueError
    on a malformed line, an invalid metric name, or a histogram whose
    cumulative buckets decrease."""
    out: dict = {}
    types: Dict[str, str] = {}
    sample_re = re.compile(
        r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)$")
    label_re = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, kind = rest.partition(" ")
            if not _NAME_RE.match(name):
                raise ValueError(f"line {lineno}: bad metric name {name!r}")
            types[name] = kind.strip()
            out.setdefault(name, {"type": kind.strip(), "samples": []})
            continue
        if line.startswith("#"):
            continue
        m = sample_re.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample {line!r}")
        name, lbl_str, val = m.groups()
        labels = {}
        if lbl_str:
            consumed = 0
            for lm in label_re.finditer(lbl_str):
                labels[lm.group(1)] = (
                    lm.group(2).replace("\\n", "\n")
                    .replace('\\"', '"').replace("\\\\", "\\"))
                consumed = lm.end()
            if lbl_str[consumed:].strip(", "):
                raise ValueError(f"line {lineno}: bad labels {lbl_str!r}")
        base = name
        for suf in ("_bucket", "_sum", "_count"):
            if name.endswith(suf) and name[:-len(suf)] in types:
                base = name[:-len(suf)]
                break
        out.setdefault(base, {"type": types.get(base, "untyped"),
                              "samples": []})
        out[base]["samples"].append(
            (name, labels, float(val) if val not in ("+Inf", "-Inf", "NaN")
             else float(val.replace("+", ""))))
    # histogram bucket monotonicity: cumulative counts must not decrease
    for base, fam in out.items():
        if fam["type"] != "histogram":
            continue
        series: Dict[tuple, list] = {}
        for name, labels, val in fam["samples"]:
            if not name.endswith("_bucket"):
                continue
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            series.setdefault(key, []).append(
                (float("inf") if labels["le"] == "+Inf"
                 else float(labels["le"]), val))
        for key, pts in series.items():
            pts.sort()
            vals = [v for _, v in pts]
            if any(b > a for a, b in zip(vals[1:], vals)):
                raise ValueError(
                    f"{base}{dict(key)}: non-monotonic buckets {vals}")
    return out


# ---------------------------------------------------------------------------
# the /metrics endpoint (stdlib http.server, daemon thread)
# ---------------------------------------------------------------------------

class MetricsServer:
    """Tiny scrape endpoint: GET /metrics renders the registry.  Runs in
    a daemon thread; ``port=0`` binds an ephemeral port (``.port`` has
    the real one).  ``close()`` shuts it down."""

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1"):
        import http.server

        reg = registry

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):                              # noqa: N802
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = reg.render_prometheus().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):                     # silence stderr
                pass

        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-server", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def scrape(self) -> str:
        """GET our own /metrics (the CLI's one-shot exposition check)."""
        import urllib.request
        with urllib.request.urlopen(self.url, timeout=10) as r:
            return r.read().decode()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)


def serve_metrics(registry: MetricsRegistry, port: int = 0,
                  host: str = "127.0.0.1") -> MetricsServer:
    return MetricsServer(registry, port, host)


# ---------------------------------------------------------------------------
# trace events (Chrome trace_event format; Perfetto-loadable)
# ---------------------------------------------------------------------------

TID_SCHED = 0  # the scheduler-level track; request tracks are rid + 1


class Tracer:
    """Bounded ring of Chrome ``trace_event`` dicts.  Timestamps are
    microseconds of host wall clock (perf_counter) since the tracer's
    epoch, so per-track ordering is monotonic by construction.  The ring
    (``max_events``) bounds a long-running server's memory; overflow
    drops the OLDEST events and counts them in ``dropped`` (the newest
    window is what a post-incident export wants).  Request lifecycles are
    B/E span pairs on the request's own track; everything inside them is
    instant ("i") or complete ("X") events."""

    def __init__(self, max_events: int = 65536,
                 clock: Callable[[], float] = time.perf_counter):
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        import collections
        self.max_events = int(max_events)
        self._clock = clock
        self.epoch = clock()
        self._events = collections.deque(maxlen=self.max_events)
        self._meta: Dict[int, dict] = {}   # tid -> thread_name metadata
        self.dropped = 0

    def now(self) -> float:
        """Seconds since the tracer epoch (host wall clock)."""
        return self._clock() - self.epoch

    def _push(self, ev: dict) -> None:
        if len(self._events) == self.max_events:
            self.dropped += 1
        self._events.append(ev)

    def name_track(self, tid: int, name: str) -> None:
        if tid not in self._meta:
            self._meta[tid] = {"name": "thread_name", "ph": "M", "pid": 0,
                               "tid": tid, "args": {"name": name}}

    def instant(self, name: str, tid: int, ts: Optional[float] = None,
                **args) -> None:
        self._push({"name": name, "ph": "i", "s": "t", "pid": 0, "tid": tid,
                    "ts": round((self.now() if ts is None else ts) * 1e6, 3),
                    "args": args})

    def begin(self, name: str, tid: int, **args) -> None:
        self._push({"name": name, "ph": "B", "pid": 0, "tid": tid,
                    "ts": round(self.now() * 1e6, 3), "args": args})

    def end(self, name: str, tid: int, **args) -> None:
        self._push({"name": name, "ph": "E", "pid": 0, "tid": tid,
                    "ts": round(self.now() * 1e6, 3), "args": args})

    def complete(self, name: str, tid: int, t0: float, **args) -> None:
        """An X (complete) event spanning [t0, now] (t0 from ``now()``)."""
        t1 = self.now()
        self._push({"name": name, "ph": "X", "pid": 0, "tid": tid,
                    "ts": round(t0 * 1e6, 3),
                    "dur": round(max(t1 - t0, 0.0) * 1e6, 3),
                    "args": args})

    def events(self) -> List[dict]:
        """Metadata first, then the ring in arrival (= ts) order."""
        return [self._meta[t] for t in sorted(self._meta)] \
            + list(self._events)

    def chrome_trace(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.write("\n")

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events():
                f.write(json.dumps(ev) + "\n")


def validate_trace(events: List[dict]) -> List[str]:
    """Structural validity checks for a trace export (the bench's and the
    tests' shared checker): every event has name/ph/pid/tid/ts (except M
    metadata), per-track timestamps are non-decreasing, and B/E span
    pairs match per track (no E without B, nothing left open)."""
    errs: List[str] = []
    last_ts: Dict[int, float] = {}
    open_spans: Dict[int, List[str]] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        for k in ("name", "ph", "pid", "tid", "ts"):
            if k not in ev:
                errs.append(f"event {i}: missing {k!r}")
        tid, ts = ev.get("tid"), ev.get("ts", 0.0)
        if tid in last_ts and ts < last_ts[tid]:
            errs.append(f"event {i} ({ev.get('name')}): ts {ts} < previous "
                        f"{last_ts[tid]} on tid {tid}")
        last_ts[tid] = max(ts, last_ts.get(tid, ts))
        if ph == "B":
            open_spans.setdefault(tid, []).append(ev.get("name"))
        elif ph == "E":
            stack = open_spans.get(tid) or []
            if not stack:
                errs.append(f"event {i}: E {ev.get('name')!r} on tid {tid} "
                            f"without a matching B")
            else:
                stack.pop()
    for tid, stack in open_spans.items():
        for name in stack:
            errs.append(f"tid {tid}: span {name!r} never ended")
    return errs


# ---------------------------------------------------------------------------
# the facade the scheduler drives
# ---------------------------------------------------------------------------

class NullTelemetry:
    """The no-op default: every hook is a pass, so an uninstrumented
    scheduler pays nothing beyond its own registry counters.  ``tracer``
    and ``registry`` are None — the scheduler owns its registry either
    way (metrics are always on; tracing and wall-clock latency are what
    this gates)."""

    enabled = False
    tracer: Optional[Tracer] = None
    registry: Optional[MetricsRegistry] = None

    def attach(self, registry: MetricsRegistry) -> None:
        pass

    # request lifecycle ------------------------------------------------------
    def request_submitted(self, rid, request_class, prompt_len, max_new,
                          clock) -> None:
        pass

    def request_rejected(self, queue_depth, clock) -> None:
        pass

    def request_admitted(self, rid, slot, clock, n_reused, n_pages) -> None:
        pass

    def prefill_chunk(self, rid, slot, start, n, width, clock) -> None:
        pass

    def first_token(self, rid, slot, width, clock) -> None:
        pass

    def token_committed(self, rid, slot, width, clock) -> None:
        pass

    def spec_macro(self, rid, slot, draft_width, k_eff, accepted,
                   committed, clock) -> None:
        pass

    def finish_request(self, rid, request_class, status, reason, clock,
                       n_tokens) -> Optional[dict]:
        return None

    # scheduler-level events -------------------------------------------------
    def slo_shift(self, clock, shift, prev_shift, cause) -> None:
        pass

    def quarantine(self, rid, slot, reason, clock) -> None:
        pass

    def page_blocked(self, rid, clock) -> None:
        pass

    def prefix_hit(self, rid, n_pages, clock) -> None:
        pass

    def prefix_evicted(self, n_pages, clock) -> None:
        pass

    def step_done(self, clock, seconds) -> None:
        pass


class Telemetry(NullTelemetry):
    """Full recording: trace events on a bounded Tracer plus wall-clock
    TTFT / inter-token-latency histograms per precision class.  All
    host-side: the hooks fire from the scheduler's existing host
    bookkeeping, never inside the jitted step, and only consume what the
    one host sync per step already transferred."""

    enabled = True

    def __init__(self, trace: bool = True, max_events: int = 65536):
        self.tracer = Tracer(max_events=max_events) if trace else None
        self.registry: Optional[MetricsRegistry] = None
        self._ttft = None
        self._itl = None
        self._step_hist = None
        # rid -> [class, submit_s, first_s, last_s, n_tokens]
        self._live: Dict[int, list] = {}
        self._t0 = time.perf_counter()

    def _now(self) -> float:
        return (self.tracer.now() if self.tracer is not None
                else time.perf_counter() - self._t0)

    def attach(self, registry: MetricsRegistry) -> None:
        """Bind the latency histograms to the scheduler's registry (the
        scheduler calls this once, at construction)."""
        self.registry = registry
        self._ttft = registry.histogram(
            "otaro_serve_ttft_seconds",
            "Wall-clock time to first token, submit to first emit",
            labels=("request_class",))
        self._itl = registry.histogram(
            "otaro_serve_itl_seconds",
            "Wall-clock inter-token latency between committed tokens",
            labels=("request_class",))
        self._step_hist = registry.histogram(
            "otaro_serve_step_seconds",
            "Wall-clock scheduler step duration (host-observed)")

    # -- request lifecycle ---------------------------------------------------
    def request_submitted(self, rid, request_class, prompt_len, max_new,
                          clock) -> None:
        t = self._now()
        self._live[rid] = [request_class, t, None, None, 0]
        tr = self.tracer
        if tr is not None:
            tid = rid + 1
            tr.name_track(TID_SCHED, "scheduler")
            tr.name_track(tid, f"req {rid} [{request_class or 'default'}]")
            tr.begin("request", tid, rid=rid,
                     request_class=request_class, prompt_len=int(prompt_len),
                     max_new=int(max_new), clock=int(clock))

    def request_rejected(self, queue_depth, clock) -> None:
        if self.tracer is not None:
            self.tracer.instant("rejected", TID_SCHED,
                                queue_depth=int(queue_depth),
                                clock=int(clock))

    def request_admitted(self, rid, slot, clock, n_reused, n_pages) -> None:
        if self.tracer is not None:
            self.tracer.instant("admitted", rid + 1, slot=int(slot),
                                clock=int(clock), reused_pages=int(n_reused),
                                pages=int(n_pages))

    def prefill_chunk(self, rid, slot, start, n, width, clock) -> None:
        if self.tracer is not None:
            self.tracer.instant("prefill_chunk", rid + 1, slot=int(slot),
                                start=int(start), tokens=int(n),
                                width=int(width), clock=int(clock))

    def first_token(self, rid, slot, width, clock) -> None:
        t = self._now()
        rec = self._live.get(rid)
        if rec is not None:
            rec[2] = rec[3] = t
            rec[4] += 1
            if self._ttft is not None:
                self._ttft.labels(
                    request_class=rec[0] or "default").observe(t - rec[1])
        if self.tracer is not None:
            self.tracer.instant("first_token", rid + 1, slot=int(slot),
                                width=int(width), clock=int(clock))

    def token_committed(self, rid, slot, width, clock) -> None:
        t = self._now()
        rec = self._live.get(rid)
        if rec is not None:
            if rec[3] is not None and self._itl is not None:
                self._itl.labels(
                    request_class=rec[0] or "default").observe(t - rec[3])
            rec[3] = t
            rec[4] += 1
        if self.tracer is not None:
            self.tracer.instant("token", rid + 1, slot=int(slot),
                                width=int(width), clock=int(clock))

    def spec_macro(self, rid, slot, draft_width, k_eff, accepted,
                   committed, clock) -> None:
        if self.tracer is not None:
            self.tracer.instant("spec_macro", rid + 1, slot=int(slot),
                                draft_width=int(draft_width),
                                drafted=int(k_eff), accepted=int(accepted),
                                rejected=int(k_eff - accepted),
                                committed=int(committed), clock=int(clock))

    def finish_request(self, rid, request_class, status, reason, clock,
                       n_tokens) -> Optional[dict]:
        t = self._now()
        rec = self._live.pop(rid, None)
        if self.tracer is not None:
            self.tracer.end("request", rid + 1, status=status,
                            reason=reason, clock=int(clock),
                            tokens=int(n_tokens))
        if rec is None:
            return None
        _, submit_s, first_s, last_s, n = rec
        ttft = (first_s - submit_s) if first_s is not None else None
        itl = (((last_s - first_s) / (n - 1))
               if (first_s is not None and n > 1) else None)
        return {"submit_s": submit_s, "first_token_s": first_s,
                "finish_s": t, "ttft_s": ttft, "itl_mean_s": itl}

    # -- scheduler-level events ----------------------------------------------
    def slo_shift(self, clock, shift, prev_shift, cause) -> None:
        if self.tracer is not None:
            self.tracer.instant(
                "slo_escalation" if shift > prev_shift else "slo_relief",
                TID_SCHED, shift=int(shift), prev_shift=int(prev_shift),
                cause=cause, clock=int(clock))

    def quarantine(self, rid, slot, reason, clock) -> None:
        if self.tracer is not None:
            self.tracer.instant("quarantine", TID_SCHED, rid=int(rid),
                                slot=int(slot), reason=reason,
                                clock=int(clock))

    def page_blocked(self, rid, clock) -> None:
        if self.tracer is not None:
            self.tracer.instant("page_blocked_admission", TID_SCHED,
                                rid=int(rid), clock=int(clock))

    def prefix_hit(self, rid, n_pages, clock) -> None:
        if self.tracer is not None:
            self.tracer.instant("prefix_hit", TID_SCHED, rid=int(rid),
                                pages=int(n_pages), clock=int(clock))

    def prefix_evicted(self, n_pages, clock) -> None:
        if self.tracer is not None:
            self.tracer.instant("prefix_evict", TID_SCHED,
                                pages=int(n_pages), clock=int(clock))

    def step_done(self, clock, seconds) -> None:
        if self._step_hist is not None:
            self._step_hist.child().observe(seconds)


# ---------------------------------------------------------------------------
# scheduler metric handles (the _counts migration target)
# ---------------------------------------------------------------------------

class SchedulerMetrics:
    """The ContinuousScheduler's registry-backed counters — the ONE
    source of truth behind ``scheduler.stats`` (which is now a thin view
    over these children).  Pre-resolved children keep the hot path at
    dict-increment cost; width-labeled families cache children by int
    width."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        c = registry.counter
        self.steps = c("otaro_serve_steps_total",
                       "Scheduler steps run").child()
        self.committed_tokens = c("otaro_serve_committed_tokens_total",
                                  "Tokens committed across slots").child()
        self.slot_steps_active = c(
            "otaro_serve_slot_steps_active_total",
            "Slot-steps with an active decode-phase request").child()
        self.slot_steps_committed = c(
            "otaro_serve_slot_steps_committed_total",
            "Slot-steps that committed").child()
        self.requests = c("otaro_serve_requests_total",
                          "Request lifecycle events",
                          labels=("event",))
        self.admitted = self.requests.labels(event="admitted")
        self.finished = self.requests.labels(event="finished")
        self.rejected = self.requests.labels(event="rejected")
        self.evicted = self.requests.labels(event="evicted")
        self.deadline_missed = self.requests.labels(event="deadline_missed")
        self.poisoned = self.requests.labels(event="poisoned")
        self.prefill_chunks = c("otaro_serve_prefill_chunks_total",
                                "Chunked-prefill chunks run").child()
        self.prefill_only_steps = c(
            "otaro_serve_prefill_only_steps_total",
            "Steps that only advanced a prefill (no decode)").child()
        self.decode_stall_steps = c(
            "otaro_serve_decode_stall_steps_total",
            "Steps where decode stalled behind a prefill").child()
        self.reused_pages = c("otaro_serve_reused_pages_total",
                              "Prefix-cache pages adopted at admission"
                              ).child()
        self.page_blocked_admissions = c(
            "otaro_serve_page_blocked_admissions_total",
            "Admissions blocked on the page budget").child()
        self._width_steps = c("otaro_serve_width_steps_total",
                              "Steps that served SEFP width",
                              labels=("width",))
        self._tokens_by_width = c(
            "otaro_serve_tokens_by_width_total",
            "Committed tokens per realized SEFP width",
            labels=("width",))
        self._ws_cache: Dict[int, _Child] = {}
        self._tbw_cache: Dict[int, _Child] = {}

    def width_step(self, w: int) -> None:
        ch = self._ws_cache.get(w)
        if ch is None:
            ch = self._width_steps.labels(width=str(int(w)))
            self._ws_cache[w] = ch
        ch.inc()

    def token_at_width(self, w: int) -> None:
        ch = self._tbw_cache.get(w)
        if ch is None:
            ch = self._tokens_by_width.labels(width=str(int(w)))
            self._tbw_cache[w] = ch
        ch.inc()

    def width_steps_dict(self) -> Dict[int, int]:
        return {int(k[0]): int(v)
                for k, v in self._width_steps.samples()}

    def tokens_by_width_dict(self) -> Dict[int, int]:
        return {int(k[0]): int(v)
                for k, v in self._tokens_by_width.samples()}

    def register_gauges(self, sched) -> None:
        """Expose the scheduler's LIVE state (queue, slots, pages, SLO
        shift, prefix cache, speculative accounting) as collect-time
        gauges — the collector idiom: the owning object stays the source
        of truth, the registry reads it at scrape time."""
        r = self.registry
        r.gauge("otaro_serve_queue_depth",
                "Requests waiting in the FIFO queue"
                ).child().set_function(lambda: sched.pending)
        r.gauge("otaro_serve_active_slots",
                "Slots holding an admitted request"
                ).child().set_function(lambda: sched.active)
        r.gauge("otaro_serve_slots", "Slot table size"
                ).child().set(sched.n_slots)
        pol = sched._width_policy
        r.gauge("otaro_serve_slo_shift",
                "Current SLO degradation shift (0 = healthy)"
                ).child().set_function(
                    lambda: int(getattr(pol, "shift", 0) or 0))
        r.gauge("otaro_serve_latency_ewma_seconds",
                "Step-latency EWMA the slo-degrade trigger watches"
                ).child().set_function(
                    lambda: float(pol.degradation.get(
                        "latency_ewma_seconds") or 0.0)
                    if pol.degradation else 0.0)
        if sched._allocator is not None:
            alloc = sched._allocator
            r.gauge("otaro_serve_pages_in_use",
                    "KV pages currently referenced"
                    ).child().set_function(lambda: alloc.pages_in_use)
            r.gauge("otaro_serve_pages_high_water",
                    "Peak KV pages referenced"
                    ).child().set_function(lambda: alloc.high_water)
            r.gauge("otaro_serve_pages", "KV page pool size (incl. null)"
                    ).child().set(sched.n_pages)
        if sched._prefix is not None:
            pc = sched._prefix
            fam = r.counter("otaro_serve_prefix_cache_events_total",
                            "Prefix-cache hit/miss/insert/evict counts",
                            labels=("event",))
            fam.set_collect(lambda: {
                ("hits",): pc.hits, ("misses",): pc.misses,
                ("inserted",): pc.inserted, ("evicted",): pc.evicted})
        if sched._spec is not None:
            acct = sched._spec_acct
            for nm, field in (("drafted", "drafted"),
                              ("accepted", "accepted"),
                              ("rejected", "rejected")):
                fam = r.counter(f"otaro_spec_{nm}_total",
                                f"Speculative tokens {nm}, per draft width",
                                labels=("width",))
                fam.set_collect(
                    lambda d=field: {(str(w),): v for w, v in
                                     getattr(acct, d).items()})
            r.counter("otaro_spec_macro_steps_total",
                      "Speculative macro-steps run"
                      ).child().set_function(lambda: acct.macro_steps)
            r.counter("otaro_spec_bonus_tokens_total",
                      "Verifier bonus tokens committed"
                      ).child().set_function(lambda: acct.bonus_tokens)


# ---------------------------------------------------------------------------
# report rendering (the CLI summary, one aggregation path)
# ---------------------------------------------------------------------------

def render_report(sched) -> List[str]:
    """The serving summary lines (pages/reuse, width mix, tokens-by-width,
    resilience, speculative, degradation), rendered from the scheduler's
    registry-backed stats view — launch/serve.py prints these instead of
    re-aggregating the same counters with bespoke formatting."""
    stats = sched.stats
    lines: List[str] = []
    pg = stats["pages"]
    if pg is not None:
        pc = pg["prefix_cache"]
        reuse = (f", prefix hits {pc['hits']}/{pc['hits'] + pc['misses']}"
                 if pc is not None else "")
        lines.append(
            f"pages: high-water {pg['high_water']}/{pg['n_pages']}"
            f", reused {pg['reused_pages']}{reuse}, "
            f"prefill chunks {stats['prefill_chunks']}, "
            f"decode stalls {stats['decode_stall_steps']}")
    lines.append(f"width steps: {stats['width_steps']}  "
                 f"starvation: {stats['starvation']}  "
                 f"policy: {stats['width_policy']}")
    tbw = stats["tokens_by_width"]
    if tbw:
        lines.append(
            "tokens by width: "
            + ", ".join(f"E5M{w}: {tbw[w]}" for w in sorted(tbw,
                                                            reverse=True))
            + f"  (committed {stats['committed_tokens']})")
    if (stats["rejected"] or stats["evicted"] or stats["deadline_missed"]
            or stats["poisoned"]):
        lines.append(f"resilience: rejected={stats['rejected']} "
                     f"evicted={stats['evicted']} "
                     f"deadline_missed={stats['deadline_missed']} "
                     f"poisoned={stats['poisoned']}")
    sp = stats.get("speculative")
    if sp is not None:
        rate = (f"{sp['acceptance_rate']:.2f}"
                if sp["acceptance_rate"] is not None else "-")
        lines.append(
            f"speculative: k={sp['k']} estimator={sp['estimator']} "
            f"macro_steps={sp['macro_steps']} drafted={sp['drafted']} "
            f"accepted={sp['accepted']} wasted={sp['wasted']} "
            f"bonus={sp['bonus_tokens']} acceptance={rate}")
    deg = stats["degradation"]
    if deg.get("escalations"):
        lines.append(f"degradation: escalations={deg['escalations']} "
                     f"degraded_steps={deg['degraded_steps']} "
                     f"downshifted_slot_steps={deg['downshifted_slot_steps']}"
                     f" final_shift={deg['shift']} "
                     f"max_shift_seen={deg['max_shift_seen']}")
    tel = getattr(sched, "telemetry", None)
    reg = getattr(sched, "metrics", None)
    if tel is not None and tel.enabled and reg is not None:
        for cls, ch in sorted(reg.series(
                "otaro_serve_ttft_seconds").items()):
            if ch.count:
                itl = reg.value("otaro_serve_itl_seconds",
                                request_class=cls[0])
                itl_ms = (f", itl mean {itl.sum / itl.count * 1e3:.2f} ms"
                          if itl is not None and itl.count else "")
                lines.append(
                    f"latency[{cls[0]}]: ttft mean "
                    f"{ch.sum / ch.count * 1e3:.2f} ms over {ch.count} "
                    f"request(s){itl_ms}")
    return lines
