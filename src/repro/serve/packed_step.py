"""Packed-weight decode step: SEFP weight streaming at the HLO level.

The baseline decode step streams bf16 weights (16 bits/param).  This variant
keeps the big per-layer weights in SEFP int8 codes (+ per-64-group int8
exponents ≈ 8.125 bits/param) and dequantizes EACH LAYER'S SLICE inside the
scan body, so the int8->bf16 convert + group-scale multiply sit right next
to their consuming matmuls (XLA fuses elementwise producers into dot
operands) and HBM weight traffic drops ~2x.  This is the XLA-level
realization of the paper's Table 2 mechanism; the Pallas kernel
(repro/kernels/sefp_matmul) is the fully-fused TPU form with runtime
mantissa truncation on top.

Supports the dense/vlm/moe families (scan-over-layers with attention KV
caches).  Serving precision m <= 7 (int8 two's-complement codes).  Used by
the dry-run's "packed" variant (hillclimb cell C) and covered by
tests/test_serving.py.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import sefp
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig

PACK_KEY = "sefp_codes"


def _eligible(name: str, leaf, min_size: int) -> bool:
    # per-layer stacked weights [L, K, N] (or [L, E, K, N] for MoE experts)
    # plus the unembed head [d, V]; the input embedding stays unpacked (it
    # is gathered, not matmul'd).
    if not (hasattr(leaf, "ndim") and leaf.ndim >= 2
            and leaf.dtype in (jnp.float32, jnp.bfloat16)
            and leaf.shape[-2] % sefp.GROUP_SIZE == 0
            and leaf.size >= min_size):
        return False
    if name.endswith("w_unembed"):
        return True
    return leaf.ndim >= 3


def pack_leaf(w: jax.Array, m: int) -> dict:
    """Quantize [..., K, N] along K into int8 codes + int8 group exps."""
    *lead, K, N = w.shape
    g = w.astype(jnp.float32).reshape(*lead, K // sefp.GROUP_SIZE,
                                      sefp.GROUP_SIZE, N)
    e = jnp.clip(sefp.floor_log2(g).max(axis=-2, keepdims=True),
                 sefp.EXP_MIN, sefp.EXP_MAX)
    quantum = sefp.exp2i(e - (m - 1))
    maxmag = float(2 ** m - 1)
    codes = jnp.clip(jnp.round(g / quantum), -maxmag, maxmag)
    return {PACK_KEY: codes.astype(jnp.int8).reshape(*lead, K, N),
            "exp": e.astype(jnp.int8).reshape(*lead, K // sefp.GROUP_SIZE,
                                              N)}


def dequant_leaf(packed: dict, m: int, dtype=jnp.bfloat16) -> jax.Array:
    codes = packed[PACK_KEY]
    e = packed["exp"].astype(jnp.int32)
    quantum = sefp.exp2i(e - (m - 1))
    quantum = jnp.repeat(quantum, sefp.GROUP_SIZE, axis=-2)
    return (codes.astype(jnp.float32) * quantum).astype(dtype)


def _is_packed(x) -> bool:
    return isinstance(x, dict) and PACK_KEY in x


def pack_params(params: Any, m: int = 7, min_size: int = 1 << 16) -> Any:
    """Pack every eligible stacked weight; other leaves stay as-is (cast to
    bf16 if float32, matching the deployed dtype).  The serving width m is
    baked in (int8 codes); runtime truncation below m is still free via
    code >> k (the master path in core/packed.py keeps the full M8)."""

    def visit(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if _eligible(name, leaf, min_size):
            return pack_leaf(leaf, m)
        if hasattr(leaf, "dtype") and leaf.dtype == jnp.float32:
            return leaf.astype(jnp.bfloat16)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def dequant_tree(tree: Any, m: int, dtype=jnp.bfloat16) -> Any:
    return jax.tree_util.tree_map(
        lambda x: dequant_leaf(x, m, dtype) if _is_packed(x) else x,
        tree, is_leaf=_is_packed)


def make_packed_serve_step(cfg: ModelConfig, m: int = 7):
    """serve(packed_params, cache, token) -> (logits, cache): per-layer
    in-scan dequant so only int8 codes stream from HBM."""
    if cfg.family not in ("dense", "vlm", "moe"):
        raise NotImplementedError(
            "packed serving currently targets attention-family stacks")
    dt = jnp.bfloat16

    def serve(params, cache, token):
        x = L.embed(params["embed"], token[:, None], dt)
        pos = cache["pos"]

        def body(xc, inp):
            lp_packed, lcache = inp
            lp = dequant_tree(lp_packed, m, dt)  # this layer's slice only
            xc, nc = T.attn_layer_decode(lp, xc, lcache, cfg, pos)
            return xc, nc

        x, new_layers = lax.scan(body, x, (params["layers"],
                                           cache["layers"]))
        h = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        unemb = dequant_tree(params["unembed"], m, dt)
        logits = L.logits_for_last(h, unemb)
        return logits, {**cache, "layers": new_layers, "pos": pos + 1}

    return serve


def packed_param_shapes(cfg: ModelConfig, m: int = 7) -> Any:
    """ShapeDtypeStruct tree of the packed serving params (dry-run)."""
    from repro.models import model_zoo as Z

    def build():
        params = Z.init_params(cfg, jax.random.PRNGKey(0))
        return pack_params(params, m)

    return jax.eval_shape(build)
