"""Packed-master serving steps: SEFP weight streaming with traced precision.

The serving weight representation is the E5M8 PackedSEFP master from
repro/core/packed.py in its *stacked* layout: every eligible weight becomes
``{"mag" uint8 [..., K, N], "sign" uint8 [..., K//8, N],
"exp" int8 [..., K//64, N]}`` (~9.1 bits/param), grouped along the
contraction axis.  The decode and prefill steps below run the ordinary
model assembly (repro/models/transformer.py) with a ``resolve`` hook that
dequantizes EACH LAYER'S SLICE inside the scan body at a *traced* mantissa
width m:

  * only packed bytes stream from HBM — the uint8->bf16 convert, the sign
    unpack and the group-quantum multiply sit right next to their consuming
    matmuls, and XLA fuses them into the dot operands (~2x less weight
    traffic than bf16, the paper's Table 2 mechanism);
  * ``m`` enters only through ``mag >> (8-m)`` and ``2^(E*-(m-1))`` — cheap
    in-graph scalars — so ONE compiled step serves every precision and a
    precision switch (even mid-generation, via the engine's traced schedule)
    moves zero bytes and recompiles nothing (the §3 traced-m property);
  * the unembed projection — the largest single decode matmul — can be
    routed through the decode-shaped ``sefp_matmul_gemv`` kernel
    (repro/kernels/sefp_matmul), the fully-fused TPU form that truncates in
    VMEM registers.

Supports every LM family (dense/vlm/moe/rwkv/hybrid); enc-dec serving is
not wired up (the engine never supported it).  The decode step is
position-shape polymorphic: ``cache["pos"]`` may be the lockstep scalar or
the continuous batcher's per-slot ``int32[B]`` (repro/serve/scheduler.py) —
the same step function traces once per cache shape and the packed-master
dequant is identical in both.  Used by the switchable serving engine
(repro/serve/engine.py), the continuous scheduler, the dry-run's "packed"
variant (hillclimb cell C) and covered by tests/test_packed_step.py.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import packed as packed_lib
from repro.core import sefp
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig


def _eligible(name: str, leaf, min_size: int) -> bool:
    # per-layer stacked weights [L, K, N] (or [L, E, K, N] for MoE experts,
    # [nshared, ...] for hybrid shared blocks) plus the unembed head [d, V];
    # the input embedding stays unpacked (it is gathered, not matmul'd) and
    # the SSM/RWKV recurrence + norm/bias leaves keep full precision
    # (sefp.DEFAULT_EXCLUDE, DESIGN.md §5).
    if not (hasattr(leaf, "ndim") and leaf.ndim >= 2
            and leaf.dtype in (jnp.float32, jnp.bfloat16)
            and leaf.shape[-2] % sefp.GROUP_SIZE == 0
            and leaf.size >= min_size):
        return False
    for s in sefp.DEFAULT_EXCLUDE:
        if s in name:
            return False
    if name.endswith("w_unembed"):
        return True
    return leaf.ndim >= 3


def pack_master_params(params: Any, min_size: int = 4096) -> Any:
    """Pack every eligible weight to the stacked E5M8 master; other leaves
    stay as-is (cast to bf16 if float32, matching the deployed dtype).  The
    result is the single multi-precision serving artifact: every width
    E5M8..E5M3 is a runtime truncation of it."""

    def visit(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if _eligible(name, leaf, min_size):
            return packed_lib.pack_stacked(leaf)
        if hasattr(leaf, "dtype") and leaf.dtype == jnp.float32:
            return leaf.astype(jnp.bfloat16)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def dequant_master_tree(tree: Any, m, dtype=jnp.bfloat16) -> Any:
    """Dequantize every master leaf at (possibly traced) width m."""
    return packed_lib.dequantize_master_tree(tree, m, dtype)


def master_logits(h_last, unembed, m, kernel_backend: str | None = None):
    """Decode head over the packed master: h_last [B,1,d] -> logits [B,V]
    f32 with on-the-fly truncation to width m.

    ``kernel_backend=None`` is the portable XLA path (dequant fused into the
    f32 dot, matching the unpacked ``logits_for_last`` head).  Naming a
    backend registered with repro.kernels.dispatch routes the projection —
    a tall-skinny gemv, the largest single decode matmul — through the
    ``sefp_matmul_gemv`` kernel op instead.  NOTE: this adopts the kernel
    contract (x AND w rounded to bf16, the MXU input precision, with fp32
    accumulation), so it is a *numerics* choice at the logit head, not pure
    routing — near-tied logits may argmax differently across the two paths.
    Each path is internally consistent (fused scan == per-token loop,
    asserted per backend in tests/test_serving.py)."""
    w = unembed["w_unembed"]
    if not packed_lib.is_master_leaf(w):
        return L.logits_for_last(h_last, unembed)
    if kernel_backend is None:
        wq = packed_lib.dequantize_stacked(w, m, dtype=jnp.float32)
        return h_last[:, 0].astype(jnp.float32) @ wq
    from repro.kernels.sefp_matmul import sefp_matmul_gemv
    return sefp_matmul_gemv(h_last[:, 0], packed_lib.packed_view(w), m,
                            backend=kernel_backend)


def master_logits_hetero(h_last, unembed, m_rows, widths,
                         kernel_backend: str | None = None):
    """``master_logits`` with a PER-ROW width vector: logits row i is
    projected at width ``m_rows[i]`` (int32 [B]); ``widths`` is the static
    candidate ladder.

    The XLA path sweeps the ladder exactly like the model-side hetero
    sweep — dequantize at each present scalar width, f32 dot, row-masked
    merge — so row i is bitwise what the scalar ``master_logits`` produces
    for that row at ``m = m_rows[i]``.  A named kernel backend routes
    through ``sefp_matmul_gemv_hetero``, whose rows are bitwise the scalar
    ``sefp_matmul_gemv`` at the matching width (same contract caveats as
    ``master_logits``)."""
    w = unembed["w_unembed"]
    if not packed_lib.is_master_leaf(w):
        return L.logits_for_last(h_last, unembed)
    if kernel_backend is None:
        from jax import lax
        h = h_last[:, 0].astype(jnp.float32)
        acc = jnp.zeros((h.shape[0], w["mag"].shape[-1]), jnp.float32)
        for wd in widths:
            rmask = m_rows == wd

            def one(wd=wd):
                wq = packed_lib.dequantize_stacked(w, jnp.int32(wd),
                                                   dtype=jnp.float32)
                return h @ wq

            out = lax.cond(jnp.any(rmask), one, lambda: acc)
            acc = jnp.where(rmask[:, None], out, acc)
        return acc
    from repro.kernels.sefp_matmul import sefp_matmul_gemv_hetero
    return sefp_matmul_gemv_hetero(h_last[:, 0], packed_lib.packed_view(w),
                                   m_rows, widths=widths,
                                   backend=kernel_backend)


def master_logits_all(h, unembed, m, kernel_backend: str | None = None):
    """``master_logits`` at EVERY position: h [B,S,d] -> logits [B,S,V]
    f32.  Row (b, s) is the same projection program as ``master_logits``
    run on that row alone — the speculative verify head, where the
    verifier needs the next-token distribution after each of the k+1
    candidate positions in one dispatch."""
    w = unembed["w_unembed"]
    if not packed_lib.is_master_leaf(w):
        wq = w.astype(jnp.float32)
        return h.astype(jnp.float32) @ wq
    if kernel_backend is None:
        wq = packed_lib.dequantize_stacked(w, m, dtype=jnp.float32)
        return h.astype(jnp.float32) @ wq
    from repro.kernels.sefp_matmul import sefp_matmul_gemv
    B, S, d = h.shape
    flat = sefp_matmul_gemv(h.reshape(B * S, d), packed_lib.packed_view(w),
                            m, backend=kernel_backend)
    return flat.reshape(B, S, -1)


def _auto_layer_unroll(cfg: ModelConfig, layer_unroll: int | None) -> int:
    """Decode layer-loop unroll factor.  Per-step compute is tiny, so on
    CPU (per-iteration loop overhead, no HLO-size pressure) the layer loop
    unrolls fully and XLA fuses across layers — ~3x step latency on the
    serving bench; on TPU the scan stays rolled (one layer's HLO regardless
    of depth, the dry-run compile-tractability requirement)."""
    if layer_unroll is not None:
        return max(1, int(layer_unroll))
    return cfg.n_layers if jax.default_backend() == "cpu" else 1


def make_master_serve_step(cfg: ModelConfig,
                           kernel_backend: str | None = None,
                           layer_unroll: int | None = None):
    """serve(master, cache, token[B] int32, m int32) -> (logits, cache):
    one decode step directly from the packed master, dequantizing each
    layer's slice in-scan at traced width m."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "packed-master serving covers the LM families; enc-dec decode "
            "caches are built from encoder output (models/encdec.py)")
    dt = jnp.bfloat16
    unroll = _auto_layer_unroll(cfg, layer_unroll)

    def serve(master, cache, token, m):
        def resolve(layer_slice):
            return dequant_master_tree(layer_slice, m, dt)

        x = L.embed(master["embed"], token[:, None], dt)
        h, cache = T.lm_decode_hidden(master, x, cache, cfg, resolve=resolve,
                                      layer_unroll=unroll)
        logits = master_logits(h, master["unembed"], m, kernel_backend)
        return logits, cache

    return serve


def make_master_serve_step_hetero(cfg: ModelConfig, widths,
                                  kernel_backend: str | None = None,
                                  layer_unroll: int | None = None):
    """serve(master, cache, token[B] int32, m_rows int32[B]) ->
    (logits, cache): one WIDTH-HETEROGENEOUS decode step — slot i is
    dequantized, attended and projected at its own width ``m_rows[i]``,
    bitwise identical to serving that row in a lockstep batch at the
    scalar width.  ``widths`` is the static candidate ladder the step is
    compiled for; the embedding is gathered unpacked (width-free), so only
    matmul-consuming weights sweep the ladder."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "packed-master serving covers the LM families")
    dt = jnp.bfloat16
    unroll = _auto_layer_unroll(cfg, layer_unroll)
    widths = tuple(widths)

    def serve(master, cache, token, m_rows):
        def resolve(layer_slice, w):
            return dequant_master_tree(layer_slice, jnp.int32(w), dt)

        x = L.embed(master["embed"], token[:, None], dt)
        h, cache = T.lm_decode_hidden(master, x, cache, cfg,
                                      resolve=resolve, layer_unroll=unroll,
                                      hetero=(m_rows, widths))
        logits = master_logits_hetero(h, master["unembed"], m_rows, widths,
                                      kernel_backend)
        return logits, cache

    return serve


def make_master_prefill(cfg: ModelConfig,
                        kernel_backend: str | None = None):
    """prefill(master, tokens [B,S], m, max_len) -> (last_logits, cache),
    with the same in-scan per-layer dequant as the decode step — no weight
    tree is ever materialized at any width."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "packed-master serving covers the LM families")
    dt = jnp.bfloat16

    def prefill(master, tokens, m, max_len: int):
        def resolve(layer_slice):
            return dequant_master_tree(layer_slice, m, dt)

        x = L.embed(master["embed"], tokens, dt)
        h, cache = T.lm_prefill_hidden(master, x, cfg, max_len,
                                       resolve=resolve)
        logits = master_logits(h[:, -1:], master["unembed"], m,
                               kernel_backend)
        return logits, cache

    return prefill


def make_master_serve_step_paged(cfg: ModelConfig,
                                 kernel_backend: str | None = None,
                                 layer_unroll: int | None = None,
                                 page_size: int = 16):
    """serve(master, cache, token[B] int32, m int32, block_table
    int32[B, max_pages]) -> (logits, cache): one continuous decode step
    against the PAGED KV cache (serve/pages.py) — each row reads/writes
    its attention KV through its block-table row; the traced-m dequant is
    identical to the dense step.  rwkv has no attention KV, so its step
    ignores the block table (one uniform signature for the scheduler)."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "packed-master serving covers the LM families")
    dt = jnp.bfloat16
    unroll = _auto_layer_unroll(cfg, layer_unroll)

    def serve(master, cache, token, m, block_table):
        def resolve(layer_slice):
            return dequant_master_tree(layer_slice, m, dt)

        x = L.embed(master["embed"], token[:, None], dt)
        h, cache = T.lm_decode_hidden_paged(
            master, x, cache, block_table, cfg, resolve=resolve,
            layer_unroll=unroll, page_size=page_size)
        logits = master_logits(h, master["unembed"], m, kernel_backend)
        return logits, cache

    return serve


def make_master_serve_step_hetero_paged(cfg: ModelConfig, widths,
                                        kernel_backend: str | None = None,
                                        layer_unroll: int | None = None,
                                        page_size: int = 16):
    """serve(master, cache, token[B] int32, m_rows int32[B], block_table
    int32[B, max_pages]) -> (logits, cache): the width-heterogeneous
    decode step against the PAGED KV cache — every active slot advances
    one token at its OWN width in a single fused step (the scheduler's
    ``heterogeneous`` policy), each row bitwise its lockstep run at that
    width.  The signature matches ``make_master_serve_step_paged`` with
    ``m`` widened to ``int32[B]``, so the continuous stepper wraps it
    unchanged."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "packed-master serving covers the LM families")
    dt = jnp.bfloat16
    unroll = _auto_layer_unroll(cfg, layer_unroll)
    widths = tuple(widths)

    def serve(master, cache, token, m_rows, block_table):
        def resolve(layer_slice, w):
            return dequant_master_tree(layer_slice, jnp.int32(w), dt)

        x = L.embed(master["embed"], token[:, None], dt)
        h, cache = T.lm_decode_hidden_paged(
            master, x, cache, block_table, cfg, resolve=resolve,
            layer_unroll=unroll, page_size=page_size,
            hetero=(m_rows, widths))
        logits = master_logits_hetero(h, master["unembed"], m_rows, widths,
                                      kernel_backend)
        return logits, cache

    return serve


def make_master_draft_scan_paged(cfg: ModelConfig, widths, k_max: int,
                                 kernel_backend: str | None = None,
                                 layer_unroll: int | None = None,
                                 page_size: int = 16):
    """draft(master, cache, tok [B] int32, m_rows int32[B], block_table,
    k_eff int32[B]) -> (draft_toks int32[B, k_max], new_cache): the
    speculative DRAFT phase as ONE fused dispatch — a lax.scan of k_max
    width-heterogeneous greedy decode sub-steps (each row truncating the
    shared packed master at its own draft width ``m_rows[b]``), feeding
    each row's argmax back on-device.  Row b participates in sub-step i
    only while ``i < k_eff[b]``: masked rows' KV cell and position are
    restored per sub-step (slots.select_paged), so plain rows and
    exhausted drafts ride the fixed-shape dispatch untouched.  Draft
    sub-step i writes row b's KV cell ``pos[b] + i`` at the DRAFT width —
    the verify step overwrites every one of them at full width, so
    low-width bytes never outlive the macro-step.  Greedy only: the draft
    proposes, the verifier disposes, so draft sampling needs no PRNG."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "packed-master serving covers the LM families")
    from repro.serve import slots as slots_lib
    dt = jnp.bfloat16
    unroll = _auto_layer_unroll(cfg, layer_unroll)
    widths = tuple(widths)
    k_max = int(k_max)

    def draft(master, cache, tok, m_rows, block_table, k_eff):
        def resolve(layer_slice, w):
            return dequant_master_tree(layer_slice, jnp.int32(w), dt)

        def body(carry, i):
            cache, tok = carry
            x = L.embed(master["embed"], tok[:, None], dt)
            h, new_cache = T.lm_decode_hidden_paged(
                master, x, cache, block_table, cfg, resolve=resolve,
                layer_unroll=unroll, page_size=page_size,
                hetero=(m_rows, widths))
            logits = master_logits_hetero(h, master["unembed"], m_rows,
                                          widths, kernel_backend)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            eff = i < k_eff
            cache = slots_lib.select_paged(eff, new_cache, cache,
                                           block_table, page_size)
            tok = jnp.where(eff, nxt, tok)
            return (cache, tok), nxt

        (cache, _), toks = jax.lax.scan(
            body, (cache, tok), jnp.arange(k_max, dtype=jnp.int32))
        return jnp.transpose(toks), cache

    return draft


def make_master_verify_step_paged(cfg: ModelConfig,
                                  kernel_backend: str | None = None,
                                  layer_unroll: int | None = None,
                                  page_size: int = 16):
    """verify(master, cache, tokens int32[B, S], m, block_table, n_used
    int32[B]) -> (logits f32[B, S, V], cache): the speculative VERIFY
    phase — row b's ``tokens[b]`` is its last committed token followed by
    S-1 draft proposals, forwarded at the FULL width m through the paged
    attention view in one batched pass (lm_verify_hidden_paged), with the
    full-width K/V overwriting the draft's low-width cells in place.
    ``logits[b, i]`` is the next-token distribution after position
    ``pos[b] + i`` — compare ``argmax(logits[b, i-1])`` to draft token i
    to find the accepted prefix.  ``cache["pos"]`` is NOT advanced; the
    caller rolls forward/back via slots.rollback_paged once accept
    lengths are known."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "packed-master serving covers the LM families")
    dt = jnp.bfloat16
    unroll = _auto_layer_unroll(cfg, layer_unroll)

    def verify(master, cache, tokens, m, block_table, n_used):
        def resolve(layer_slice):
            return dequant_master_tree(layer_slice, m, dt)

        x = L.embed(master["embed"], tokens, dt)
        h, cache = T.lm_verify_hidden_paged(
            master, x, cache, block_table, cfg, resolve=resolve,
            layer_unroll=unroll, page_size=page_size, n_used=n_used)
        logits = master_logits_all(h, master["unembed"], m, kernel_backend)
        return logits, cache

    return verify


def make_master_prefill_paged(cfg: ModelConfig,
                              kernel_backend: str | None = None,
                              page_size: int = 16):
    """prefill_chunk(master, tokens [1,C], m, pages, block_table
    int32[max_pages], start) -> (logits, new_pages): one chunk of a paged
    prefill, writing K/V straight into the shared pages through one slot's
    block-table row.  ``start`` is traced, so every chunk of every slot at
    a given chunk length shares one executable; the LAST chunk's logits
    are the ones the scheduler samples the first token from.  Attention
    families only (see lm_prefill_paged_hidden)."""
    if cfg.is_encdec:
        raise NotImplementedError(
            "packed-master serving covers the LM families")
    dt = jnp.bfloat16

    def prefill_chunk(master, tokens, m, pages, block_table, start):
        def resolve(layer_slice):
            return dequant_master_tree(layer_slice, m, dt)

        x = L.embed(master["embed"], tokens, dt)
        h, new_pages = T.lm_prefill_paged_hidden(
            master, x, pages, block_table, start, cfg, resolve=resolve,
            page_size=page_size)
        logits = master_logits(h[:, -1:], master["unembed"], m,
                               kernel_backend)
        return logits, new_pages

    return prefill_chunk


def master_param_shapes(cfg: ModelConfig, min_size: int = 1 << 16) -> Any:
    """ShapeDtypeStruct tree of the packed serving params (dry-run)."""
    from repro.models import model_zoo as Z

    def build():
        params = Z.init_params(cfg, jax.random.PRNGKey(0))
        return pack_master_params(params, min_size=min_size)

    return jax.eval_shape(build)
