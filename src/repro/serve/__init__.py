from repro.serve.engine import GenerationResult, SwitchableServer  # noqa: F401
from repro.serve.sampler import sample_token  # noqa: F401
