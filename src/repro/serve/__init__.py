from repro.serve.engine import GenerationResult, SwitchableServer  # noqa: F401
from repro.serve.errors import (  # noqa: F401
    BadDeadline,
    DeadlineExceeded,
    QueueFull,
    ServeError,
    SlotPoisoned,
    UnknownRequestClass,
)
from repro.serve.faults import (  # noqa: F401
    ArrivalFlood,
    CacheCorruptionFault,
    FaultInjector,
    NaNLogitsFault,
    StallFault,
)
from repro.serve.sampler import sample_token, sample_token_vec  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    WIDTH_POLICIES,
    Admission,
    ContinuousScheduler,
    MaxWidthPolicy,
    SLODegradePolicy,
    WidthPolicy,
    WidthRoundRobinPolicy,
)
from repro.serve.slots import FinishedRequest, Request  # noqa: F401
from repro.serve.telemetry import (  # noqa: F401
    MetricsRegistry,
    NullTelemetry,
    Telemetry,
    Tracer,
    parse_prometheus,
    serve_metrics,
)
