from repro.serve.engine import GenerationResult, SwitchableServer  # noqa: F401
from repro.serve.sampler import sample_token, sample_token_vec  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    WIDTH_POLICIES,
    ContinuousScheduler,
    MaxWidthPolicy,
    WidthPolicy,
    WidthRoundRobinPolicy,
)
from repro.serve.slots import FinishedRequest, Request  # noqa: F401
