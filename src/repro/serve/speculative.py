"""Self-speculative decoding: the packed SEFP master drafts for itself
(DESIGN.md §15).

The stacked {mag, sign, exp} master already CONTAINS its own draft model:
truncating the mantissa to m=3/4 is a cheaper forward pass over the same
bytes, and the BPS visit/loss stats the artifact stores per width arm
quantify how closely each truncation tracks the full-width model — which
is exactly the signal that predicts draft acceptance.  So speculative
decoding here needs ZERO extra weight memory: draft and verifier are one
packed artifact read at two widths.

One speculative macro-step per slot:

  1. **draft** — k greedy sub-steps at the slot's draft width (m=3/4,
     chosen per request by an AcceptanceEstimator from the artifact's BPS
     loss stats, static fallback when stats are absent), fused into ONE
     dispatch (packed_step.make_master_draft_scan_paged): the argmax
     feedback loop runs on-device, per-slot draft widths ride the
     ``sefp_matmul_gemv_hetero`` ladder sweep, and draft K/V lands in the
     slot's own pages at the draft width.
  2. **verify** — all k+1 candidate positions forwarded at the FULL width
     (m=8) in ONE batched dispatch (make_master_verify_step_paged),
     reusing the paged block-table attention view with a per-query causal
     horizon — the same view-index-is-position discipline as the chunked
     prefill path — and overwriting every draft K/V cell at full width.
  3. **accept** — the longest prefix of drafts matching the verifier's
     argmax commits, PLUS the verifier's own next token (the "bonus"), so
     even a 0-accept macro-step nets one token — speculation never
     decodes slower than plain in tokens-per-dispatch.
  4. **rollback** — rejected-tail cells are zeroed through the block
     table and the position advances by exactly the committed count
     (slots.rollback_paged); pages are refcount-untouched (the budget was
     reserved at admission) and the zero-restore is byte-exact because
     decode-region cells are slot-exclusive and scrubbed-at-retirement.

The lockstep engine stays the bitwise oracle: greedy speculative output
is token-identical to plain greedy m=8 decode at matched batch shapes
(tests/test_speculative.py), because every committed token is the argmax
of full-width logits over the identical cache contents.

This module owns the host-side pieces: the per-request config
(SpeculativeConfig), the pluggable acceptance estimator registry, the
accept-length rule and the drafted/accepted/rejected accounting whose
invariants the property tests pin (drafted == accepted + rejected, per
slot and in aggregate).  The scheduler (serve/scheduler.py,
``ContinuousScheduler(spec_decode=...)``) wires them into the continuous
batch, mixing speculative and plain requests in one slot table.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.core.packed import MASTER_M


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """Speculation spec for a scheduler (or a PrecisionPolicy).

    ``k`` — draft tokens per macro-step (the verify step batches k+1
    positions).  ``draft_width`` — the STATIC fallback draft width, used
    whenever the estimator has no BPS stats to read.  ``verify_width`` —
    the full width drafts are checked at; a slot speculates only when its
    realized step width equals it, which is what makes SLO-degrade
    compose for free: a degraded (or heterogeneous sub-full-width) slot
    silently falls back to plain decode.  ``candidates`` — the draft
    widths the estimator chooses among (they define the fused draft
    step's compiled ladder).  ``estimator`` — a name in ESTIMATORS or an
    AcceptanceEstimator instance.  ``classes`` — restrict speculation to
    these request classes (None = every eligible request)."""

    k: int = 3
    draft_width: int = 4
    verify_width: int = MASTER_M
    candidates: Tuple[int, ...] = (3, 4)
    estimator: object = "bps"
    classes: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if not 1 <= int(self.k) <= 8:
            raise ValueError(f"spec_decode k must be in 1..8, got {self.k}")
        cands = tuple(int(w) for w in self.candidates)
        if not cands:
            raise ValueError("spec_decode needs at least one candidate "
                             "draft width")
        object.__setattr__(self, "candidates", cands)
        for w in cands + (int(self.draft_width),):
            if not 1 <= w <= MASTER_M:
                raise ValueError(f"draft width {w} outside 1..{MASTER_M}")
            if w >= int(self.verify_width):
                raise ValueError(
                    f"draft width {w} must be strictly below the verify "
                    f"width {self.verify_width} — drafting at (or above) "
                    f"full width is just a slower plain step")
        if not 1 <= int(self.verify_width) <= MASTER_M:
            raise ValueError(f"verify_width must be in 1..{MASTER_M}, got "
                             f"{self.verify_width}")
        if int(self.draft_width) not in cands:
            object.__setattr__(self, "candidates",
                               tuple(sorted(set(cands)
                                            | {int(self.draft_width)})))
        if self.classes is not None:
            object.__setattr__(self, "classes",
                               tuple(str(c) for c in self.classes))

    @property
    def ladder(self) -> Tuple[int, ...]:
        """Static draft-width ladder the fused draft step compiles for."""
        return tuple(sorted(set(self.candidates), reverse=True))

    def describe(self) -> dict:
        """JSON-serializable form (PrecisionPolicy round-trip)."""
        d = {"k": int(self.k), "draft_width": int(self.draft_width),
             "verify_width": int(self.verify_width),
             "candidates": [int(w) for w in self.candidates],
             "estimator": (self.estimator if isinstance(self.estimator, str)
                           else getattr(self.estimator, "name",
                                        type(self.estimator).__name__))}
        if self.classes is not None:
            d["classes"] = list(self.classes)
        return d

    @classmethod
    def from_meta(cls, d: Optional[dict]) -> Optional["SpeculativeConfig"]:
        if d is None:
            return None
        return cls(k=int(d.get("k", 3)),
                   draft_width=int(d.get("draft_width", 4)),
                   verify_width=int(d.get("verify_width", MASTER_M)),
                   candidates=tuple(d.get("candidates", (3, 4))),
                   estimator=d.get("estimator", "bps"),
                   classes=(tuple(d["classes"])
                            if d.get("classes") is not None else None))


def as_spec(spec) -> Optional[SpeculativeConfig]:
    """Normalize a scheduler's ``spec_decode`` argument: None/False off,
    True for defaults, an int for ``k``, a dict of kwargs, or a ready
    SpeculativeConfig."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return SpeculativeConfig()
    if isinstance(spec, SpeculativeConfig):
        return spec
    if isinstance(spec, int):
        return SpeculativeConfig(k=spec)
    if isinstance(spec, dict):
        return SpeculativeConfig(**spec)
    raise TypeError(f"spec_decode must be None/bool/int/dict/"
                    f"SpeculativeConfig, got {type(spec).__name__}")


# ---------------------------------------------------------------------------
# acceptance estimators (pluggable)
# ---------------------------------------------------------------------------

class AcceptanceEstimator:
    """Chooses a per-request draft width from the artifact's BPS stats.

    ``draft_width(spec, bps_stats, widths)`` returns a width from
    ``spec.candidates``; ``bps_stats`` is ``Artifact.bps_stats`` (a
    ``{"t", "t_b", "loss_b"}`` dict whose arms align with the precision
    policy's ``widths`` order) or None when the artifact predates the
    stats — every estimator must degrade to ``spec.draft_width`` then."""

    name = "abstract"

    def draft_width(self, spec: SpeculativeConfig, bps_stats,
                    widths) -> int:
        raise NotImplementedError


class StaticEstimator(AcceptanceEstimator):
    """Always the configured static draft width — the explicit opt-out of
    stats-driven selection, and the documented fallback body."""

    name = "static"

    def draft_width(self, spec, bps_stats, widths) -> int:
        return int(spec.draft_width)


class BPSAcceptanceEstimator(AcceptanceEstimator):
    """Pick the candidate draft width maximizing expected committed
    tokens per unit of weight-streaming cost, using the loss gap between
    each width arm and the full-width arm as an acceptance proxy.

    The BPS loss stats (artifact meta ``bps.loss_b``, one arm per policy
    width) measure how much worse the truncated model predicts the same
    data.  A greedy draft at width w is accepted when its argmax matches
    the full-width argmax, and a per-token match probability is
    well-approximated by ``a = exp(-(loss_w - loss_full))`` — the
    likelihood-ratio reading of the loss gap (exact when the gap is 0:
    a=1, every draft accepted).  Expected committed tokens of a k-draft
    macro-step with per-token acceptance a is the standard speculative
    formula ``E[c] = (1 - a^(k+1)) / (1 - a)`` (k+1 at a=1, counting the
    bonus token), and the macro-step's weight-bytes cost relative to one
    full-width step is ``1 + k * (w + 1.125) / (M + 1.125)`` (the SEFP
    bytes-per-weight ratio, DESIGN.md §7).  The arg-max of E[c]/cost over
    ``spec.candidates`` wins.  Missing/malformed stats, or a candidate
    without an arm, fall back to the static width — never an error on the
    serving path (Artifact.require_bps_stats is the loud accessor)."""

    name = "bps"

    def acceptance(self, spec, bps_stats, widths,
                   w: int) -> Optional[float]:
        """Predicted per-token draft acceptance for width ``w`` (None when
        the stats cannot say)."""
        try:
            losses = [float(x) for x in bps_stats["loss_b"]]
            arms = {int(a): l for a, l in zip(widths, losses)}
            gap = arms[int(w)] - arms[int(spec.verify_width)]
        except (KeyError, TypeError, ValueError):
            return None
        return math.exp(-max(0.0, gap))

    def draft_width(self, spec, bps_stats, widths) -> int:
        if not bps_stats:
            return int(spec.draft_width)
        k = int(spec.k)
        best_w, best_rate = None, -1.0
        for w in spec.candidates:
            a = self.acceptance(spec, bps_stats, widths, w)
            if a is None:
                continue
            exp_tokens = (k + 1.0 if a >= 1.0
                          else (1.0 - a ** (k + 1)) / (1.0 - a))
            cost = 1.0 + k * (w + 1.125) / (spec.verify_width + 1.125)
            rate = exp_tokens / cost
            if rate > best_rate:
                best_w, best_rate = int(w), rate
        return best_w if best_w is not None else int(spec.draft_width)


ESTIMATORS = {
    StaticEstimator.name: StaticEstimator,
    BPSAcceptanceEstimator.name: BPSAcceptanceEstimator,
}


def make_estimator(est) -> AcceptanceEstimator:
    """Resolve ``SpeculativeConfig.estimator`` (or a SpeculativeConfig):
    an instance passes through, a registered name constructs."""
    if isinstance(est, SpeculativeConfig):
        est = est.estimator
    if isinstance(est, AcceptanceEstimator):
        return est
    try:
        return ESTIMATORS[est]()
    except (KeyError, TypeError):
        raise ValueError(f"unknown acceptance estimator {est!r}; "
                         f"registered: {sorted(ESTIMATORS)}") from None


# ---------------------------------------------------------------------------
# accept rule + accounting
# ---------------------------------------------------------------------------

def accept_length(draft_tokens, verified_tokens, k_eff: int) -> int:
    """Longest accepted draft prefix: drafts ``d_1..d_k`` (draft_tokens)
    against the verifier's argmax ``verified_tokens`` where
    ``verified_tokens[i]`` is the full-width next token AFTER candidate
    position i — so draft i+1 is accepted iff it equals
    ``verified_tokens[i]``, and acceptance stops at the first miss."""
    j = 0
    while j < k_eff and int(draft_tokens[j]) == int(verified_tokens[j]):
        j += 1
    return j


@dataclasses.dataclass
class SpecAccounting:
    """Aggregate drafted/accepted/rejected accounting, per draft width.

    Invariants (property-tested): ``drafted == accepted + rejected`` both
    per width and in total; ``committed == accepted + bonus`` where bonus
    counts one verifier token per healthy macro-slot-step.  "wasted" in
    the bench schema is ``rejected`` — draft tokens whose compute never
    produced a committed token."""

    drafted: Dict[int, int] = dataclasses.field(default_factory=dict)
    accepted: Dict[int, int] = dataclasses.field(default_factory=dict)
    rejected: Dict[int, int] = dataclasses.field(default_factory=dict)
    macro_steps: int = 0
    bonus_tokens: int = 0
    committed_tokens: int = 0

    def record(self, draft_width: int, k_eff: int, n_accepted: int,
               n_committed: int) -> None:
        """One slot's macro-step outcome: ``k_eff`` drafted,
        ``n_accepted`` of them matched, ``n_committed`` tokens actually
        committed (accepted prefix + bonus, possibly truncated by EOS)."""
        w = int(draft_width)
        self.drafted[w] = self.drafted.get(w, 0) + int(k_eff)
        self.accepted[w] = self.accepted.get(w, 0) + int(n_accepted)
        self.rejected[w] = (self.rejected.get(w, 0)
                            + int(k_eff) - int(n_accepted))
        self.macro_steps += 1
        self.committed_tokens += int(n_committed)
        if n_committed > n_accepted:
            self.bonus_tokens += 1

    def summary(self) -> dict:
        tot_d = sum(self.drafted.values())
        tot_a = sum(self.accepted.values())
        tot_r = sum(self.rejected.values())
        return {
            "macro_steps": self.macro_steps,
            "drafted": tot_d,
            "accepted": tot_a,
            "wasted": tot_r,
            "bonus_tokens": self.bonus_tokens,
            "committed_tokens": self.committed_tokens,
            "acceptance_rate": (tot_a / tot_d) if tot_d else None,
            "by_width": {
                str(w): {
                    "drafted": self.drafted.get(w, 0),
                    "accepted": self.accepted.get(w, 0),
                    "wasted": self.rejected.get(w, 0),
                    "acceptance_rate": (self.accepted.get(w, 0)
                                        / self.drafted[w])
                    if self.drafted.get(w) else None,
                }
                for w in sorted(self.drafted)
            },
        }
