"""Deterministic fault injectors for the continuous scheduler (DESIGN.md
§12).

Robustness claims are only as good as the faults they were tested against,
so the resilience layer ships its own harness: small, seedable injector
objects that plug into a ``ContinuousScheduler`` (``faults=[...]`` at
construction or ``sched.inject(fault)``) and fire at exact step-clock
ticks.  Everything is deterministic — the same workload with the same
injectors produces the same token streams, retirements and width traces,
which is what lets tests pin down the recovery behaviour *bitwise* (a
faulted run's surviving slots must equal the no-fault run exactly) and
lets CI replay the whole scenario as a pass/fail check
(``benchmarks/bench_serving.py --faults --smoke --check``).

Two hook points, both called once per ``step()``:

  * ``before_step(sched)`` — runs first, with full scheduler access:
    mutate device state (cache corruption), sleep (stalls), submit load
    (floods).
  * ``poison_slots(sched, poison)`` — fill the boolean poison mask the
    jitted step consumes; flagged rows get their logits overwritten with
    NaN in-graph *before* the health check, exercising the quarantine
    path exactly as a real numerical blow-up would (and costing nothing
    when the mask is all-False — the select is a bitwise identity).

The four injectors cover the failure modes the acceptance tests demand:

  ``NaNLogitsFault``        non-finite logits on slot k at step t
  ``CacheCorruptionFault``  NaN bit-pattern OR'd into slot k's cache row
  ``StallFault``            artificial wall-clock step stalls (drives the
                            slo-degrade latency-EWMA trigger)
  ``ArrivalFlood``          a burst of synthetic arrivals at one tick
                            (drives backpressure + queue-depth triggers)

Every injector records what it actually did in ``fired`` (a list of event
dicts with the step clock), so tests and the bench can assert a fault
*happened* rather than silently missing its window.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.serve.slots import _is_pages, _is_pos


class FaultInjector:
    """Base injector: override one (or both) hooks.  ``fired`` records the
    events the injector actually performed."""

    def __init__(self):
        self.fired: List[dict] = []

    def before_step(self, sched) -> None:
        """Called at the top of every ``step()``, before eviction and
        admission; may mutate the scheduler (device state, queue, clock
        side effects like sleeping)."""

    def poison_slots(self, sched, poison: np.ndarray) -> None:
        """Called after width selection with the step's poison mask
        (bool[n_slots], host side); set entries True to NaN that row's
        logits in-graph this step."""

    def describe(self) -> dict:
        return {"kind": type(self).__name__, "fired": len(self.fired)}


class NaNLogitsFault(FaultInjector):
    """Overwrite slot ``slot``'s logits with NaN at step-clock ``step``
    (via the traced poison mask — the forward pass itself is untouched,
    so co-resident rows are bitwise unaffected by construction)."""

    def __init__(self, slot: int, step: int):
        super().__init__()
        self.slot = int(slot)
        self.step = int(step)

    def poison_slots(self, sched, poison: np.ndarray) -> None:
        if sched.clock == self.step:
            poison[self.slot] = True
            self.fired.append({"clock": sched.clock, "slot": self.slot,
                               "kind": "nan-logits"})


def _corrupt_row(cache, idx: int, page: Optional[int] = None) -> tuple:
    """OR a quiet-NaN bit pattern into slot ``idx``'s cache state.  Dense
    per-slot leaves (recurrent Mamba2/RWKV6 state) are hit at row ``idx``;
    with ``page`` set, the PAGED attention KV is corrupted *through the
    block table* — at offset 0 of physical page ``page`` in every stacked
    pages leaf (page offsets are written by prefill, so the NaN sits where
    attention *will* read it — corrupting unwritten tail positions would
    be masked out and never detected).  Returns
    (new_cache, n_leaves_corrupted).  Bit-level corruption (not value
    assignment) is the point: this models a radiation/DRAM-style flip that
    lands in cache bytes, and the quiet-NaN pattern guarantees the
    corruption *propagates* to the logits instead of denormalizing away.
    Non-float leaves (f8 pages under kv_dtype="int8") are left alone —
    their corruption stays finite and is a silent-accuracy fault outside
    the quarantine's detection model."""
    nan_bits = {"bfloat16": (jnp.uint16, 0x7FC0),
                "float32": (jnp.uint32, 0x7FC00000),
                "float16": (jnp.uint16, 0x7E00)}

    n_hit = 0

    def cor(path, leaf):
        nonlocal n_hit
        paged = _is_pages(path)
        if _is_pos(path) or leaf.dtype.name not in nan_bits:
            return leaf
        if paged:
            if page is None:
                return leaf
            # pages leaves are [L, n_pages, page_size, KV, hd]
            ix = (slice(None), page, 0)
        else:
            ix = ((slice(None), idx, 0) if leaf.ndim >= 3
                  else (slice(None), idx))
        utype, pattern = nan_bits[leaf.dtype.name]
        u = lax.bitcast_convert_type(leaf, utype)
        u = u.at[ix].set(u[ix] | jnp.asarray(pattern, utype))
        n_hit += 1
        return lax.bitcast_convert_type(u, leaf.dtype)

    new = jax.tree_util.tree_map_with_path(cor, cache)
    return new, n_hit


class CacheCorruptionFault(FaultInjector):
    """Flip NaN bits into slot ``slot``'s cache state at step-clock
    ``step`` — unlike ``NaNLogitsFault`` this corrupts *state*, so
    detection relies on the corruption actually propagating through the
    next decode step's attention reads into the logits health check.

    Under the paged KV cache the attention corruption goes through the
    victim's BLOCK TABLE: the first page the victim holds *exclusively*
    (refcount 1) is hit, never a page shared with other requests through
    the prefix cache — a radiation flip lands in one request's bytes, and
    targeting a shared page would (correctly) poison every reader, which
    is a different scenario than the per-slot quarantine containment this
    injector exists to test.  Recurrent (dense per-slot) state is hit at
    the victim's row as before; rwkv has no paged state at all."""

    def __init__(self, slot: int, step: int):
        super().__init__()
        self.slot = int(slot)
        self.step = int(step)

    def _victim_page(self, sched) -> Optional[int]:
        if not getattr(sched, "_paged", False):
            return None
        try:
            slot = sched._table.get(self.slot)
        except Exception:
            return None
        if slot is None:
            return None
        for pid in slot.pages:
            if sched._allocator.ref(pid) == 1:
                return int(pid)
        return None

    def before_step(self, sched) -> None:
        if sched.clock == self.step:
            page = self._victim_page(sched)
            sched._cache, n = _corrupt_row(sched._cache, self.slot,
                                           page=page)
            self.fired.append({"clock": sched.clock, "slot": self.slot,
                               "kind": "cache-corruption",
                               "leaves_corrupted": n, "page": page})


class StallFault(FaultInjector):
    """Sleep ``seconds`` of wall-clock at each step-clock tick in
    ``steps`` — the scheduler's step-latency EWMA sees a real latency
    spike, which is the slo-degrade policy's third trigger (the one queue
    depth cannot exercise)."""

    def __init__(self, steps, seconds: float):
        super().__init__()
        self.steps = {int(s) for s in (
            steps if hasattr(steps, "__iter__") else [steps])}
        self.seconds = float(seconds)

    def before_step(self, sched) -> None:
        if sched.clock in self.steps:
            time.sleep(self.seconds)
            self.fired.append({"clock": sched.clock, "kind": "stall",
                               "seconds": self.seconds})


class ArrivalFlood(FaultInjector):
    """Submit ``n`` synthetic requests in one burst at step-clock
    ``at_step`` (deterministic prompts from ``seed``), via ``try_submit``
    so a bounded queue exercises real backpressure — accepted and
    rejected counts land in ``fired`` and the rids in ``rids`` for
    post-hoc assertions."""

    def __init__(self, at_step: int, n: int, prompt_len: int = 4,
                 max_new: int = 8, request_class: Optional[str] = None,
                 min_width: Optional[int] = None,
                 deadline: Optional[int] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 seed: int = 0):
        super().__init__()
        self.at_step = int(at_step)
        self.n = int(n)
        self.prompt_len = int(prompt_len)
        self.max_new = int(max_new)
        self.request_class = request_class
        self.min_width = min_width
        self.deadline = deadline
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.seed = int(seed)
        self.rids: List[int] = []
        self.prompts: List[np.ndarray] = []  # rids[i] was sent prompts[i]
        self.rejected = 0

    def before_step(self, sched) -> None:
        if sched.clock != self.at_step:
            return
        rng = np.random.default_rng(self.seed)
        vocab = sched.cfg.vocab_size
        for j in range(self.n):
            prompt = rng.integers(0, vocab, size=self.prompt_len,
                                  dtype=np.int64).astype(np.int32)
            adm = sched.try_submit(
                prompt=prompt,
                max_new=self.max_new,
                request_class=self.request_class,
                min_width=self.min_width,
                deadline=self.deadline,
                temperature=self.temperature, top_k=self.top_k,
                seed=self.seed + j)
            if adm.accepted:
                self.rids.append(adm.rid)
                self.prompts.append(prompt)
            else:
                self.rejected += 1
        self.fired.append({"clock": sched.clock, "kind": "flood",
                           "submitted": len(self.rids),
                           "rejected": self.rejected})


FAULT_KINDS: Dict[str, type] = {
    "nan-logits": NaNLogitsFault,
    "cache-corruption": CacheCorruptionFault,
    "stall": StallFault,
    "flood": ArrivalFlood,
}
