"""Paged KV cache: page allocator, block tables and the prompt-prefix
cache (DESIGN.md §13).

The continuous batcher used to back every slot with a dense cache row of
``max_len`` positions, so a short chat request reserved as much KV memory
as a long-document one and capacity was ``n_slots`` regardless of request
shape.  This module replaces that with block-table paging:

  * the attention KV cache becomes a pool of fixed-size **pages**
    ``[L, n_pages, page_size, KV, hd]`` shared by every slot;
  * each slot owns a **block table** row ``int32[max_pages]`` mapping its
    logical page index (``position // page_size``) to a physical page;
  * physical page 0 is the **null page**: never allocated, it is where
    free slots' garbage decode writes land and what unallocated block
    table entries point at — its contents are never read unmasked;
  * pages are **ref-counted** so full prompt pages can be shared across
    requests (prefix reuse): a shared page is read-only by construction —
    only FULL, immutable pages are ever shared, the partial tail page and
    every decode page are freshly allocated and exclusive, which is
    copy-on-write without ever copying.

Only attention KV is paged.  Mamba2/RWKV6 recurrent state is O(1) per
slot and position-free — it stays dense per-slot (repro/serve/slots.py).

The ``PrefixCache`` maps a chain hash of page-aligned prompt chunks to a
page id.  The hash is keyed on the **prefill width** as well as the
tokens: SEFP serves every width from one master, so the same prompt
prefilled at m=8 and m=4 produces different K/V bytes — reusing across
widths would silently break the lockstep-oracle bitwise property the
scheduler guarantees.  The cache holds one reference per cached page;
eviction is LRU over entries whose pages are otherwise unreferenced, run
on demand when an admission falls short of pages.
"""

from __future__ import annotations

import collections
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np


class PageBudgetExceeded(RuntimeError):
    """An allocation asked for more free pages than the pool has."""


class PageAllocator:
    """Host-side free list + per-page reference counts over ``n_pages``
    physical pages.  Page 0 is reserved as the null page (never handed
    out); ``high_water`` tracks the peak pages in use — the number a
    static provisioning of this workload would have needed."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (one is the null page), "
                             f"got {n_pages}")
        self.n_pages = int(n_pages)
        # LIFO free list: recently freed (already-scrubbed) pages are
        # reused first, keeping the touched working set small.
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))
        self._ref = np.zeros((self.n_pages,), np.int32)
        self.high_water = 0

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - 1 - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` pages off the free list with refcount 1 each."""
        if n > len(self._free):
            raise PageBudgetExceeded(
                f"asked for {n} pages, {len(self._free)} free "
                f"(pool {self.n_pages - 1})")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._ref[p] = 1
        self.high_water = max(self.high_water, self.pages_in_use)
        return out

    def ref(self, pid: int) -> int:
        return int(self._ref[pid])

    def incref(self, pid: int) -> None:
        if pid == 0 or self._ref[pid] <= 0:
            raise ValueError(f"incref on unallocated page {pid}")
        self._ref[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; returns True when the page was freed (the
        caller is responsible for scrubbing freed pages on device)."""
        if pid == 0 or self._ref[pid] <= 0:
            raise ValueError(f"decref on unallocated page {pid}")
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)
            return True
        return False


def prefix_keys(tokens: np.ndarray, page_size: int, m: int) -> List[str]:
    """Chain hash of the prompt's page-aligned chunks at prefill width
    ``m``: key ``i`` commits tokens ``[0, (i+1)*page_size)`` — a page is
    only reusable when its entire causal history matches, which the chain
    structure encodes for free.  Returns one key per FULL page."""
    n_full = len(tokens) // page_size
    keys = []
    h = hashlib.blake2b(f"m={int(m)}|ps={int(page_size)}".encode(),
                        digest_size=16)
    for i in range(n_full):
        chunk = np.asarray(tokens[i * page_size:(i + 1) * page_size],
                           np.int64)
        h = hashlib.blake2b(h.digest() + chunk.tobytes(), digest_size=16)
        keys.append(h.hexdigest())
    return keys


class PrefixCache:
    """LRU map from a prefix chain-hash key to a physical page id.  Each
    entry holds ONE reference on its page (taken at ``insert``, dropped at
    eviction/purge), so cached pages survive their producer's retirement
    and co-exist with any number of active readers."""

    def __init__(self, allocator: PageAllocator):
        self._alloc = allocator
        self._entries: "collections.OrderedDict[str, int]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.inserted = 0
        self.evicted = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, keys: List[str]) -> List[int]:
        """Longest consecutive run of cached pages for ``keys`` (a chain —
        a miss at i invalidates every later key).  Returns the hit pages
        WITHOUT taking references; the caller increfs the ones it adopts."""
        run: List[int] = []
        for k in keys:
            pid = self._entries.get(k)
            if pid is None:
                self.misses += 1
                break
            self._entries.move_to_end(k)
            self.hits += 1
            run.append(pid)
        return run

    def insert(self, key: str, pid: int) -> bool:
        """Cache ``pid`` under ``key`` (incref'd); no-op if the key is
        already cached (first producer wins — both copies are bitwise
        identical by the key construction).  Returns True when the entry
        was newly published (the producer tracks these for poisoned-retire
        purging)."""
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        self._alloc.incref(pid)
        self._entries[key] = pid
        self.inserted += 1
        return True

    def purge_pages(self, pids) -> List[int]:
        """Drop every entry whose page is in ``pids`` (poisoned-producer
        hygiene: a quarantined slot's own pages must never serve future
        requests).  Returns the pages actually freed (for scrubbing)."""
        pids = set(int(p) for p in pids)
        doomed = [k for k, p in self._entries.items() if p in pids]
        freed: List[int] = []
        for k in doomed:
            pid = self._entries.pop(k)
            if self._alloc.decref(pid):
                freed.append(pid)
        self.evicted += len(doomed)
        return freed

    def evict_for(self, n_needed: int) -> List[int]:
        """Evict LRU entries whose pages have no other reference until
        ``n_needed`` pages are free (or the cache runs out of evictable
        entries).  Returns the page ids actually freed (for scrubbing)."""
        freed: List[int] = []
        if self._alloc.can_alloc(n_needed):
            return freed
        for k in list(self._entries):
            pid = self._entries[k]
            if self._alloc.ref(pid) > 1:
                continue  # an active slot still reads this page
            del self._entries[k]
            self.evicted += 1
            if self._alloc.decref(pid):
                freed.append(pid)
            if self._alloc.can_alloc(n_needed):
                break
        return freed

    @property
    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "inserted": self.inserted,
                "evicted": self.evicted}


def pages_for(n_positions: int, page_size: int) -> int:
    """Pages needed to hold ``n_positions`` KV positions."""
    return -(-int(n_positions) // int(page_size))


def request_pages(prompt_len: int, max_new: int, page_size: int) -> int:
    """Total logical pages a request can touch: prefill writes positions
    ``[0, prompt_len)`` and decode steps write up to position
    ``prompt_len + max_new - 2`` (the last sampled token is never fed
    back), so the page budget covers ``prompt_len + max_new - 1``
    positions.  ``max_new == 0`` never reaches a slot (scheduler fast
    path)."""
    return pages_for(prompt_len + max(int(max_new), 1) - 1, page_size)
