"""Switchable-precision serving engine — the paper's deployment story,
fully device-resident.

One stacked SEFP master (~9.1 bits/param, repro/core/packed.py) is kept
resident; serving at any precision E5M8..E5M3 is a runtime mantissa
truncation of that master (``mag >> (8-m)``) performed *inside* the decode
step (repro/serve/packed_step.py).  Consequences, in order of importance:

  * ``set_precision(m)`` is O(1): it records the default width.  No weight
    tree is ever rebuilt — the truncation happens in-graph against the
    packed arrays, next to the consuming matmuls (contrast: conventional
    int quantization needs a per-bit-width model zoo, and the old
    materialize-on-switch engine paid a full O(params) elementwise pass per
    switch; tests/test_sefp_core.py demonstrates why SEFP avoids both);
  * decode is ONE jitted ``lax.scan`` over steps: sampling lives in the
    scan body, the precision schedule is a traced ``int32[max_new]`` array
    consumed in-graph (the §3 traced-m property — one executable covers
    every schedule), and the whole generation returns as a single
    ``[B, max_new]`` device array — exactly one host transfer;
  * precision can therefore switch *mid-generation* (prefill high, decode
    low — the paper's prefill/decode asymmetry, or per-request by task
    type) at zero per-token cost: a different int in the schedule array;
  * requests are served in fixed batch slots with a shared KV cache.

``generate_per_token`` keeps the legacy loop — one jitted call and one
host sync per token — as the measured baseline; benchmarks/bench_decode.py
tracks fused-scan vs per-token vs materialized throughput, host-sync
counts and switch latency in BENCH_decode.json.  Both lockstep paths stop
at ``eos_id`` (the fused scan by masking its fixed-length emissions, the
loop by actually breaking).  For arrival-driven traffic, ``continuous()``
wraps this server in the continuous-batching scheduler
(repro/serve/scheduler.py, DESIGN.md §11): per-slot admission/retirement
over a shared cache, with the lockstep ``generate`` kept as the bitwise
replay oracle for any realized schedule.  On TPU the unembed gemv
can be routed through the fused sefp_matmul_gemv kernel
(``kernel_backend=``); layer matmuls use the XLA-fused in-scan dequant,
which is numerically identical (tests/test_serving.py asserts it).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import packed as packed_lib
from repro.models.config import ModelConfig
from repro.policy import PrecisionPolicy
from repro.serve import packed_step as PS
from repro.serve.sampler import sample_token


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, new]
    prompt_len: int
    precision_trace: List[int]  # mantissa width used at each decode step
    decode_seconds: float
    host_transfers: int         # device->host syncs during decode
    # eos_id generations only: per-row emitted count INCLUDING the eos
    # token; rows that never emitted eos have lengths == tokens.shape[1].
    # Positions past a row's length are padded with eos_id.
    lengths: Optional[np.ndarray] = None
    prefill_precision: Optional[int] = None  # width the prompt ran at


class SwitchableServer:
    """Batched switchable-precision server over one packed SEFP master.

    ``kernel_backend``: None (default) keeps every matmul on the portable
    XLA path with fused in-scan dequant; any backend registered with
    repro.kernels.dispatch (compiled Mosaic on TPU, the interpreter, or the
    jitted jnp oracle) additionally routes the unembed projection — the
    decode-shaped gemv — through the ``sefp_matmul_gemv`` kernel op, which
    also adopts the kernel's bf16-operand numerics at the logit head (see
    packed_step.master_logits)."""

    def __init__(self, cfg: ModelConfig, params=None, max_len: int = 256,
                 cache_dtype=jnp.bfloat16, min_size: int = 4096,
                 kernel_backend: Optional[str] = None,
                 layer_unroll: Optional[int] = None, master=None):
        if (params is None) == (master is None):
            raise ValueError("pass exactly one of params (fp32 weights, "
                             "packed here) or master (pre-packed, e.g. from "
                             "a repro.artifact load)")
        self.cfg = cfg
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.kernel_backend = kernel_backend
        self.layer_unroll = layer_unroll
        # the single multi-precision master: packed once here from fp32, or
        # adopted pre-packed (the artifact path — no O(params) pack pass)
        self.master = master if master is not None else \
            PS.pack_master_params(params, min_size=min_size)
        self.master_bytes = packed_lib.tree_nbytes(self.master)
        self._m = packed_lib.MASTER_M
        self._policy: Optional[PrecisionPolicy] = None
        serve = PS.make_master_serve_step(cfg, kernel_backend, layer_unroll)
        self._serve = jax.jit(serve)
        self._prefill = jax.jit(PS.make_master_prefill(cfg, kernel_backend),
                                static_argnames=("max_len",))
        self._fused = jax.jit(_make_fused_decode(serve),
                              static_argnames=("temperature", "top_k"))

    @classmethod
    def from_master(cls, cfg: ModelConfig, master,
                    **kw) -> "SwitchableServer":
        """Serve a pre-packed stacked-SEFP master (the repro.artifact load
        path): startup performs no fp32 quantize/pack pass — the packed
        arrays go device-resident as-is."""
        return cls(cfg, master=master, **kw)

    # -- precision switching ------------------------------------------------
    def set_precision(self, m: int):
        """Set the default serving width E5M<m>.  O(1): no weight pass, no
        recompilation — the width is a traced scalar of the compiled step
        (per-generation overrides go through ``precision_schedule``).  With
        a PrecisionPolicy installed this overrides its default and clears
        its default mid-stream plan; per-class plans stay in force."""
        m = int(m)
        if not 1 <= m <= packed_lib.MASTER_M:
            raise ValueError(f"mantissa width must be in "
                             f"1..{packed_lib.MASTER_M}, got {m}")
        self._m = m
        if self._policy is not None:
            self._policy = dataclasses.replace(self._policy, default=m,
                                               plan=None)

    @property
    def precision(self) -> int:
        return self._m

    def set_policy(self, policy: Optional[PrecisionPolicy]):
        """Install a PrecisionPolicy: it supplies the default width and the
        per-request-class / mid-stream schedules for every following
        ``generate`` call.  O(1) like ``set_precision`` — policy lowering
        produces schedule *data* for the one compiled executable."""
        if policy is not None and not isinstance(policy, PrecisionPolicy):
            raise TypeError(f"expected PrecisionPolicy, got {type(policy)}")
        self._policy = policy
        if policy is not None:
            self._m = int(policy.default)

    @property
    def policy(self) -> Optional[PrecisionPolicy]:
        return self._policy

    def _schedule(self, max_new: int, precision_schedule,
                  request_class: Optional[str] = None) -> List[int]:
        if precision_schedule is not None and request_class is not None:
            raise ValueError("precision_schedule and request_class are "
                             "mutually exclusive — pass one width source")
        if max_new == 0:
            return []          # prefill-only: nothing to schedule
        if precision_schedule is None:
            if request_class is not None:
                if self._policy is None:
                    raise ValueError("request_class routing needs a "
                                     "PrecisionPolicy (set_policy)")
                sched = self._policy.request_schedule(max_new, request_class)
            elif self._policy is not None and self._policy.plan is not None:
                sched = self._policy.request_schedule(max_new)
            else:
                sched = [self._m] * max_new
        elif callable(precision_schedule):
            sched = [int(precision_schedule(i)) for i in range(max_new)]
        else:
            sched = [int(x) for x in precision_schedule]
            if len(sched) != max_new:
                raise ValueError(f"schedule length {len(sched)} != "
                                 f"max_new {max_new}")
        for m in sched:
            if not 1 <= m <= packed_lib.MASTER_M:
                raise ValueError(f"schedule width {m} out of range")
        return sched

    # -- serving --------------------------------------------------------------
    def prefill(self, prompts: np.ndarray):
        """prompts: [B, S] int32 (equal-length batch slot).  Returns
        (last_logits, cache), computed straight from the packed master at
        the current precision."""
        toks = jnp.asarray(prompts, jnp.int32)
        return self._prefill(self.master, toks, jnp.int32(self._m),
                             max_len=self.max_len)

    def _prefill_m(self, sched: List[int],
                   prefill_precision: Optional[int]) -> int:
        """Width the prompt runs at: an explicit override, else the first
        decode step's width (the historical rule), else the default."""
        if prefill_precision is None:
            return sched[0] if sched else self._m
        m = int(prefill_precision)
        if not 1 <= m <= packed_lib.MASTER_M:
            raise ValueError(f"prefill_precision must be in "
                             f"1..{packed_lib.MASTER_M}, got {m}")
        return m

    def generate(self, prompts: np.ndarray, max_new: int,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 precision_schedule=None,
                 request_class: Optional[str] = None,
                 eos_id: Optional[int] = None,
                 prefill_precision: Optional[int] = None) -> GenerationResult:
        """Batched generation as one fused device-resident scan.

        ``precision_schedule``: optional callable ``step_idx -> mantissa
        width`` or int sequence of length ``max_new``; it becomes a traced
        int32 array consumed in-graph, so mid-generation switching (e.g.
        prefill/high, decode/low) costs nothing and triggers no retrace.
        ``request_class``: route through the installed PrecisionPolicy's
        per-class plan instead (mutually exclusive with an explicit
        schedule).  Prefill runs at the width of the first decode step
        unless ``prefill_precision`` overrides it (the continuous
        scheduler's lockstep-oracle hook: a slot admitted at one width may
        be stepped at another — repro/serve/scheduler.py).
        ``eos_id``: a row's generation semantically ends at the first
        emission of this id — positions after it are padded with ``eos_id``
        and per-row counts come back in ``result.lengths``.  The fused scan
        has fixed length, so the remaining steps still execute (tokens
        masked after the fact, bitwise-identical prefix); use
        ``generate_per_token`` when actually cutting compute matters more
        than the single host transfer.
        ``temperature``/``top_k`` are static (see serve/sampler.py); a new
        ``max_new`` retraces once (new scan length)."""
        B, S = prompts.shape
        assert S + max_new <= self.max_len
        sched = self._schedule(max_new, precision_schedule, request_class)
        pm = self._prefill_m(sched, prefill_precision)
        logits, cache = self._prefill(
            self.master, jnp.asarray(prompts, jnp.int32), jnp.int32(pm),
            max_len=self.max_len)
        key = jax.random.PRNGKey(seed)
        t0 = time.perf_counter()
        toks = self._fused(self.master, cache, logits,
                           jnp.asarray(sched, jnp.int32), key,
                           temperature=temperature, top_k=top_k)
        tokens = np.asarray(toks)  # the single device->host transfer
        dt = time.perf_counter() - t0
        lengths = None
        if eos_id is not None:
            tokens, lengths = _mask_after_eos(tokens, int(eos_id))
        return GenerationResult(tokens=tokens, prompt_len=S,
                                precision_trace=sched, decode_seconds=dt,
                                host_transfers=1, lengths=lengths,
                                prefill_precision=pm)

    def generate_per_token(self, prompts: np.ndarray, max_new: int,
                           temperature: float = 0.0, top_k: int = 0,
                           seed: int = 0, precision_schedule=None,
                           request_class: Optional[str] = None,
                           eos_id: Optional[int] = None,
                           prefill_precision: Optional[int] = None
                           ) -> GenerationResult:
        """Legacy decode loop: one jitted step dispatch and one host token
        sync per step.  Numerically the same master step as the fused scan
        (token-for-token identical at temperature 0); kept as the measured
        baseline for BENCH_decode.json and as the shape a non-batched
        interactive client would run.  With ``eos_id`` the loop genuinely
        stops once every row has emitted it — fewer steps, fewer host
        syncs, ``tokens.shape[1]`` may be < ``max_new`` and
        ``precision_trace`` is truncated to the steps that ran."""
        B, S = prompts.shape
        assert S + max_new <= self.max_len
        sched = self._schedule(max_new, precision_schedule, request_class)
        pm = self._prefill_m(sched, prefill_precision)
        logits, cache = self._prefill(
            self.master, jnp.asarray(prompts, jnp.int32), jnp.int32(pm),
            max_len=self.max_len)
        key = jax.random.PRNGKey(seed)
        out = []
        done = np.zeros((B,), bool)
        t0 = time.perf_counter()
        tok = sample_token(logits, key, temperature, top_k)
        for m in sched:
            tok_np = np.asarray(tok)
            out.append(tok_np)  # per-step host sync (the cost)
            if eos_id is not None:
                done |= tok_np == eos_id
                if done.all():  # every row finished: skip remaining steps
                    break
            logits, cache = self._serve(self.master, cache, tok,
                                        jnp.int32(m))
            key, sub = jax.random.split(key)
            tok = sample_token(logits, sub, temperature, top_k)
        dt = time.perf_counter() - t0
        tokens = (np.stack(out, axis=1) if out
                  else np.zeros((B, 0), np.int32))
        lengths = None
        if eos_id is not None:
            tokens, lengths = _mask_after_eos(tokens, int(eos_id))
        return GenerationResult(tokens=tokens, prompt_len=S,
                                precision_trace=sched[:len(out)],
                                decode_seconds=dt,
                                host_transfers=len(out), lengths=lengths,
                                prefill_precision=pm)

    # -- continuous batching ---------------------------------------------------
    def continuous(self, slots: int = 8, width_policy="max-width",
                   policy: Optional[PrecisionPolicy] = None, **kw):
        """A ContinuousScheduler over this server: requests enter a queue,
        are admitted into free batch slots via per-slot prefill, decode in
        one jitted step with per-slot positions/sampling, and leave on EOS
        or max_new so their slot is immediately re-admitted
        (repro/serve/scheduler.py).  ``width_policy`` selects the per-step
        weight width from the active slots' precision classes ("max-width",
        "width-rr", "slo-degrade", "heterogeneous", or a WidthPolicy
        instance); "heterogeneous" runs every slot at its own wanted width
        in one fused step (per-row dequant, DESIGN.md §14) so no slot is
        ever deferred; ``policy`` defaults to the installed
        PrecisionPolicy.  Resilience knobs
        (DESIGN.md §12) pass through as keywords: ``max_queue`` (bounded
        queue + QueueFull backpressure), ``queue_ttl``, per-request
        deadlines via ``submit``, ``repetition_limit``, and ``faults``
        (repro/serve/faults.py injectors).  The attention KV cache is
        PAGED (repro/serve/pages.py): ``page_size`` / ``n_pages`` size the
        page pool, ``prefill_chunk`` splits long prefills into chunks
        interleaved with decode steps, ``prefix_cache=False`` disables
        cross-request prompt-prefix KV reuse, and ``kv_dtype`` selects the
        page storage dtype (e.g. ``jnp.float8_e4m3fn`` for the int8-class
        KV cache — a tolerance regime, not bitwise).  ``spec_decode``
        turns on self-speculative decoding (DESIGN.md §15: the same packed
        master drafts k tokens at a low width and verifies them in one
        full-width batched step) — True / a draft depth int / a dict of
        SpeculativeConfig fields / a SpeculativeConfig; None inherits the
        policy's ``speculative`` spec, False disables.  ``telemetry``
        (DESIGN.md §16) enables trace spans + wall-clock TTFT/ITL
        recording: True or a ``repro.serve.telemetry.Telemetry`` instance
        (default NullTelemetry — metrics registry only, every trace hook a
        no-op); the scheduler's registry is always live at
        ``sched.metrics`` with ``render_prometheus()``.  Shares this
        server's compiled prefill/decode executables and packed master."""
        from repro.serve.scheduler import ContinuousScheduler
        return ContinuousScheduler(self, slots=slots,
                                   width_policy=width_policy,
                                   policy=policy, **kw)

    # -- accounting ------------------------------------------------------------
    def memory_report(self) -> dict:
        """Bytes: fp16 baseline vs packed master vs truncated stream at the
        current precision (paper Table 2 accounting).  All figures derive
        from core/packed.py's layout constants via ``tree_nbytes`` and
        ``stream_bits_per_param`` — nothing is re-derived here, so the
        accounting cannot drift from the format."""
        nb = self.master_bytes
        stream_bits = packed_lib.stream_bits_per_param(self._m)
        return {
            "n_params": nb["n_params"],
            "fp16_bytes": 2 * nb["n_params"],
            "master_bytes": nb["total_bytes"],
            "master_bits_per_param": packed_lib.stream_bits_per_param(
                packed_lib.MASTER_M),
            "stream_bytes_at_precision": int(
                stream_bits / 8 * nb["packed_params"]) + nb["raw_bytes"],
            "precision": self._m,
        }


def _mask_after_eos(tokens: np.ndarray, eos_id: int):
    """Host-side eos semantics: positions strictly after a row's first
    ``eos_id`` are padded with ``eos_id``; returns (masked, lengths) where
    lengths[b] counts emitted tokens INCLUDING the eos (== width for rows
    that never emitted it).  The prefix up to and including eos is
    untouched, so eos handling never perturbs the generation numerics."""
    B, T = tokens.shape
    hit = tokens == eos_id
    after = (np.cumsum(hit, axis=1) - hit) > 0
    masked = np.where(after, eos_id, tokens)
    lengths = np.where(hit.any(axis=1), hit.argmax(axis=1) + 1, T)
    return masked.astype(tokens.dtype), lengths.astype(np.int64)


def _make_fused_decode(serve_step):
    """Build the fused decode fn: one lax.scan over steps, schedule traced,
    sampling in-body.  Emits the token *consumed* at each step (the token
    sampled from the previous logits), matching the legacy loop exactly."""

    def fused(master, cache, logits0, schedule, key, temperature, top_k):
        tok0 = sample_token(logits0, key, temperature, top_k)

        def body(carry, m_step):
            tok, cache, key = carry
            logits, cache = serve_step(master, cache, tok, m_step)
            key, sub = jax.random.split(key)
            nxt = sample_token(logits, sub, temperature, top_k)
            return (nxt, cache, key), tok

        (_, cache, _), toks = lax.scan(body, (tok0, cache, key), schedule)
        return jnp.swapaxes(toks, 0, 1)  # [T, B] -> [B, T]

    return fused
