"""Switchable-precision serving engine — the paper's deployment story.

One PackedSEFP master (~9.1 bits/param) is kept resident; serving at any
precision E5M8..E5M3 is a mantissa truncation of that master:

  * `set_precision(m)` rebuilds the live weights with a single cheap
    elementwise pass (shift + dequant) — no scale refits, no re-quantization,
    no second model copy (contrast: conventional int quantization needs a
    per-bit-width model zoo, tests/test_sefp_core.py demonstrates why);
  * precision can be switched *mid-generation* — prefill at high precision,
    decode at low (the paper's prefill/decode asymmetry), or per-request by
    task type (generation vs understanding);
  * requests are served in fixed batch slots with a shared KV cache; the
    decode step is one jitted call per token for the whole batch.

The fused HBM-streaming path (dequant inside the matmul kernel,
repro/kernels/sefp_matmul) is what a real TPU serving binary would run for
the big projections; benchmarks/bench_memory_speed.py measures it.  This
engine uses the materialize-on-switch path, which is numerically identical
(tests/test_serving.py asserts it).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packed as packed_lib
from repro.models import model_zoo as Z
from repro.models.config import ModelConfig
from repro.serve.sampler import sample_token


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, new]
    prompt_len: int
    precision_trace: List[int]  # mantissa width used at each decode step
    decode_seconds: float


class SwitchableServer:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        # pack once: the single multi-precision master
        self.master = packed_lib.pack_tree(params)
        self.master_bytes = packed_lib.tree_nbytes(self.master)
        self._m: Optional[int] = None
        self._live = None
        self._serve = jax.jit(Z.make_serve_step(cfg))
        self._prefill = jax.jit(Z.make_prefill(cfg),
                                static_argnames=("max_len",))
        self.set_precision(8)

    # -- precision switching ------------------------------------------------
    def set_precision(self, m: int):
        """Truncate the master to E5M<m>.  One elementwise pass; no scale
        refits (the SEFP property)."""
        if m == self._m:
            return
        self._live = packed_lib.dequantize_tree(
            self.master, jnp.int32(m), dtype=jnp.bfloat16)
        self._m = m

    @property
    def precision(self) -> int:
        return self._m

    # -- serving --------------------------------------------------------------
    def prefill(self, prompts: np.ndarray):
        """prompts: [B, S] int32 (equal-length batch slot).  Returns
        (last_logits, cache)."""
        toks = jnp.asarray(prompts, jnp.int32)
        return self._prefill(self._live, toks, max_len=self.max_len)

    def generate(self, prompts: np.ndarray, max_new: int,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 precision_schedule=None) -> GenerationResult:
        """Batched generation.  ``precision_schedule``: optional callable
        step_idx -> mantissa width, enabling mid-generation switching
        (e.g. prefill/high, decode/low)."""
        B, S = prompts.shape
        assert S + max_new <= self.max_len
        logits, cache = self.prefill(prompts)
        key = jax.random.PRNGKey(seed)
        out = []
        trace = []
        t0 = time.perf_counter()
        tok = sample_token(logits, key, temperature, top_k)
        for i in range(max_new):
            if precision_schedule is not None:
                self.set_precision(int(precision_schedule(i)))
            trace.append(self._m)
            out.append(np.asarray(tok))
            logits, cache = self._serve(self._live, cache, tok)
            key, sub = jax.random.split(key)
            tok = sample_token(logits, sub, temperature, top_k)
        dt = time.perf_counter() - t0
        return GenerationResult(tokens=np.stack(out, axis=1), prompt_len=S,
                                precision_trace=trace, decode_seconds=dt)

    # -- accounting ------------------------------------------------------------
    def memory_report(self) -> dict:
        """Bytes: fp16 baseline vs packed master vs truncated stream at the
        current precision (paper Table 2 accounting)."""
        n_params = 0
        packed_bytes = self.master_bytes["packed_bytes"]
        raw_bytes = self.master_bytes["raw_bytes"]

        def count(leaf):
            nonlocal n_params
            if isinstance(leaf, packed_lib.PackedSEFP):
                n_params += int(np.prod(leaf.shape))
            elif hasattr(leaf, "size"):
                n_params += int(leaf.size)
            return leaf

        jax.tree_util.tree_map(
            count, self.master,
            is_leaf=lambda x: isinstance(x, packed_lib.PackedSEFP))
        m = self._m or 8
        stream_bits = (m + 1) + 8.0 / 64
        return {
            "n_params": n_params,
            "fp16_bytes": 2 * n_params,
            "master_bytes": packed_bytes + raw_bytes,
            "stream_bytes_at_precision": int(
                stream_bits / 8 * (packed_bytes / (9.125 / 8))) + raw_bytes,
            "precision": m,
        }
