"""Encoder-decoder backbone (Seamless-M4T-v2 text/speech backbone).

The speech frontend is a STUB per the brief: the encoder consumes
precomputed frame embeddings [B, S_enc, d] (as if produced by the conformer
feature extractor).  The decoder is a standard causal transformer with
cross-attention; decode shapes run the DECODER (self-attn KV cache +
precomputed cross-attention K/V), since the arch is enc-dec, not
encoder-only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import (_fit_block, _remat, _stack_init,
                                      attn_cache_init, dense_layer_init)


def dec_layer_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(k1, cfg),
        "lnx": L.rmsnorm_init(cfg.d_model),
        "xattn": L.attention_init(k2, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff),
    }


def encdec_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    return {
        "dec_embed": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model),
        "enc_layers": _stack_init(lambda k: dense_layer_init(k, cfg), ks[1],
                                  cfg.n_enc_layers),
        "dec_layers": _stack_init(lambda k: dec_layer_init(k, cfg), ks[2],
                                  cfg.n_dec_layers),
        "ln_enc": L.rmsnorm_init(cfg.d_model),
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "unembed": L.unembed_init(ks[3], cfg.d_model, cfg.vocab_size),
    }


def encode(params, enc_embeds, cfg: ModelConfig):
    """enc_embeds: [B, S_enc, d] (frontend stub output) -> [B, S_enc, d]."""
    S = enc_embeds.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, lp):
        def f(p, x):
            x = x + L.attention_apply(
                p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                positions, causal=False)
            x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"],
                                                    cfg.norm_eps))
            return x
        return _remat(f, cfg)(lp, x), None

    x, _ = lax.scan(body, enc_embeds, params["enc_layers"])
    return L.rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def decode_train(params, enc_out, dec_tokens, cfg: ModelConfig):
    """Teacher-forced decoder pass -> hidden [B, S_dec, d]."""
    x = L.embed(params["dec_embed"], dec_tokens, enc_out.dtype)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, lp):
        def f(p, x):
            x = x + L.attention_apply(
                p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                positions, causal=True)
            k, v = L.cross_kv(p["xattn"], enc_out, cfg)
            x = x + L.cross_attention_apply(
                p["xattn"], L.rmsnorm(x, p["lnx"], cfg.norm_eps), cfg, k, v)
            x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"],
                                                    cfg.norm_eps))
            return x
        return _remat(f, cfg)(lp, x), None

    x, _ = lax.scan(body, x, params["dec_layers"])
    return L.rmsnorm(x, params["ln_f"], cfg.norm_eps)


def encdec_init_cache(params, enc_out, cfg: ModelConfig, max_len: int,
                      dtype=jnp.bfloat16):
    """Build the decoder cache: per-layer self-attn KV (empty, max_len) +
    per-layer precomputed cross K/V from the encoder output."""
    def xkv(lp):
        return L.cross_kv(lp["xattn"], enc_out, cfg)

    xk, xv = jax.vmap(xkv)(params["dec_layers"])  # [L, B, S_enc, KV, hd]
    B = enc_out.shape[0]
    self_cache = jax.vmap(
        lambda _: attn_cache_init(cfg, B, max_len, dtype))(
        jnp.arange(cfg.n_dec_layers))
    return {"self": self_cache,
            "cross_k": xk.astype(dtype), "cross_v": xv.astype(dtype),
            "pos": jnp.zeros((), jnp.int32)}


def encdec_decode_hidden(params, x_emb, cache, cfg: ModelConfig):
    """One decoder token. x_emb: [B,1,d] -> (hidden, new cache)."""
    pos = cache["pos"]

    def body(x, inp):
        lp, sc, xk, xv = inp
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        o, kc, vc = L.attention_decode(lp["attn"], h, cfg, sc["k"], sc["v"],
                                       pos)
        x = x + o
        x = x + L.cross_attention_apply(
            lp["xattn"], L.rmsnorm(x, lp["lnx"], cfg.norm_eps), cfg, xk, xv)
        x = x + L.mlp_apply(lp["mlp"], L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, {"k": kc, "v": vc}

    x, new_self = lax.scan(
        body, x_emb,
        (params["dec_layers"], cache["self"], cache["cross_k"],
         cache["cross_v"]))
    h = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return h, {**cache, "self": new_self, "pos": pos + 1}
