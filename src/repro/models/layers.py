"""Shared neural-net layers: norms, rotary, blockwise (flash) attention,
GQA projections, SwiGLU MLP, embeddings, chunked cross-entropy.

All weights are stored ``[in_features, out_features]`` (``x @ W``), so the
SEFP group/contraction axis is axis 0 — matching PackedSEFP's k-major layout
and the sefp_matmul kernel.

Attention is blockwise with an online-softmax (flash) formulation: nested
scans over query and key/value blocks keep live attention memory at
O(q_block * kv_block) regardless of sequence length — required for the
32k-prefill cells and friendly to remat.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.sharding.constraints import constrain_batch

NEG_INF = -1e30


def truncated_normal(key, shape, std, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                       jnp.float32).astype(dtype) * std


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"norm_scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(x, params, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps) * params["norm_scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise (flash) attention
# ---------------------------------------------------------------------------

@functools.partial(jax.checkpoint, static_argnums=(5, 6))
def _attend_block(q_blk, k_blk, v_blk, qpos, kpos, causal, scale):
    """q_blk [B,qb,KV,G,hd]; k_blk/v_blk [B,kb,KV,hd]; returns un-normalized
    (m, l, o) contribution of this kv block.  checkpointed: the backward
    pass recomputes the O(qb*kb) score/prob tensors instead of saving one
    per (q-block, kv-block) pair — without this, training memory scales as
    O(S^2) again and the 32k cells blow past HBM."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk.astype(jnp.float32),
                   k_blk.astype(jnp.float32)) * scale  # [B,KV,G,qb,kb]
    if causal:
        mask = kpos[None, :] > qpos[:, None]            # [qb, kb]
        s = jnp.where(mask[None, None, None], NEG_INF, s)
    m = jnp.max(s, axis=-1)                              # [B,KV,G,qb]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                              # [B,KV,G,qb]
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v_blk.astype(jnp.float32))
    return m, l, o


def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 512,
                    kv_block: int = 1024, q_offset=0) -> jax.Array:
    """q: [B,Sq,H,hd]; k,v: [B,Skv,KV,hd] with H % KV == 0 (GQA).
    q_offset: global position of q[0] (for chunked prefill).  Requires
    Sq % q_block == 0 and Skv % kv_block == 0."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    q = constrain_batch(q.reshape(B, Sq, KV, G, hd))
    k = constrain_batch(k)
    v = constrain_batch(v)
    nqb, nkb = Sq // q_block, Skv // kv_block
    q_offset = jnp.asarray(q_offset, jnp.int32)

    def q_step(_, qi):
        q_blk = lax.dynamic_slice_in_dim(q, qi * q_block, q_block, axis=1)
        qpos = q_offset + qi * q_block + jnp.arange(q_block, dtype=jnp.int32)

        def kv_step(carry, ki):
            m, l, o = carry
            k_blk = lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, 1)
            v_blk = lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, 1)
            kpos = ki * kv_block + jnp.arange(kv_block, dtype=jnp.int32)
            bm, bl, bo = _attend_block(q_blk, k_blk, v_blk, qpos, kpos,
                                       causal, scale)
            new_m = jnp.maximum(m, bm)
            alpha = jnp.exp(m - new_m)
            beta = jnp.exp(bm - new_m)
            new_l = l * alpha + bl * beta
            new_o = o * alpha[..., None] + bo * beta[..., None]
            return (new_m, new_l, new_o), None

        # constrained inits: GSPMD's propagation through while-loop carries
        # is weak — without these the whole attention runs batch-replicated.
        init = (
            constrain_batch(jnp.full((B, KV, G, q_block), NEG_INF,
                                     jnp.float32)),
            constrain_batch(jnp.zeros((B, KV, G, q_block), jnp.float32)),
            constrain_batch(jnp.zeros((B, KV, G, q_block, hd), jnp.float32)),
        )
        (m, l, o), _ = lax.scan(kv_step, init, jnp.arange(nkb))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        # [B,KV,G,qb,hd] -> [B,qb,KV,G,hd]
        return None, jnp.transpose(o, (0, 3, 1, 2, 4))

    _, outs = lax.scan(q_step, None, jnp.arange(nqb))  # [nqb,B,qb,KV,G,hd]
    out = jnp.transpose(outs, (1, 0, 2, 3, 4, 5)).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_len=None) -> jax.Array:
    """Single-token attention: q [B,1,H,hd] vs cache [B,S,KV,hd].
    kv_len: optional int32 — number of valid cache positions; either a
    scalar (lockstep batch, every row at the same position) or int32[B]
    (continuous batching: per-slot causal masking over the shared cache —
    each row sees only its own valid prefix).  The scalar branch is kept
    byte-for-byte as before; per-row masking is the same elementwise
    ``where`` with a broadcast over the batch axis, so a row masked at
    kv_len=n is bitwise identical either way."""
    B, _, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qr.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if kv_len is not None:
        pos = jnp.arange(S, dtype=jnp.int32)
        kv_len = jnp.asarray(kv_len)
        if kv_len.ndim == 0:
            s = jnp.where(pos[None, None, None] >= kv_len, NEG_INF, s)
        else:  # per-slot valid lengths [B]
            s = jnp.where(pos[None, None, None, :]
                          >= kv_len[:, None, None, None], NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projections + rope + attention)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": truncated_normal(ks[0], (d, H * hd), std),
        "wk": truncated_normal(ks[1], (d, KV * hd), std),
        "wv": truncated_normal(ks[2], (d, KV * hd), std),
        "wo": truncated_normal(ks[3], (H * hd, d), (H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["q_bias"] = jnp.zeros((H * hd,), jnp.float32)
        p["k_bias"] = jnp.zeros((KV * hd,), jnp.float32)
        p["v_bias"] = jnp.zeros((KV * hd,), jnp.float32)
    return p


def qkv_project(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    q = (x @ params["wq"].astype(dt))
    k = (x @ params["wk"].astype(dt))
    v = (x @ params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["q_bias"].astype(dt)
        k = k + params["k_bias"].astype(dt)
        v = v + params["v_bias"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(params, x, cfg: ModelConfig, positions=None,
                    causal: bool = True):
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = qkv_project(params, x, cfg, positions)
    qb = min(cfg.q_block, S)
    kb = min(cfg.kv_block, S)
    while S % qb:
        qb //= 2
    while S % kb:
        kb //= 2
    o = flash_attention(q, k, v, causal=causal, q_block=qb, kv_block=kb)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return o @ params["wo"].astype(x.dtype)


def attention_decode(params, x, cfg: ModelConfig, k_cache, v_cache, pos):
    """x: [B,1,d]; caches [B,S,KV,hd]; pos: current position — int32[]
    (lockstep: one position broadcast to every row, the original path,
    unchanged) or int32[B] (continuous batching: each slot writes its k/v
    at its OWN position and attends to its own causal prefix of the shared
    cache).  Returns (out [B,1,d], new_k_cache, new_v_cache)."""
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        positions = jnp.broadcast_to(pos, (B, 1))
        q, k, v = qkv_project(params, x, cfg, positions)
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), pos, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), pos, axis=1)
    else:  # per-slot positions [B]: vmapped row-wise cache update
        positions = pos[:, None]
        q, k, v = qkv_project(params, x, cfg, positions)

        def row_update(c, new, p):
            return lax.dynamic_update_slice_in_dim(c, new, p, axis=0)

        k_cache = jax.vmap(row_update)(k_cache, k.astype(k_cache.dtype), pos)
        v_cache = jax.vmap(row_update)(v_cache, v.astype(v_cache.dtype), pos)
    o = decode_attention(q, k_cache, v_cache, kv_len=pos + 1)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
    return o @ params["wo"].astype(x.dtype), k_cache, v_cache


def paged_attention_decode(params, x, cfg: ModelConfig, k_pages, v_pages,
                           block_table, pos, page_size: int):
    """Single-token attention against a PAGED KV cache (one layer's slice).

    x: [B,1,d]; k_pages/v_pages: [n_pages, page_size, KV, hd]; block_table:
    int32[B, max_pages] mapping each row's logical page (``position //
    page_size``) to a physical page; pos: int32[B] per-slot positions.

    Row b writes its k/v at ``(block_table[b, pos[b] // page_size],
    pos[b] % page_size)`` — one scatter touching exactly one page slot per
    row (active rows' write pages are exclusive by the allocator's sharing
    rule, so rows never collide; free rows all land in null page 0, whose
    contents are never read unmasked).  Attention then runs over the
    gathered block-table view ``[B, max_pages * page_size, KV, hd]``: view
    index IS logical position, so the per-slot causal mask (``kv_len =
    pos + 1``) is unchanged from the dense path, and masked positions
    (stale pages, the null page) contribute exact zeros — the view is
    bitwise equivalent to the dense per-slot row it replaces.
    Returns (out [B,1,d], new_k_pages, new_v_pages)."""
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None]
    q, k, v = qkv_project(params, x, cfg, positions)
    pg = jnp.take_along_axis(block_table, (pos // page_size)[:, None],
                             axis=1)[:, 0]                      # [B]
    off = pos % page_size
    k_pages = k_pages.at[pg, off].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[pg, off].set(v[:, 0].astype(v_pages.dtype))
    kc = k_pages[block_table].reshape(B, -1, *k_pages.shape[2:])
    vc = v_pages[block_table].reshape(B, -1, *v_pages.shape[2:])
    o = decode_attention(q, kc, vc, kv_len=pos + 1)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
    return o @ params["wo"].astype(x.dtype), k_pages, v_pages


def verify_attention(q, k_cache, v_cache, kv_len) -> jax.Array:
    """Multi-position attention for draft verification: q [B,S,H,hd] vs
    cache [B,Skv,KV,hd] with a per-(row, query) causal mask.

    kv_len: int32[B,S] — query (b, i) sees only cache positions
    ``< kv_len[b, i]``.  The speculative verify step feeds S = k+1 query
    positions per row at positions ``pos[b] .. pos[b]+k``, each seeing its
    own prefix (``kv_len[b, i] = pos[b] + i + 1``), so every query row is
    the same elementwise score/softmax program as `decode_attention` run
    solo at that position — the view index IS the logical position, exactly
    as in the decode path."""
    B, S, H, hd = q.shape
    _, Skv, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qr.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale     # [B,KV,G,S,Skv]
    tpos = jnp.arange(Skv, dtype=jnp.int32)
    mask = tpos[None, None, :] >= kv_len[:, :, None]        # [B,S,Skv]
    s = jnp.where(mask[:, None, None], NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bkgqh", p, v_cache.astype(jnp.float32))
    o = jnp.moveaxis(o, 3, 1)                               # [B,S,KV,G,hd]
    return o.reshape(B, S, H, hd).astype(q.dtype)


def paged_attention_verify(params, x, cfg: ModelConfig, k_pages, v_pages,
                           block_table, pos, page_size: int, n_used):
    """Batched multi-position attention against a PAGED KV cache — the
    speculative verify analogue of `paged_attention_decode`.

    x: [B,S,d] — S = k+1 candidate positions per row, row b's query i at
    logical position ``pos[b] + i``; k_pages/v_pages:
    [n_pages, page_size, KV, hd]; block_table: int32[B, max_pages]; pos:
    int32[B] (the position of the first candidate, i.e. the slot's current
    decode position); n_used: int32[B] — row b only verifies its first
    ``n_used[b]`` positions (0 for non-speculative rows riding the same
    fixed-shape dispatch).

    Each row scatters its VALID cells at ``(block_table[b, (pos[b]+i) //
    page_size], (pos[b]+i) % page_size)`` — decode-region cells are
    exclusive per slot (only full immutable prompt pages are ever shared),
    so valid rows never collide; padded queries (``i >= n_used[b]``) are
    routed to null page 0, whose contents are never read unmasked, so a
    short or non-participating row can never corrupt a live cell.
    Attention runs over the gathered block-table view with a per-query
    causal mask (`verify_attention`), overwriting the draft's low-width
    K/V with full-width bytes in the same pass.
    Returns (out [B,S,d], new_k_pages, new_v_pages)."""
    B, S, _ = x.shape
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = qkv_project(params, x, cfg, positions)
    valid = jnp.arange(S, dtype=jnp.int32)[None, :] < n_used[:, None]
    logical = jnp.minimum(positions // page_size,
                          block_table.shape[1] - 1)
    pg = jnp.take_along_axis(block_table, logical, axis=1)   # [B,S]
    pg = jnp.where(valid, pg, 0)
    off = jnp.where(valid, positions % page_size, 0)
    k_pages = k_pages.at[pg, off].set(k.astype(k_pages.dtype))
    v_pages = v_pages.at[pg, off].set(v.astype(v_pages.dtype))
    kc = k_pages[block_table].reshape(B, -1, *k_pages.shape[2:])
    vc = v_pages[block_table].reshape(B, -1, *v_pages.shape[2:])
    o = verify_attention(q, kc, vc, kv_len=positions + 1)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return o @ params["wo"].astype(x.dtype), k_pages, v_pages


def cross_attention_apply(params, x, cfg: ModelConfig, k, v):
    """Decoder cross-attention against precomputed encoder k/v
    [B,S_enc,KV,hd].  Non-causal; x may be [B,S,d] or [B,1,d]."""
    B, S, _ = x.shape
    hd, H = cfg.hd, cfg.n_heads
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, H, hd)
    if S == 1:
        o = decode_attention(q, k, v)
    else:
        kb = min(cfg.kv_block, k.shape[1])
        while k.shape[1] % kb:
            kb //= 2
        qb = min(cfg.q_block, S)
        while S % qb:
            qb //= 2
        o = flash_attention(q, k, v, causal=False, q_block=qb, kv_block=kb)
    o = o.reshape(B, S, H * hd)
    return o @ params["wo"].astype(dt)


def cross_kv(params, enc_out, cfg: ModelConfig):
    B, S, _ = enc_out.shape
    dt = enc_out.dtype
    k = (enc_out @ params["wk"].astype(dt)).reshape(
        B, S, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ params["wv"].astype(dt)).reshape(
        B, S, cfg.n_kv_heads, cfg.hd)
    return k, v


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": truncated_normal(ks[0], (d, f), d ** -0.5),
        "w_up": truncated_normal(ks[1], (d, f), d ** -0.5),
        "w_down": truncated_normal(ks[2], (f, d), f ** -0.5),
    }


def mlp_apply(params, x):
    dt = x.dtype
    g = jax.nn.silu(x @ params["w_gate"].astype(dt))
    u = x @ params["w_up"].astype(dt)
    return (g * u) @ params["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings + chunked cross-entropy
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int):
    return {"embedding": truncated_normal(key, (vocab, d), 0.02)}


def embed(params, ids, dtype):
    return jnp.take(params["embedding"], ids, axis=0).astype(dtype)


def unembed_init(key, d: int, vocab: int):
    return {"w_unembed": truncated_normal(key, (d, vocab), d ** -0.5)}


def chunked_softmax_xent(h, unembed_params, labels, chunk: int,
                         label_mask=None):
    """Mean next-token cross-entropy without materializing [B,S,V] logits:
    scan over sequence chunks, rematerializing logits in backward.
    h: [B,S,d]; labels: [B,S] int32."""
    B, S, d = h.shape
    w = unembed_params["w_unembed"]
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    h = h.reshape(B, n, chunk, d)
    labels = labels.reshape(B, n, chunk)
    if label_mask is not None:
        label_mask = label_mask.reshape(B, n, chunk)

    @jax.checkpoint
    def chunk_loss(h_c, y_c, m_c):
        h_c = constrain_batch(h_c)
        logits = (h_c.astype(jnp.float32)
                  @ w.astype(jnp.float32))            # [B,chunk,V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        # target logit via masked reduction (NOT take_along_axis: a gather
        # over the vocab-sharded dim makes GSPMD replicate the batch and
        # all-gather multi-GiB logits; an iota-compare reduce shards clean).
        vocab_iota = lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        tgt = jnp.sum(jnp.where(vocab_iota == y_c[..., None], logits, 0.0),
                      axis=-1)
        nll = lse - tgt
        if m_c is not None:
            nll = nll * m_c
            return nll.sum(), m_c.sum()
        return nll.sum(), jnp.asarray(nll.size, jnp.float32)

    def body(carry, i):
        tot, cnt = carry
        m_c = None if label_mask is None else label_mask[:, i]
        s, c = chunk_loss(h[:, i], labels[:, i], m_c)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                             jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


def logits_for_last(h_last, unembed_params):
    """h_last: [B,1,d] -> [B,vocab] (decode head)."""
    w = unembed_params["w_unembed"]
    return (h_last[:, 0].astype(jnp.float32) @ w.astype(jnp.float32))
