"""Mixture-of-Experts layer with two dispatch strategies.

"capacity" (train/prefill): scatter-based token dispatch — tokens are routed
to fixed-capacity expert buffers via cumsum positions and gather/scatter, so
HLO FLOPs stay proportional to top_k (not n_experts) and everything is
static-shaped / pjit-friendly.  Dispatch is chunked along the sequence
(capacity is per chunk) to bound the transient [E, C, d] buffers.

"dense" (decode / tiny models): every expert runs on every token and
non-selected contributions are zeroed by the combine weights.  For decode
this is the right call: with realistic batches every expert's weights must
stream from HBM anyway (the memory roofline is unchanged), and it avoids
gather/scatter latency on a tiny-FLOP step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal


def moe_init(key, cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    f, e = cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": truncated_normal(ks[0], (d, e), d ** -0.5),
        "w_gate": truncated_normal(ks[1], (e, d, f), d ** -0.5),
        "w_up": truncated_normal(ks[2], (e, d, f), d ** -0.5),
        "w_down": truncated_normal(ks[3], (e, f, d), f ** -0.5),
    }


def _route(params, x, cfg: ModelConfig):
    """x: [T, d] -> (weights [T, k], sel [T, k]) with normalized top-k."""
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, sel = lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, sel


def _expert_ffn(params, xe, dt):
    """xe: [E, C, d] -> [E, C, d]; batched SwiGLU over experts."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                               params["w_gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", g * u, params["w_down"].astype(dt))


def _moe_chunk_capacity(params, x, cfg: ModelConfig):
    """x: [T, d] (one dispatch chunk). Returns [T, d]."""
    T, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(T * k / e * cfg.moe_capacity_factor)
    cap = max(8, -(-cap // 8) * 8)  # round up to 8
    dt = x.dtype

    w, sel = _route(params, x, cfg)                     # [T, k]
    flat_sel = sel.reshape(-1)                          # [T*k]
    flat_w = w.reshape(-1)
    # position of each assignment within its expert (priority = token order)
    onehot = jax.nn.one_hot(flat_sel, e, dtype=jnp.int32)      # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1                       # [T*k, E]
    pos = jnp.take_along_axis(pos, flat_sel[:, None], axis=1)[:, 0]
    valid = pos < cap
    dest = jnp.where(valid, flat_sel * cap + pos, e * cap)     # overflow slot

    # token index for each (expert, capacity) slot
    tok_of_assign = jnp.arange(T * k, dtype=jnp.int32) // k
    idx_buf = jnp.zeros((e * cap + 1,), jnp.int32).at[dest].set(
        tok_of_assign, mode="drop")
    gate_buf = jnp.zeros((e * cap + 1,), jnp.float32).at[dest].set(
        flat_w, mode="drop")

    xe = jnp.take(x, idx_buf[:-1].reshape(e, cap), axis=0)     # [E, C, d]
    ye = _expert_ffn(params, xe, dt)                           # [E, C, d]
    ye = ye.reshape(e * cap, d) * gate_buf[:-1, None].astype(dt)
    out = jnp.zeros((T, d), dt).at[idx_buf[:-1]].add(ye, mode="drop")
    return out


def _moe_dense(params, x, cfg: ModelConfig):
    """x: [T, d]. All experts computed; combine weights zero the rest."""
    T, d = x.shape
    e = cfg.n_experts
    dt = x.dtype
    w, sel = _route(params, x, cfg)                      # [T, k]
    combine = jnp.zeros((T, e), jnp.float32)
    combine = combine.at[jnp.arange(T)[:, None], sel].add(w)   # [T, E]
    ye = _expert_ffn(params, jnp.broadcast_to(x, (e, T, d)).astype(dt)
                     .reshape(e, T, d), dt)              # [E, T, d]
    return jnp.einsum("etd,te->td", ye, combine.astype(dt))


def moe_apply(params, x, cfg: ModelConfig, dispatch_chunk: int = 4096):
    """x: [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    use_dense = cfg.moe_dispatch == "dense" or S == 1 or (B * S) <= 64
    if use_dense:
        out = _moe_dense(params, x.reshape(B * S, d), cfg)
        return out.reshape(B, S, d)

    chunk = min(dispatch_chunk, S)
    while S % chunk:
        chunk //= 2
    rows = x.reshape(B * (S // chunk), chunk, d)

    @jax.checkpoint
    def row_fn(xr):
        # checkpointed: backward recomputes the [E, C, d] dispatch buffers
        # per chunk instead of saving them all.
        return _moe_chunk_capacity(params, xr, cfg)

    out = lax.map(row_fn, rows)
    return out.reshape(B, S, d)


def aux_load_balance_loss(params, x, cfg: ModelConfig):
    """Standard Switch-style load-balance auxiliary (mean over tokens)."""
    T = x.shape[0] * x.shape[1]
    xf = x.reshape(T, -1)
    logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, sel = lax.top_k(probs, cfg.top_k)
    frac = jax.nn.one_hot(sel, cfg.n_experts).sum((0, 1)) / (T * cfg.top_k)
    imp = probs.mean(0)
    return cfg.n_experts * jnp.sum(frac * imp)
