"""Model/arch configuration dataclasses and the assigned input-shape sets."""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "capacity"          # capacity | dense

    # SSM (Mamba2) / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_every: int = 6                     # hybrid: shared attn cadence
    n_shared_attn_blocks: int = 2

    # RWKV6
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 64

    # encoder-decoder
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # modality frontend (stub: inputs arrive as embeddings)
    frontend: str = "none"                  # none | vision_stub | audio_stub
    n_prefix_embeds: int = 0                # prefix embeddings per example

    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    dtype: str = "bfloat16"                 # activation/compute dtype
    param_dtype: str = "float32"            # master weight dtype
    remat: str = "full"                     # none | dots | full

    # loss / head
    loss_chunk: int = 512                   # sequence chunking for CE loss

    # attention blocking (flash-style)
    q_block: int = 512
    kv_block: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.family in ("encdec",)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32,
            ssm_chunk=16,
            rwkv_head_dim=32,
            rwkv_chunk=8,
            attn_every=2,
            n_enc_layers=min(self.n_enc_layers, 2) if self.n_enc_layers else 0,
            n_dec_layers=min(self.n_dec_layers, 2) if self.n_dec_layers else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 4),
            q_block=32,
            kv_block=32,
            loss_chunk=32,
            remat="none",
            name=self.name + "-reduced",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode | long_decode


# The assigned input-shape set (LM-family: seq_len x global_batch).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}

# Families allowed to run long_500k (sub-quadratic sequence mixing).
SUBQUADRATIC_FAMILIES = ("hybrid", "rwkv")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; returns (ok, reason)."""
    if shape.kind == "long_decode" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("pure full-attention arch: 500k dense-KV decode "
                       "requires sub-quadratic mixing (DESIGN.md §5)")
    return True, ""
