"""RWKV6 "Finch" block — attention-free time mixing with data-dependent decay.

Per-head (hd=64) linear-attention-style recurrence over state S [hd_k, hd_v]:

    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
    y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])

with the decay w_t = exp(-exp(w0 + lora_w(x_mix))) *data-dependent* (the
Finch contribution).  Training/prefill use a chunked formulation: within a
chunk the output is a causal pairwise-decay einsum (all decay exponents are
<= 0, so nothing overflows), across chunks the [B, H, hd, hd] state is
carried by lax.scan — O(S) time, constant state, which is what makes the
500k cells feasible.

The decay parameters (time_decay_*, lora) and bonus u are excluded from SEFP
(DESIGN.md §5); the d x d projections are quantized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal
from repro.sharding.constraints import constrain_batch


def rdims(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return d, H, hd


def rwkv6_init(key, cfg: ModelConfig, d: int | None = None):
    d, H, hd = rdims(cfg, d)
    ks = jax.random.split(key, 8)
    lora = 64 if d >= 1024 else 16
    return {
        # token-shift mixing coefficients (static part)
        "time_mix_r": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_k": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_v": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_w": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_g": jnp.full((d,), 0.5, jnp.float32),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "time_decay_w0": jnp.full((d,), -3.0, jnp.float32),
        "time_decay_A": truncated_normal(ks[0], (d, lora), d ** -0.5),
        "time_decay_B": truncated_normal(ks[1], (lora, d), lora ** -0.5),
        "time_bonus_u": truncated_normal(ks[2], (H, hd), 0.1),
        "wr": truncated_normal(ks[3], (d, d), d ** -0.5),
        "wk": truncated_normal(ks[4], (d, d), d ** -0.5),
        "wv": truncated_normal(ks[5], (d, d), d ** -0.5),
        "wg": truncated_normal(ks[6], (d, d), d ** -0.5),
        "wo": truncated_normal(ks[7], (d, d), d ** -0.5),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
    }


def _mix(x, x_prev, mu):
    """token shift: lerp(x_t, x_{t-1}, mu) (mu toward previous token)."""
    return x + (x_prev - x) * mu[None, None, :].astype(x.dtype)


def _project(params, x, x_prev):
    dt = x.dtype
    xr = _mix(x, x_prev, params["time_mix_r"]) @ params["wr"].astype(dt)
    xk = _mix(x, x_prev, params["time_mix_k"]) @ params["wk"].astype(dt)
    xv = _mix(x, x_prev, params["time_mix_v"]) @ params["wv"].astype(dt)
    xg = _mix(x, x_prev, params["time_mix_g"]) @ params["wg"].astype(dt)
    xw = _mix(x, x_prev, params["time_mix_w"])
    loga = -jnp.exp(
        params["time_decay_w0"][None, None]
        + jnp.tanh(xw.astype(jnp.float32) @ params["time_decay_A"])
        @ params["time_decay_B"])                        # [B,S,d]  (<= 0)
    return xr, xk, xv, xg, loga


def _group_norm(y, scale, H, hd, eps):
    """per-head layer norm of the wkv output."""
    B, S, d = y.shape
    yf = y.astype(jnp.float32).reshape(B, S, H, hd)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mean) * lax.rsqrt(var + eps)
    return (yn.reshape(B, S, d) * scale).astype(y.dtype)


def rwkv6_apply(params, x, cfg: ModelConfig, d: int | None = None):
    """Full-sequence (train). x: [B, S, d] -> [B, S, d]."""
    y, _ = _rwkv6_forward(params, x, cfg, d, want_state=False)
    return y


def rwkv6_apply_with_state(params, x, cfg: ModelConfig, d: int | None = None):
    """Full-sequence prefill; also returns the final wkv state
    [B, H, hd, hd]."""
    return _rwkv6_forward(params, x, cfg, d, want_state=True)


def _rwkv6_forward(params, x, cfg: ModelConfig, d: int | None,
                   want_state: bool):
    d, H, hd = rdims(cfg, d)
    B, S, _ = x.shape
    dt_ = x.dtype
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, loga = _project(params, x, x_prev)

    rf = r.astype(jnp.float32).reshape(B, S, H, hd)
    kf = k.astype(jnp.float32).reshape(B, S, H, hd)
    vf = v.astype(jnp.float32).reshape(B, S, H, hd)
    la = loga.reshape(B, S, H, hd)
    u = params["time_bonus_u"]                          # [H,hd]

    L = min(cfg.rwkv_chunk, S)
    while S % L:
        L //= 2
    nc = S // L

    @jax.checkpoint
    def chunk_step(S0, inp):
        # checkpointed: backward recomputes the O(L^2 * d) pairwise-decay
        # tensor instead of saving one per chunk.
        rk, kk, vk, lak = inp                            # [B,L,H,hd] each
        lcum = jnp.cumsum(lak, axis=1)                   # [B,L,H,hd]
        # pairwise decay exponent for s < t:  lcum_{t-1} - lcum_s  (<= 0)
        # (prod of w over u in (s, t-1]); for s = t-1 it is 0.
        lq = lcum - lak                                  # lcum_{t-1} rel chunk
        e = lq[:, :, None] - lcum[:, None, :]            # [B,L,L,H,hd]
        strict = jnp.tril(jnp.ones((L, L), jnp.float32), -1)
        decay = jnp.exp(e) * strict[None, :, :, None, None]
        A = jnp.einsum("bthi,bshi,btshi->bhts", rk, kk, decay)
        # bonus diagonal
        diag = jnp.einsum("bthi,hi,bthi->bth", rk, u, kk)
        y = jnp.einsum("bhts,bshj->bthj", A, vk)
        y = y + diag[..., None] * vk
        # initial-state contribution: r_t * exp(lcum_{t-1}) . S0
        rdec = rk * jnp.exp(lq)
        y = y + jnp.einsum("bthi,bhij->bthj", rdec, S0)
        # state update: S_L = exp(lcum_L) S0 + sum_s exp(lcum_L - lcum_s) k_s v_s
        ltot = lcum[:, -1]                               # [B,H,hd]
        kdec = kk * jnp.exp(ltot[:, None] - lcum)
        S_new = (jnp.exp(ltot)[..., None] * S0
                 + jnp.einsum("bshi,bshj->bhij", kdec, vk))
        return S_new, y

    # constrained carry/inputs (see mamba2.py — while-carry batch sharding)
    S0 = constrain_batch(jnp.zeros((B, H, hd, hd), jnp.float32),
                         extra=((1, "model"),))
    inps = tuple(jnp.moveaxis(
        constrain_batch(a.reshape(B, nc, L, H, hd), extra=((3, "model"),)),
        1, 0) for a in (rf, kf, vf, la))
    S_final, ys = lax.scan(chunk_step, S0, inps)         # [nc,B,L,H,hd]
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d)
    y = _group_norm(y, params["ln_x_scale"], H, hd, cfg.norm_eps)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(dt_)
    out = y @ params["wo"].astype(dt_)
    return out, (S_final if want_state else None)


def rwkv6_init_cache(cfg: ModelConfig, batch: int, d: int | None = None,
                     dtype=jnp.float32):
    d, H, hd = rdims(cfg, d)
    return {
        "wkv_state": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "shift_state": jnp.zeros((batch, 1, d), dtype),
    }


def rwkv6_decode(params, x, cache, cfg: ModelConfig, d: int | None = None):
    """Single token. x: [B,1,d] -> (y [B,1,d], new_cache)."""
    d, H, hd = rdims(cfg, d)
    B = x.shape[0]
    dt_ = x.dtype
    x_prev = cache["shift_state"].astype(dt_)
    r, k, v, g, loga = _project(params, x, x_prev)
    rf = r.astype(jnp.float32).reshape(B, H, hd)
    kf = k.astype(jnp.float32).reshape(B, H, hd)
    vf = v.astype(jnp.float32).reshape(B, H, hd)
    w = jnp.exp(loga.reshape(B, H, hd))                  # decay in (0,1)
    u = params["time_bonus_u"]
    S0 = cache["wkv_state"]
    kv = kf[..., :, None] * vf[..., None, :]             # [B,H,hd,hd]
    y = jnp.einsum("bhi,bhij->bhj", rf, S0 + u[None, :, :, None] * kv)
    S_new = w[..., None] * S0 + kv
    y = y.reshape(B, 1, d)
    y = _group_norm(y, params["ln_x_scale"], H, hd, cfg.norm_eps)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(dt_)
    new_cache = {"wkv_state": S_new, "shift_state": x.astype(
        cache["shift_state"].dtype)}
    return y @ params["wo"].astype(dt_), new_cache
