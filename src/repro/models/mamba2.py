"""Mamba2 (SSD) block — chunked state-space scan.

Per-layer structure (simplified-but-faithful Mamba2, n_groups=1):
  in_proj d -> [z(d_in), x(d_in), B(N), C(N), dt(H)]  (d_in = expand*d)
  causal depthwise conv (width 4) over [x, B, C]
  SSD recurrence with per-head scalar decay:
      h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * (x_t ⊗ B_t)   h: [H, P, N]
      y_t = (h_t · C_t) + D_h * x_t
  gated RMSNorm (silu(z)) then out_proj d_in -> d.

Training/prefill use the chunked SSD algorithm: within a chunk the
contribution is an attention-like causal matmul with pairwise decay, across
chunks a [B, H, P, N] state is carried by lax.scan — O(S) time, O(chunk^2)
memory, which is what makes the 500k-token cells feasible.

Recurrence parameters (A_log, ssm_dt_bias, ssm_D, conv kernels) are excluded
from SEFP quantization (DESIGN.md §5); the large in/out projections are
quantized like any other weight.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal
from repro.sharding.constraints import constrain_batch


def dims(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    d_in = cfg.ssm_expand * d
    P = cfg.ssm_head_dim
    H = d_in // P
    N = cfg.ssm_state
    return d, d_in, H, P, N


def mamba2_init(key, cfg: ModelConfig, d: int | None = None):
    d, d_in, H, P, N = dims(cfg, d)
    ks = jax.random.split(key, 7)
    conv_ch = d_in + 2 * N
    # separate projections per output (NOT one fused [d, 2*d_in+2N+H]
    # matrix): the fused form's split boundaries cross 16-way TP shard
    # boundaries, forcing GSPMD to all-gather the full projection every
    # layer (~0.6 TB/step observed on the zamba2-7b train dry-run); the
    # split matrices shard independently and stay aligned.
    return {
        "in_proj_z": truncated_normal(ks[0], (d, d_in), d ** -0.5),
        "in_proj_x": truncated_normal(ks[1], (d, d_in), d ** -0.5),
        "in_proj_B": truncated_normal(ks[2], (d, N), d ** -0.5),
        "in_proj_C": truncated_normal(ks[3], (d, N), d ** -0.5),
        "in_proj_dt": truncated_normal(ks[5], (d, H), d ** -0.5),
        "conv_kernel": truncated_normal(ks[4], (cfg.ssm_conv_width, conv_ch),
                                        0.1),
        "conv_bias": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "ssm_D": jnp.ones((H,), jnp.float32),
        "ssm_dt_bias": jnp.log(jnp.expm1(
            jnp.full((H,), 0.01, jnp.float32))),  # softplus^-1(0.01)
        "gate_norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": truncated_normal(ks[6], (d_in, d), d_in ** -0.5),
    }


def _split_proj(params, x, cfg: ModelConfig, d: int):
    dt_ = x.dtype
    z = x @ params["in_proj_z"].astype(dt_)
    xi = x @ params["in_proj_x"].astype(dt_)
    Bc = x @ params["in_proj_B"].astype(dt_)
    Cc = x @ params["in_proj_C"].astype(dt_)
    dt = x @ params["in_proj_dt"].astype(dt_)
    return z, xi, Bc, Cc, dt


def _causal_conv(u, kernel, bias, width: int):
    """u: [B, S, C]; depthwise causal conv via stacked shifts."""
    out = u * kernel[width - 1][None, None, :]
    for i in range(1, width):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :u.shape[1]]
        out = out + shifted * kernel[width - 1 - i][None, None, :]
    return out + bias[None, None, :]


def _gated_norm(y, z, scale, eps):
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yn = yf * lax.rsqrt(var + eps) * scale
    return (yn * jax.nn.silu(z.astype(jnp.float32))).astype(y.dtype)


def mamba2_apply(params, x, cfg: ModelConfig, d: int | None = None):
    """Full-sequence (train). x: [B, S, d] -> [B, S, d]."""
    y, _ = _mamba2_forward(params, x, cfg, d, want_state=False)
    return y


def mamba2_apply_with_state(params, x, cfg: ModelConfig,
                            d: int | None = None):
    """Full-sequence prefill; also returns the decode cache
    {ssm_state, conv_state}."""
    return _mamba2_forward(params, x, cfg, d, want_state=True)


def _mamba2_forward(params, x, cfg: ModelConfig, d: int | None,
                    want_state: bool):
    d, d_in, H, P, N = dims(cfg, d)
    B, S, _ = x.shape
    dt_ = x.dtype
    z, xi, Bc, Cc, dtr = _split_proj(params, x, cfg, d)
    conv_in = jnp.concatenate([xi, Bc, Cc], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, params["conv_kernel"],
                                    params["conv_bias"], cfg.ssm_conv_width))
    xi, Bc, Cc = jnp.split(conv, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + params["ssm_dt_bias"])        # [B,S,H]
    A = -jnp.exp(params["A_log"])                        # [H], negative
    xh = xi.reshape(B, S, H, P).astype(jnp.float32)
    Bc = Bc.astype(jnp.float32)
    Cc = Cc.astype(jnp.float32)

    L = min(cfg.ssm_chunk, S)
    while S % L:
        L //= 2
    nc = S // L
    # reshape into chunks
    dt_c = dt.reshape(B, nc, L, H)
    x_c = xh.reshape(B, nc, L, H, P)
    B_c = Bc.reshape(B, nc, L, N)
    C_c = Cc.reshape(B, nc, L, N)

    @jax.checkpoint
    def chunk_step(h0, inp):
        # checkpointed: backward recomputes the O(L^2) intra-chunk decay
        # tensors instead of saving one per chunk.
        dtk, xk, Bk, Ck = inp                            # [B,L,H],[B,L,H,P],..
        loga = dtk * A[None, None, :]                    # [B,L,H]  (<= 0)
        lcum = jnp.cumsum(loga, axis=1)                  # [B,L,H]
        # intra-chunk: y[t] += sum_{s<=t} exp(lcum_t - lcum_s) dt_s (C_t.B_s) x_s
        G = jnp.einsum("btn,bsn->bts", Ck, Bk)           # [B,L,L]
        decay = jnp.exp(lcum[:, :, None, :] - lcum[:, None, :, :])  # [B,L,L,H]
        causal = jnp.tril(jnp.ones((L, L), jnp.float32))
        M = G[..., None] * decay * dtk[:, None, :, :] * causal[None, :, :,
                                                               None]
        y = jnp.einsum("btsh,bshp->bthp", M, xk)         # [B,L,H,P]
        # inter-chunk: contribution of incoming state
        y = y + jnp.exp(lcum)[..., None] * jnp.einsum(
            "btn,bhpn->bthp", Ck, h0)
        # state update
        ltot = lcum[:, -1]                               # [B,H]
        w_s = jnp.exp(ltot[:, None, :] - lcum) * dtk     # [B,L,H]
        h_new = (jnp.exp(ltot)[:, :, None, None] * h0
                 + jnp.einsum("bsh,bshp,bsn->bhpn", w_s, xk, Bk))
        return h_new, y

    # constrained carries/inputs: GSPMD propagation through while-loop
    # carries is weak — without these the chunk scan runs batch-replicated
    # (observed: ~0.6 TB/step of all-gathers on zamba2-7b train).  Heads
    # shard over the model axis when divisible.
    h0 = constrain_batch(jnp.zeros((B, H, P, N), jnp.float32),
                         extra=((1, "model"),))
    dt_c = constrain_batch(dt_c, extra=((3, "model"),))
    x_c = constrain_batch(x_c, extra=((3, "model"),))
    B_c = constrain_batch(B_c)
    C_c = constrain_batch(C_c)
    # scan over chunks
    inps = (jnp.moveaxis(dt_c, 1, 0), jnp.moveaxis(x_c, 1, 0),
            jnp.moveaxis(B_c, 1, 0), jnp.moveaxis(C_c, 1, 0))
    h_final, ys = lax.scan(chunk_step, h0, inps)         # [nc,B,L,H,P]
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + params["ssm_D"][None, None, :, None] * xh
    y = y.reshape(B, S, d_in).astype(dt_)
    y = _gated_norm(y, z, params["gate_norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    if not want_state:
        return out, None
    # decode cache: final ssm state + last (width-1) conv inputs
    w = cfg.ssm_conv_width
    tail = conv_in[:, -(w - 1):] if S >= w - 1 else jnp.pad(
        conv_in, ((0, 0), (w - 1 - S, 0), (0, 0)))
    cache = {"ssm_state": h_final, "conv_state": tail.astype(dt_)}
    return out, cache


def mamba2_init_cache(cfg: ModelConfig, batch: int, d: int | None = None,
                      dtype=jnp.float32):
    d, d_in, H, P, N = dims(cfg, d)
    conv_ch = d_in + 2 * N
    return {
        "ssm_state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_state": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch),
                                dtype),
    }


def mamba2_decode(params, x, cache, cfg: ModelConfig, d: int | None = None):
    """Single-token step. x: [B, 1, d]; returns (y [B,1,d], new_cache)."""
    d, d_in, H, P, N = dims(cfg, d)
    B = x.shape[0]
    dt_ = x.dtype
    z, xi, Bc, Cc, dtr = _split_proj(params, x, cfg, d)
    u = jnp.concatenate([xi, Bc, Cc], axis=-1)           # [B,1,C]
    hist = jnp.concatenate([cache["conv_state"], u.astype(
        cache["conv_state"].dtype)], axis=1)             # [B,W,C]
    kernel = params["conv_kernel"]
    conv = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                      kernel.astype(jnp.float32)) + params["conv_bias"]
    conv = jax.nn.silu(conv)[:, None, :]
    xi, Bc, Cc = jnp.split(conv, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32)
                         + params["ssm_dt_bias"])        # [B,H]
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A[None, :])                         # [B,H]
    xh = xi.reshape(B, H, P).astype(jnp.float32)
    h = cache["ssm_state"]
    h = (a[:, :, None, None] * h
         + (dt[:, :, None] * xh)[..., None] * Bc[:, 0][:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", h, Cc[:, 0].astype(jnp.float32))
    y = y + params["ssm_D"][None, :, None] * xh
    y = y.reshape(B, 1, d_in).astype(dt_)
    y = _gated_norm(y, z, params["gate_norm_scale"], cfg.norm_eps)
    new_cache = {"ssm_state": h, "conv_state": hist[:, 1:]}
    return y @ params["out_proj"].astype(dt_), new_cache
