"""Model facade: loss functions, serve steps and input specs per family.

This is the single entry point the trainer, the serving engine, the dry-run
launcher and the benchmarks all use:

    loss_fn   = make_loss_fn(cfg)            # loss_fn(params, batch)
    serve_fn  = make_serve_step(cfg)          # serve_fn(params, cache, token)
    specs     = input_specs(cfg, shape)       # ShapeDtypeStruct stand-ins

Frontend stubs (per brief): [vlm] batches carry precomputed patch embeddings,
[audio] batches carry precomputed frame embeddings; the backbone is real.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeConfig

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def act_dtype(cfg: ModelConfig):
    return DTYPES[cfg.dtype]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.is_encdec:
        return ED.encdec_init(key, cfg)
    return T.lm_init(key, cfg)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# losses (training)
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig):
    dt = act_dtype(cfg)

    if cfg.is_encdec:
        def loss_fn(params, batch):
            enc_out = ED.encode(params, batch["enc_embeds"].astype(dt), cfg)
            h = ED.decode_train(params, enc_out, batch["inputs"], cfg)
            return L.chunked_softmax_xent(h, params["unembed"],
                                          batch["targets"], cfg.loss_chunk)
        return loss_fn

    if cfg.family == "vlm":
        def loss_fn(params, batch):
            tok = L.embed(params["embed"], batch["inputs"], dt)
            x = jnp.concatenate([batch["patch_embeds"].astype(dt), tok],
                                axis=1)
            h = T.lm_apply_hidden(params, x, cfg)
            npfx = batch["patch_embeds"].shape[1]
            h_txt = h[:, npfx:]
            return L.chunked_softmax_xent(h_txt, params["unembed"],
                                          batch["targets"], cfg.loss_chunk)
        return loss_fn

    def loss_fn(params, batch):
        x = L.embed(params["embed"], batch["inputs"], dt)
        h = T.lm_apply_hidden(params, x, cfg)
        mask = batch.get("mask")
        return L.chunked_softmax_xent(h, params["unembed"],
                                      batch["targets"], cfg.loss_chunk,
                                      label_mask=mask)

    return loss_fn


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, params, batch: int, max_len: int,
               enc_out=None, dtype=jnp.bfloat16):
    if cfg.is_encdec:
        assert enc_out is not None
        return ED.encdec_init_cache(params, enc_out, cfg, max_len, dtype)
    return T.lm_init_cache(cfg, batch, max_len, dtype)


def make_serve_step(cfg: ModelConfig):
    """serve_fn(params, cache, token[B] int32) -> (logits [B,V], new_cache).
    One new token against the current cache (the decode shapes' step)."""
    dt = act_dtype(cfg)

    def serve_fn(params, cache, token):
        if cfg.is_encdec:
            x = L.embed(params["dec_embed"], token[:, None], dt)
            h, cache = ED.encdec_decode_hidden(params, x, cache, cfg)
        else:
            x = L.embed(params["embed"], token[:, None], dt)
            h, cache = T.lm_decode_hidden(params, x, cache, cfg)
        logits = L.logits_for_last(h, params["unembed"])
        return logits, cache

    return serve_fn


def make_prefill(cfg: ModelConfig):
    """prefill(params, tokens [B,S]) -> (last_logits [B,V], cache)."""
    dt = act_dtype(cfg)

    def prefill_fn(params, tokens, max_len: int):
        x = L.embed(params["embed"], tokens, dt)
        h, cache = T.lm_prefill_hidden(params, x, cfg, max_len)
        logits = L.logits_for_last(h[:, -1:], params["unembed"])
        return logits, cache

    return prefill_fn


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def enc_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Encoder frame count for enc-dec cells (documented: seq/4)."""
    return max(64, seq_len // 4)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of a given shape cell.
    For train/prefill kinds this is the training batch; decode kinds get
    {token} (the cache spec comes from cache_specs())."""
    B, S = shape.global_batch, shape.seq_len
    dt = act_dtype(cfg)
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.is_encdec:
            return {
                "enc_embeds": _sds((B, enc_len_for(cfg, S), cfg.d_model), dt),
                "inputs": _sds((B, S), i32),
                "targets": _sds((B, S), i32),
            }
        if cfg.family == "vlm":
            npfx = cfg.n_prefix_embeds
            return {
                "patch_embeds": _sds((B, npfx, cfg.d_model), dt),
                "inputs": _sds((B, S - npfx), i32),
                "targets": _sds((B, S - npfx), i32),
            }
        return {"inputs": _sds((B, S), i32), "targets": _sds((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.is_encdec:
            return {
                "enc_embeds": _sds((B, enc_len_for(cfg, S), cfg.d_model), dt),
                "inputs": _sds((B, S), i32),
            }
        if cfg.family == "vlm":
            npfx = cfg.n_prefix_embeds
            return {
                "patch_embeds": _sds((B, npfx, cfg.d_model), dt),
                "inputs": _sds((B, S - npfx), i32),
            }
        return {"inputs": _sds((B, S), i32)}
    # decode kinds
    return {"token": _sds((B,), i32)}


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """prefill_step(params, batch) -> (last_logits, cache): process the full
    prompt and build the decode cache (the inference-prefill cell)."""
    dt = act_dtype(cfg)

    if cfg.is_encdec:
        def step(params, batch):
            enc_out = ED.encode(params, batch["enc_embeds"].astype(dt), cfg)
            h = ED.decode_train(params, enc_out, batch["inputs"], cfg)
            cache = ED.encdec_init_cache(params, enc_out, cfg, max_len)
            return L.logits_for_last(h[:, -1:], params["unembed"]), cache
        return step

    if cfg.family == "vlm":
        def step(params, batch):
            tok = L.embed(params["embed"], batch["inputs"], dt)
            x = jnp.concatenate([batch["patch_embeds"].astype(dt), tok], 1)
            h, cache = T.lm_prefill_hidden(params, x, cfg, max_len)
            return L.logits_for_last(h[:, -1:], params["unembed"]), cache
        return step

    def step(params, batch):
        x = L.embed(params["embed"], batch["inputs"], dt)
        h, cache = T.lm_prefill_hidden(params, x, cfg, max_len)
        return L.logits_for_last(h[:, -1:], params["unembed"]), cache

    return step


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct pytree of the decode cache at this shape."""
    B, S = shape.global_batch, shape.seq_len

    def shapes_of(tree):
        return jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)

    if cfg.is_encdec:
        def build():
            params = ED.encdec_init(jax.random.PRNGKey(0), cfg)
            enc_out = jnp.zeros((B, enc_len_for(cfg, S), cfg.d_model), dtype)
            return ED.encdec_init_cache(params, enc_out, cfg, S, dtype)
        return jax.eval_shape(build)

    def build():
        return T.lm_init_cache(cfg, B, S, dtype)

    return jax.eval_shape(build)
