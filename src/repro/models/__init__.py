from repro.models.config import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
)
from repro.models.model_zoo import (  # noqa: F401
    act_dtype,
    cache_specs,
    init_cache,
    init_params,
    input_specs,
    make_loss_fn,
    make_prefill,
    make_serve_step,
    param_count,
)
