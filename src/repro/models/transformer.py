"""Model assembly: per-family blocks + scan-over-layers stacks + decode.

Families
  dense / vlm : pre-norm GQA attention + SwiGLU MLP (vlm adds a stubbed
                patch-embedding prefix; the backbone is identical)
  moe         : pre-norm GQA attention + MoE FFN
  hybrid      : Mamba2 backbone; every ``attn_every`` layers one of
                ``n_shared_attn_blocks`` *shared* attention blocks is invoked
                on concat(h, first-layer embeddings) (Zamba2 wiring)
  rwkv        : RWKV6 time mix + RWKV channel mix
  encdec      : see repro/models/encdec.py

Homogeneous stacks are scanned (lax.scan over stacked layer params) with a
configurable remat policy — one layer's HLO regardless of depth, which keeps
512-device dry-run compiles tractable and is how the real deployment would
be built anyway.

Three execution modes per family:
  apply   : full sequence -> hidden states (training)
  prefill : full sequence -> (hidden, cache)   (serving, padded to max_len)
  decode  : one token + cache -> (hidden, new cache)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models.config import ModelConfig


def _resolve(resolve, layer_params):
    """Apply an optional per-layer parameter transform.  The serving engine
    passes the packed-master dequant here (repro/serve/packed_step.py), so
    the int8/uint8 master arrays are what lax.scan slices per layer and the
    dequant sits right next to its consuming matmuls inside the scan body —
    XLA fuses it into the dot operands and only packed bytes stream from
    HBM.  ``None`` (training / unpacked serving) is the identity."""
    return layer_params if resolve is None else resolve(layer_params)


# ---------------------------------------------------------------------------
# width-heterogeneous decode: per-row precision inside one fused step
# ---------------------------------------------------------------------------
#
# The decode step is row-independent in the batch dimension (attention
# masks per-row positions, the MLP/matmuls act per row), so a batch whose
# slots want DIFFERENT SEFP widths can be served in one step by sweeping a
# static candidate ladder: run the layer at each width that is present
# (lax.cond skips absent ones) and merge outputs row-wise.  Row i of the
# merged result is bitwise identical to running the whole batch at scalar
# width m_rows[i] and reading row i — the same dot shapes, the same fp32
# reduction order — which is what the heterogeneous-vs-lockstep oracle
# tests pin down (tests/test_hetero.py).


def _hetero_bcast(mask, ndim: int):
    """Broadcast a [B] row mask against a batch-major leaf of rank ndim."""
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def _hetero_sweep(run, m_rows, widths):
    """Run ``run(w)`` (a layer at static python width ``w``) once per
    candidate width, skipping widths no row wants, and merge the outputs
    row-wise: row i keeps the results of the run at ``m_rows[i]``.  Every
    output leaf must be batch-major (dense caches, hidden states); rows
    whose width is absent from the ladder come back zero — serve callers
    validate ladder membership on the host."""
    proto = jax.eval_shape(run, widths[0])

    def zeros():
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), proto)

    acc = zeros()
    for w in widths:
        rmask = m_rows == w
        out = lax.cond(jnp.any(rmask), functools.partial(run, w), zeros)
        acc = jax.tree_util.tree_map(
            lambda n, o, rm=rmask: jnp.where(_hetero_bcast(rm, n.ndim), n, o),
            out, acc)
    return acc


def _hetero_sweep_paged(run, m_rows, widths, kp, vp, block_table, pos,
                        page_size: int):
    """``_hetero_sweep`` for a paged attention layer: ``run(w)`` returns
    ``(x, k_pages, v_pages)`` where the pages are pool-shaped (shared
    across rows), not batch-major.  One decode step writes exactly one
    (page, offset) cell per row (see layers.paged_attention_decode), so
    per-row merging of the pages is a surgical per-cell select seeded from
    the INPUT pages — the same pattern slots.select_paged uses to unwind
    rejected rows — while the hidden state merges row-wise as usual."""
    pg = jnp.take_along_axis(block_table, (pos // page_size)[:, None],
                             axis=1)[:, 0]
    off = pos % page_size
    proto = jax.eval_shape(run, widths[0])

    def zeros():
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), proto)

    acc_x = jnp.zeros(proto[0].shape, proto[0].dtype)
    acc_kp, acc_vp = kp, vp
    for w in widths:
        rmask = m_rows == w
        x_w, kp_w, vp_w = lax.cond(jnp.any(rmask),
                                   functools.partial(run, w), zeros)
        acc_x = jnp.where(_hetero_bcast(rmask, x_w.ndim), x_w, acc_x)
        keep = _hetero_bcast(rmask, acc_kp[pg, off].ndim)
        acc_kp = acc_kp.at[pg, off].set(
            jnp.where(keep, kp_w[pg, off], acc_kp[pg, off]))
        acc_vp = acc_vp.at[pg, off].set(
            jnp.where(keep, vp_w[pg, off], acc_vp[pg, off]))
    return acc_x, acc_kp, acc_vp


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _stack_init(layer_init, key, n_layers: int):
    keys = jax.random.split(key, n_layers)
    return jax.vmap(layer_init)(keys)


# ---------------------------------------------------------------------------
# dense / moe layers
# ---------------------------------------------------------------------------

def dense_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff),
    }


def moe_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "moe": MOE.moe_init(k2, cfg),
    }


def _ffn_apply(p, h, cfg: ModelConfig):
    if "moe" in p:
        return MOE.moe_apply(p["moe"], h, cfg)
    return L.mlp_apply(p["mlp"], h)


def attn_layer_apply(p, x, cfg: ModelConfig, positions=None, causal=True):
    x = x + L.attention_apply(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                              cfg, positions, causal)
    x = x + _ffn_apply(p, L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    return x


def attn_layer_prefill(p, x, cfg: ModelConfig, max_len: int, positions=None):
    """Like apply, but also returns the (padded) kv cache for this layer."""
    B, S, _ = x.shape
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q, k, v = L.qkv_project(p["attn"], h, cfg, positions)
    qb = _fit_block(cfg.q_block, S)
    kb = _fit_block(cfg.kv_block, S)
    o = L.flash_attention(q, k, v, causal=True, q_block=qb, kv_block=kb)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    x = x + o @ p["attn"]["wo"].astype(x.dtype)
    x = x + _ffn_apply(p, L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    pad = max_len - S
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x, {"k": kc, "v": vc}


def attn_layer_decode(p, x, cache, cfg: ModelConfig, pos):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, kc, vc = L.attention_decode(p["attn"], h, cfg, cache["k"], cache["v"],
                                   pos)
    x = x + o
    x = x + _ffn_apply(p, L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    return x, {"k": kc, "v": vc}


def attn_layer_decode_paged(p, x, k_pages, v_pages, block_table,
                            cfg: ModelConfig, pos, page_size: int):
    """``attn_layer_decode`` against one layer's KV pages (serve/pages.py):
    the row's k/v is scattered into its block-table page and attention
    reads the gathered view — bitwise the dense row path (layers.py)."""
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, kp, vp = L.paged_attention_decode(p["attn"], h, cfg, k_pages,
                                         v_pages, block_table, pos,
                                         page_size)
    x = x + o
    x = x + _ffn_apply(p, L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    return x, kp, vp


def attn_layer_verify_paged(p, x, k_pages, v_pages, block_table,
                            cfg: ModelConfig, pos, page_size: int, n_used):
    """``attn_layer_decode_paged`` generalized to S candidate positions per
    row (speculative verify): x is ``[B, S, d]`` with row b's query i at
    global position ``pos[b] + i``, attending its own causal prefix through
    the block-table view exactly like the chunked-prefill path does
    (view index == logical position, per-query horizon).  ``n_used`` rows
    the write mask — see layers.paged_attention_verify."""
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, kp, vp = L.paged_attention_verify(p["attn"], h, cfg, k_pages,
                                         v_pages, block_table, pos,
                                         page_size, n_used)
    x = x + o
    x = x + _ffn_apply(p, L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    return x, kp, vp


def attn_layer_prefill_paged(p, x, k_pages, v_pages, block_table, start,
                             cfg: ModelConfig, page_size: int,
                             positions=None):
    """One prefill CHUNK of one layer against the paged cache: x is
    ``[1, C, d]`` at global positions ``start + [0, C)``.  Attention runs
    over the block-table view with the chunk's fresh k/v spliced in at
    ``start`` — earlier chunks (and reused prefix pages) are read from the
    pages, so a chunk only ever computes O(C * view) work and the whole
    chunked prefill is bitwise the un-chunked one (q rows are independent
    in flash attention; positions beyond the causal horizon contribute
    exact zeros).  Returns (x_out, k_chunk, v_chunk) — the caller scatters
    the chunk k/v into the pages once, after the layer scan."""
    B, C, _ = x.shape
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if positions is None:
        positions = start + jnp.arange(C, dtype=jnp.int32)[None, :]
    q, k, v = L.qkv_project(p["attn"], h, cfg, positions)
    view = block_table.shape[0] * page_size
    kview = k_pages[block_table].reshape(1, view, *k_pages.shape[2:])
    vview = v_pages[block_table].reshape(1, view, *v_pages.shape[2:])
    kview = lax.dynamic_update_slice_in_dim(
        kview, k.astype(kview.dtype), start, axis=1)
    vview = lax.dynamic_update_slice_in_dim(
        vview, v.astype(vview.dtype), start, axis=1)
    qb = _fit_block(cfg.q_block, C)
    kb = _fit_block(cfg.kv_block, view)
    o = L.flash_attention(q, kview, vview, causal=True, q_block=qb,
                          kv_block=kb, q_offset=start)
    o = o.reshape(B, C, cfg.n_heads * cfg.hd)
    x = x + o @ p["attn"]["wo"].astype(x.dtype)
    x = x + _ffn_apply(p, L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg)
    return x, k, v


def _fit_block(b, s):
    b = min(b, s)
    while s % b:
        b //= 2
    return max(b, 1)


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    kv = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}


# ---------------------------------------------------------------------------
# rwkv layer (time mix + channel mix)
# ---------------------------------------------------------------------------

def rwkv_channel_mix_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "time_mix_k": jnp.full((d,), 0.5, jnp.float32),
        "time_mix_r": jnp.full((d,), 0.5, jnp.float32),
        "wk_ffn": L.truncated_normal(k1, (d, f), d ** -0.5),
        "wv_ffn": L.truncated_normal(k2, (f, d), f ** -0.5),
        "wr_ffn": L.truncated_normal(k3, (d, d), d ** -0.5),
    }


def rwkv_channel_mix(p, x, x_prev):
    dt = x.dtype
    xk = x + (x_prev - x) * p["time_mix_k"][None, None, :].astype(dt)
    xr = x + (x_prev - x) * p["time_mix_r"][None, None, :].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["wk_ffn"].astype(dt)))
    r = jax.nn.sigmoid((xr @ p["wr_ffn"].astype(dt)).astype(jnp.float32))
    return r.astype(dt) * (k @ p["wv_ffn"].astype(dt))


def rwkv_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "tmix": R6.rwkv6_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "cmix": rwkv_channel_mix_init(k2, cfg),
    }


def rwkv_layer_apply(p, x, cfg: ModelConfig):
    x = x + R6.rwkv6_apply(p["tmix"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                           cfg)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    x = x + rwkv_channel_mix(p["cmix"], h, h_prev)
    return x


def rwkv_layer_decode(p, x, cache, cfg: ModelConfig):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, tcache = R6.rwkv6_decode(p["tmix"], h, cache["tmix"], cfg)
    x = x + o
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    o = rwkv_channel_mix(p["cmix"], h, cache["cmix_shift"].astype(h.dtype))
    x = x + o
    return x, {"tmix": tcache,
               "cmix_shift": h.astype(cache["cmix_shift"].dtype)}


def rwkv_cache_init(cfg: ModelConfig, batch: int, dtype):
    return {"tmix": R6.rwkv6_init_cache(cfg, batch, dtype=dtype),
            "cmix_shift": jnp.zeros((batch, 1, cfg.d_model), dtype)}


# ---------------------------------------------------------------------------
# hybrid (zamba2) layer pieces
# ---------------------------------------------------------------------------

def hybrid_layer_init(key, cfg: ModelConfig):
    return {
        "ln": L.rmsnorm_init(cfg.d_model),
        "mamba": M2.mamba2_init(key, cfg),
    }


def hybrid_shared_block_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "fuse_proj": L.truncated_normal(k1, (2 * d, d), (2 * d) ** -0.5),
        "ln1": L.rmsnorm_init(d),
        "attn": L.attention_init(k2, cfg),
        "ln2": L.rmsnorm_init(d),
        "mlp": L.mlp_init(k3, d, cfg.d_ff),
    }


def hybrid_shared_block_apply(p, x, emb0, cfg: ModelConfig, positions=None):
    dt = x.dtype
    h = jnp.concatenate([x, emb0], axis=-1) @ p["fuse_proj"].astype(dt)
    h = h + L.attention_apply(p["attn"], L.rmsnorm(h, p["ln1"], cfg.norm_eps),
                              cfg, positions, causal=True)
    h = h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
    return x + h


def hybrid_shared_block_decode(p, x, emb0, cache, cfg: ModelConfig, pos):
    dt = x.dtype
    h = jnp.concatenate([x, emb0], axis=-1) @ p["fuse_proj"].astype(dt)
    hn = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
    o, kc, vc = L.attention_decode(p["attn"], hn, cfg, cache["k"], cache["v"],
                                   pos)
    h = h + o
    h = h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
    return x + h, {"k": kc, "v": vc}


def hybrid_shared_block_decode_paged(p, x, emb0, k_pages, v_pages,
                                     block_table, cfg: ModelConfig, pos,
                                     page_size: int):
    """``hybrid_shared_block_decode`` with the shared attention KV paged;
    the Mamba2 recurrent state is position-free and stays dense per-slot."""
    dt = x.dtype
    h = jnp.concatenate([x, emb0], axis=-1) @ p["fuse_proj"].astype(dt)
    hn = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
    o, kp, vp = L.paged_attention_decode(p["attn"], hn, cfg, k_pages,
                                         v_pages, block_table, pos,
                                         page_size)
    h = h + o
    h = h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps))
    return x + h, kp, vp


def n_attn_invocations(cfg: ModelConfig) -> int:
    return len(range(0, cfg.n_layers, cfg.attn_every))


# ---------------------------------------------------------------------------
# decoder-only LM
# ---------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model),
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "unembed": L.unembed_init(ks[1], cfg.d_model, cfg.vocab_size),
    }
    if cfg.family == "hybrid":
        params["layers"] = _stack_init(
            lambda k: hybrid_layer_init(k, cfg), ks[2], cfg.n_layers)
        params["shared"] = _stack_init(
            lambda k: hybrid_shared_block_init(k, cfg), ks[3],
            cfg.n_shared_attn_blocks)
    elif cfg.family == "rwkv":
        params["layers"] = _stack_init(
            lambda k: rwkv_layer_init(k, cfg), ks[2], cfg.n_layers)
    elif cfg.family == "moe":
        params["layers"] = _stack_init(
            lambda k: moe_layer_init(k, cfg), ks[2], cfg.n_layers)
    else:  # dense / vlm
        params["layers"] = _stack_init(
            lambda k: dense_layer_init(k, cfg), ks[2], cfg.n_layers)
    return params


def _hybrid_apply(params, x_emb, cfg: ModelConfig, positions):
    """Segment structure: shared attention BEFORE mamba layers 0,
    attn_every, 2*attn_every, ... then a scan over that segment's mamba
    layers — the same cadence prefill/decode use.

    Deliberately NOT a single scan with lax.cond over the attention: a cond
    inside a scanned layer makes autodiff save the attention branch's
    residuals for every one of the n_layers iterations instead of the ~14
    real invocations (observed: 227 GiB/device on the zamba2-7b train_4k
    dry-run; segments + remat bring it back to layer-boundary scale)."""
    emb0 = x_emb
    nshared = cfg.n_shared_attn_blocks
    x = x_emb

    from repro.sharding.constraints import constrain_batch

    def mamba_seg_body(x, lp):
        def f(lp, x):
            return x + M2.mamba2_apply(
                lp["mamba"], L.rmsnorm(x, lp["ln"], cfg.norm_eps), cfg)
        # pin the residual stream to batch-sharded / d-replicated at block
        # boundaries (canonical megatron annotation) — otherwise GSPMD keeps
        # x sharded on d and emits fp32 all-gathers around every block
        # (~0.6 TB/step observed on zamba2-7b train).
        return constrain_batch(_remat(f, cfg)(lp, x)), None

    def attn_block(sp, x):
        return hybrid_shared_block_apply(sp, x, emb0, cfg, positions)

    for inv_idx, start in enumerate(range(0, cfg.n_layers, cfg.attn_every)):
        end = min(start + cfg.attn_every, cfg.n_layers)
        sp = jax.tree_util.tree_map(
            lambda a, i=inv_idx % nshared: a[i], params["shared"])
        x = constrain_batch(_remat(attn_block, cfg)(sp, x))
        seg = jax.tree_util.tree_map(lambda a: a[start:end],
                                     params["layers"])
        x, _ = lax.scan(mamba_seg_body, x, seg)
    return x


def lm_apply_hidden(params, x_emb, cfg: ModelConfig, positions=None):
    """Run the stack on embeddings [B,S,d] -> final hidden [B,S,d]."""
    if cfg.family == "hybrid":
        x = _hybrid_apply(params, x_emb, cfg, positions)
    elif cfg.family == "rwkv":
        def body(x, lp):
            return _remat(lambda p, x: rwkv_layer_apply(p, x, cfg), cfg)(
                lp, x), None
        x, _ = lax.scan(body, x_emb, params["layers"])
    else:
        def body(x, lp):
            return _remat(
                lambda p, x: attn_layer_apply(p, x, cfg, positions), cfg)(
                lp, x), None
        x, _ = lax.scan(body, x_emb, params["layers"])
    return L.rmsnorm(x, params["ln_f"], cfg.norm_eps)


# -- caches ------------------------------------------------------------------

def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16, per_slot: bool = False):
    """Stacked decode cache for the whole model + position counter.

    ``per_slot=True`` makes the position counter ``int32[batch]`` instead of
    a scalar — the continuous-batching cache shape (repro/serve/slots.py):
    each batch slot tracks its own sequence position and ``attention_decode``
    writes/masks the shared KV cache per row.  The scalar form is the
    lockstep shape (every request in the batch at the same position)."""
    pos0 = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    if cfg.family == "hybrid":
        def one_layer(_):
            return M2.mamba2_init_cache(cfg, batch, dtype=dtype)
        layer_caches = jax.vmap(one_layer)(jnp.arange(cfg.n_layers))
        n_inv = n_attn_invocations(cfg)
        attn_caches = jax.vmap(
            lambda _: attn_cache_init(cfg, batch, max_len, dtype))(
            jnp.arange(n_inv))
        return {"layers": layer_caches, "attn": attn_caches, "pos": pos0}
    if cfg.family == "rwkv":
        layer_caches = jax.vmap(
            lambda _: rwkv_cache_init(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))
        return {"layers": layer_caches, "pos": pos0}
    layer_caches = jax.vmap(
        lambda _: attn_cache_init(cfg, batch, max_len, dtype))(
        jnp.arange(cfg.n_layers))
    return {"layers": layer_caches, "pos": pos0}


def lm_init_paged_cache(cfg: ModelConfig, n_slots: int, n_pages: int,
                        page_size: int, dtype=jnp.bfloat16, kv_dtype=None):
    """The continuous-batching cache with the attention KV PAGED
    (serve/pages.py): instead of a dense ``[*, n_slots, max_len, KV, hd]``
    row per slot, all slots share a pool of ``n_pages`` fixed-size pages
    indexed through per-slot block tables (kept by the scheduler, passed
    to the step as a traced argument).  ``kv_dtype`` applies to the KV
    pages only — int8-family storage composes with any SEFP weight width
    (tests/test_kv8_cache.py); recurrent state keeps ``dtype``.

    dense/moe/vlm : {"pages": {"k","v" [L, n_pages, ps, KV, hd]}, "pos"}
    hybrid        : Mamba2 state dense per-slot + shared-attention pages
                    stacked over the ``n_attn_invocations``
    rwkv          : no attention KV exists — the dense per-slot cache is
                    returned unchanged (nothing to page)."""
    kv_dtype = dtype if kv_dtype is None else kv_dtype
    pos0 = jnp.zeros((n_slots,), jnp.int32)

    def pages(stack: int):
        shape = (stack, n_pages, page_size, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, kv_dtype),
                "v": jnp.zeros(shape, kv_dtype)}

    if cfg.family == "rwkv":
        return lm_init_cache(cfg, n_slots, 0, dtype, per_slot=True)
    if cfg.family == "hybrid":
        def one_layer(_):
            return M2.mamba2_init_cache(cfg, n_slots, dtype=dtype)
        layer_caches = jax.vmap(one_layer)(jnp.arange(cfg.n_layers))
        return {"layers": layer_caches,
                "pages": pages(n_attn_invocations(cfg)), "pos": pos0}
    return {"pages": pages(cfg.n_layers), "pos": pos0}


# -- decode (one token) --------------------------------------------------------

def lm_decode_hidden(params, x_emb, cache, cfg: ModelConfig, resolve=None,
                     layer_unroll: int = 1, hetero=None):
    """x_emb: [B,1,d]; returns (hidden [B,1,d], new_cache).  ``cache["pos"]``
    may be a scalar (lockstep decode) or ``int32[B]`` (continuous batching:
    per-slot positions threaded through ``attention_decode`` for row-wise
    cache writes and per-slot causal masking — see ``lm_init_cache``
    ``per_slot=``); every family handles both, since only attention consumes
    ``pos``.  ``resolve``
    (optional) maps each layer's parameter slice before use — the packed
    master's in-scan dequant hook (see ``_resolve``).  ``layer_unroll``
    unrolls the layer scan by that factor: per-step compute is tiny at
    decode, so on backends with per-iteration loop overhead (CPU) an
    unrolled body lets XLA fuse across layers (~3x step latency on the CPU
    serving bench); keep 1 (pure scan) where HLO compactness matters
    (deep-model dry-run lowerings).

    ``hetero`` (optional) is ``(m_rows, widths)``: an int32 [B] per-row
    SEFP width vector plus the static candidate ladder.  When set,
    ``resolve`` must be the TWO-argument form ``resolve(layer_slice, w)``
    (w a static python int) and every layer runs the width-heterogeneous
    sweep (see ``_hetero_sweep``): row i is decoded at ``m_rows[i]``,
    bitwise identical to a lockstep batch at that scalar width."""
    pos = cache["pos"]
    if hetero is not None:
        m_rows, h_widths = hetero[0], tuple(hetero[1])
    if cfg.family == "hybrid":
        emb0 = x_emb
        nshared = cfg.n_shared_attn_blocks
        # shared attention interleaves the mamba stack at a static cadence;
        # run scan over each mamba segment, python loop over segments.
        x = x_emb
        new_layer_caches = []
        new_attn_caches = []
        seg_bounds = list(range(0, cfg.n_layers, cfg.attn_every))
        for inv_idx, start in enumerate(seg_bounds):
            end = min(start + cfg.attn_every, cfg.n_layers)
            seg = jax.tree_util.tree_map(lambda a: a[start:end],
                                         params["layers"])
            seg_cache = jax.tree_util.tree_map(lambda a: a[start:end],
                                               cache["layers"])

            def seg_layer(x, inp):
                lp, lcache = inp

                def one(lp, x):
                    h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
                    o, new_lcache = M2.mamba2_decode(lp["mamba"], h, lcache,
                                                     cfg)
                    return x + o, new_lcache

                if hetero is None:
                    return one(_resolve(resolve, lp), x)
                return _hetero_sweep(lambda w: one(resolve(lp, w), x),
                                     m_rows, h_widths)

            # shared attention first (cadence: at layer index start)
            sp_raw = jax.tree_util.tree_map(
                lambda a, i=inv_idx % nshared: a[i], params["shared"])
            ac = jax.tree_util.tree_map(lambda a, i=inv_idx: a[i],
                                        cache["attn"])
            if hetero is None:
                x, new_ac = hybrid_shared_block_decode(
                    _resolve(resolve, sp_raw), x, emb0, ac, cfg, pos)
            else:
                x, new_ac = _hetero_sweep(
                    lambda w, x=x: hybrid_shared_block_decode(
                        resolve(sp_raw, w), x, emb0, ac, cfg, pos),
                    m_rows, h_widths)
            new_attn_caches.append(new_ac)
            x, new_seg_cache = lax.scan(seg_layer, x, (seg, seg_cache),
                                        unroll=layer_unroll)
            new_layer_caches.append(new_seg_cache)

        new_cache = {
            "layers": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_layer_caches),
            "attn": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, axis=0), *new_attn_caches),
            "pos": pos + 1,
        }
        h = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return h, new_cache

    if cfg.family == "rwkv":
        def body(x, inp):
            lp, lcache = inp
            if hetero is None:
                return rwkv_layer_decode(_resolve(resolve, lp), x, lcache,
                                         cfg)
            return _hetero_sweep(
                lambda w: rwkv_layer_decode(resolve(lp, w), x, lcache, cfg),
                m_rows, h_widths)
        x, new_layer_caches = lax.scan(body, x_emb,
                                       (params["layers"], cache["layers"]),
                                       unroll=layer_unroll)
    else:
        def body(x, inp):
            lp, lcache = inp
            if hetero is None:
                return attn_layer_decode(_resolve(resolve, lp), x, lcache,
                                         cfg, pos)
            return _hetero_sweep(
                lambda w: attn_layer_decode(resolve(lp, w), x, lcache, cfg,
                                            pos),
                m_rows, h_widths)
        x, new_layer_caches = lax.scan(body, x_emb,
                                       (params["layers"], cache["layers"]),
                                       unroll=layer_unroll)
    h = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return h, {**cache, "layers": new_layer_caches, "pos": pos + 1}


def lm_decode_hidden_paged(params, x_emb, cache, block_table,
                           cfg: ModelConfig, resolve=None,
                           layer_unroll: int = 1, page_size: int = 16,
                           hetero=None):
    """``lm_decode_hidden`` over the paged continuous cache
    (``lm_init_paged_cache``): per-slot positions route each row's KV
    read/write through its block-table row.  rwkv has no attention KV, so
    its dense path is reused with the block table ignored.

    ``hetero=(m_rows, widths)`` serves each row at its own SEFP width (see
    ``lm_decode_hidden``); the attention page pools are merged per written
    (page, offset) cell (``_hetero_sweep_paged``), everything else
    row-wise."""
    if cfg.family == "rwkv":
        return lm_decode_hidden(params, x_emb, cache, cfg, resolve=resolve,
                                layer_unroll=layer_unroll, hetero=hetero)
    pos = cache["pos"]
    if hetero is not None:
        m_rows, h_widths = hetero[0], tuple(hetero[1])
    if cfg.family == "hybrid":
        emb0 = x_emb
        nshared = cfg.n_shared_attn_blocks
        x = x_emb
        new_layer_caches = []
        new_kp, new_vp = [], []
        seg_bounds = list(range(0, cfg.n_layers, cfg.attn_every))
        for inv_idx, start in enumerate(seg_bounds):
            end = min(start + cfg.attn_every, cfg.n_layers)
            seg = jax.tree_util.tree_map(lambda a: a[start:end],
                                         params["layers"])
            seg_cache = jax.tree_util.tree_map(lambda a: a[start:end],
                                               cache["layers"])

            def seg_layer(x, inp):
                lp, lcache = inp

                def one(lp, x):
                    h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
                    o, new_lcache = M2.mamba2_decode(lp["mamba"], h, lcache,
                                                     cfg)
                    return x + o, new_lcache

                if hetero is None:
                    return one(_resolve(resolve, lp), x)
                return _hetero_sweep(lambda w: one(resolve(lp, w), x),
                                     m_rows, h_widths)

            sp_raw = jax.tree_util.tree_map(
                lambda a, i=inv_idx % nshared: a[i], params["shared"])
            kp_in = cache["pages"]["k"][inv_idx]
            vp_in = cache["pages"]["v"][inv_idx]
            if hetero is None:
                x, kp, vp = hybrid_shared_block_decode_paged(
                    _resolve(resolve, sp_raw), x, emb0, kp_in, vp_in,
                    block_table, cfg, pos, page_size)
            else:
                x, kp, vp = _hetero_sweep_paged(
                    lambda w, x=x: hybrid_shared_block_decode_paged(
                        resolve(sp_raw, w), x, emb0, kp_in, vp_in,
                        block_table, cfg, pos, page_size),
                    m_rows, h_widths, kp_in, vp_in, block_table, pos,
                    page_size)
            new_kp.append(kp)
            new_vp.append(vp)
            x, new_seg_cache = lax.scan(seg_layer, x, (seg, seg_cache),
                                        unroll=layer_unroll)
            new_layer_caches.append(new_seg_cache)
        new_cache = {
            "layers": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_layer_caches),
            "pages": {"k": jnp.stack(new_kp, 0), "v": jnp.stack(new_vp, 0)},
            "pos": pos + 1,
        }
        return L.rmsnorm(x, params["ln_f"], cfg.norm_eps), new_cache

    def body(x, inp):
        lp, (kp, vp) = inp
        if hetero is None:
            x, kp, vp = attn_layer_decode_paged(_resolve(resolve, lp), x,
                                                kp, vp, block_table, cfg,
                                                pos, page_size)
        else:
            x, kp, vp = _hetero_sweep_paged(
                lambda w, x=x: attn_layer_decode_paged(
                    resolve(lp, w), x, kp, vp, block_table, cfg, pos,
                    page_size),
                m_rows, h_widths, kp, vp, block_table, pos, page_size)
        return x, (kp, vp)

    x, (new_kp, new_vp) = lax.scan(
        body, x_emb,
        (params["layers"], (cache["pages"]["k"], cache["pages"]["v"])),
        unroll=layer_unroll)
    h = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return h, {**cache, "pages": {"k": new_kp, "v": new_vp},
               "pos": pos + 1}


def lm_verify_hidden_paged(params, x_emb, cache, block_table,
                           cfg: ModelConfig, resolve=None,
                           layer_unroll: int = 1, page_size: int = 16,
                           n_used=None):
    """Speculative VERIFY forward over the paged continuous cache: x_emb
    ``[B, S, d]`` holds, per row, the last committed token followed by up
    to S-1 draft tokens, placed at global positions ``pos[b] + [0, S)``.
    All S positions run in ONE batched pass (attn_layer_verify_paged) and
    their full-width K/V overwrites the draft's low-width cells in place.

    Unlike the decode step this does NOT advance ``cache["pos"]`` — the
    caller decides how far the position moves after comparing draft tokens
    to the verifier's argmax (serve/slots.rollback_paged).  ``n_used``
    int32[B] marks how many leading positions each row actually verifies;
    rows at 0 ride the dispatch without touching live cells.  Attention
    families only — recurrent state (rwkv/hybrid) cannot be rolled back
    position-wise, so those families cannot speculate."""
    if cfg.family in ("rwkv", "hybrid"):
        raise NotImplementedError(
            "speculative verify requires a position-indexed cache; family "
            f"{cfg.family!r} carries recurrent state that cannot be rolled "
            "back to a rejected draft's predecessor")
    pos = cache["pos"]
    if n_used is None:
        n_used = jnp.full(x_emb.shape[:1], x_emb.shape[1], jnp.int32)

    def body(x, inp):
        lp, (kp, vp) = inp
        x, kp, vp = attn_layer_verify_paged(_resolve(resolve, lp), x,
                                            kp, vp, block_table, cfg,
                                            pos, page_size, n_used)
        return x, (kp, vp)

    x, (new_kp, new_vp) = lax.scan(
        body, x_emb,
        (params["layers"], (cache["pages"]["k"], cache["pages"]["v"])),
        unroll=layer_unroll)
    h = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return h, {**cache, "pages": {"k": new_kp, "v": new_vp}}


def lm_prefill_paged_hidden(params, x_emb, pages, block_table, start,
                            cfg: ModelConfig, resolve=None,
                            page_size: int = 16):
    """One CHUNK of a paged prefill for the pure-attention families
    (dense/moe/vlm): x_emb ``[1, C, d]`` at global positions ``start +
    [0, C)``, attending earlier positions through the block-table view
    (reused prefix pages and previously-written chunks alike), then ONE
    scatter commits the chunk's k/v into the pages.  Returns
    (hidden [1, C, d], new_pages).  Recurrent families cannot skip or
    chunk their sequential state and go through the whole-prefill +
    scatter path instead (serve/slots.py)."""
    if cfg.family in ("rwkv", "hybrid"):
        raise NotImplementedError(
            "chunked paged prefill requires a position-indexed cache; "
            f"family {cfg.family!r} carries recurrent state — use "
            "lm_prefill_hidden + install_prefill_pages")
    B, C, _ = x_emb.shape
    positions = start + jnp.arange(C, dtype=jnp.int32)[None, :]

    def body(x, inp):
        lp, (kp, vp) = inp
        x, k, v = attn_layer_prefill_paged(
            _resolve(resolve, lp), x, kp, vp, block_table, start, cfg,
            page_size, positions)
        return x, (k, v)

    x, (k_all, v_all) = lax.scan(
        body, x_emb, (params["layers"], (pages["k"], pages["v"])))
    pos_arr = start + jnp.arange(C, dtype=jnp.int32)
    pg = block_table[pos_arr // page_size]
    off = pos_arr % page_size
    new_pages = {
        "k": pages["k"].at[:, pg, off].set(
            k_all[:, 0].astype(pages["k"].dtype)),
        "v": pages["v"].at[:, pg, off].set(
            v_all[:, 0].astype(pages["v"].dtype)),
    }
    return L.rmsnorm(x, params["ln_f"], cfg.norm_eps), new_pages


# -- prefill (sequence -> cache) ----------------------------------------------

def lm_prefill_hidden(params, x_emb, cfg: ModelConfig, max_len: int,
                      resolve=None):
    """Run the full stack, returning (hidden [B,S,d], decode cache).
    ``resolve``: optional per-layer parameter transform (see _resolve)."""
    B, S, d = x_emb.shape
    dtype = x_emb.dtype
    if cfg.family == "rwkv":
        def body(x, lp):
            def f(lp, x):
                lp = _resolve(resolve, lp)
                h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
                y, st = R6.rwkv6_apply_with_state(lp["tmix"], h, cfg)
                x = x + y
                h2 = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
                h2_prev = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
                x = x + rwkv_channel_mix(lp["cmix"], h2, h2_prev)
                cache = {"tmix": {"wkv_state": st,
                                  "shift_state": h[:, -1:].astype(dtype)},
                         "cmix_shift": h2[:, -1:].astype(dtype)}
                return x, cache
            return _remat(f, cfg)(lp, x)

        x, caches = lax.scan(body, x_emb, params["layers"])
        cache = {"layers": caches, "pos": jnp.asarray(S, jnp.int32)}
        return L.rmsnorm(x, params["ln_f"], cfg.norm_eps), cache

    if cfg.family == "hybrid":
        emb0 = x_emb
        nshared = cfg.n_shared_attn_blocks
        x = x_emb
        attn_caches = []
        seg_caches = []
        seg_bounds = list(range(0, cfg.n_layers, cfg.attn_every))
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]

        def mamba_seg_body(x, lp):
            def f(lp, x):
                lp = _resolve(resolve, lp)
                h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
                y, st = M2.mamba2_apply_with_state(lp["mamba"], h, cfg)
                return x + y, st
            return _remat(f, cfg)(lp, x)

        for inv_idx, start in enumerate(seg_bounds):
            end = min(start + cfg.attn_every, cfg.n_layers)
            sp = _resolve(resolve, jax.tree_util.tree_map(
                lambda a, i=inv_idx % nshared: a[i], params["shared"]))
            dt = x.dtype
            hcat = jnp.concatenate([x, emb0], -1) @ sp["fuse_proj"].astype(dt)
            hh, ac = attn_layer_prefill(sp, hcat, cfg, max_len, positions)
            x = x + hh
            attn_caches.append(ac)
            seg = jax.tree_util.tree_map(lambda a: a[start:end],
                                         params["layers"])
            x, st = lax.scan(mamba_seg_body, x, seg)
            seg_caches.append(st)
        cache = {
            "layers": jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0), *seg_caches),
            "attn": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs, 0), *attn_caches),
            "pos": jnp.asarray(S, jnp.int32),
        }
        return L.rmsnorm(x, params["ln_f"], cfg.norm_eps), cache

    # dense / moe / vlm
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, lp):
        x, c = attn_layer_prefill(_resolve(resolve, lp), x, cfg, max_len,
                                  positions)
        return x, c

    x, layer_caches = lax.scan(body, x_emb, params["layers"])
    cache = {"layers": layer_caches, "pos": jnp.asarray(S, jnp.int32)}
    return L.rmsnorm(x, params["ln_f"], cfg.norm_eps), cache
