"""Atomic, resumable, elastic checkpointing.

Layout:  <dir>/step_<N>/arrays.npz + meta.json + DONE

* atomic: written into a tmp dir, fsync'd, then os.replace'd; a DONE marker
  guards against torn writes (a crash mid-save leaves no valid checkpoint).
* resumable: `meta` carries the data-pipeline cursor and user extras.
* elastic: arrays are saved as FULL (unsharded) numpy arrays and restored
  with jax.device_put against whatever mesh/shardings the new job uses —
  restoring onto a different device count / mesh shape re-shards for free
  (the elastic-scaling path: checkpoint on 512 chips, resume on 256).
* keep-k: old steps are garbage-collected after a successful save.

On a multi-host deployment each host would save only its addressable shards
(jax.experimental.multihost_utils); this container is single-process, so
full-array save/restore is both correct and the simplest elastic format.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(state) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(like_state, arrays: dict):
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        like_state)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array for {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint shape mismatch at {key!r}: "
                f"{arr.shape} vs expected {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomically persist `state` (any pytree) at `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        arrays = _flatten(jax.device_get(state))
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        meta = {"step": step, "extra": extra or {},
                "n_arrays": len(arrays)}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        open(os.path.join(tmp, "DONE"), "w").close()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _cleanup(ckpt_dir, keep)
    return final


def _valid(path: str) -> bool:
    return os.path.exists(os.path.join(path, "DONE"))


def list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and _valid(os.path.join(ckpt_dir, name)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, like_state: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, dict]:
    """Restore onto the current topology.  `like_state` provides the pytree
    structure/shapes (e.g. from jax.eval_shape of the init fn); `shardings`
    (optional pytree of NamedSharding) places each array — pass the NEW
    mesh's shardings to restore elastically onto a different topology."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    if not _valid(path):
        raise FileNotFoundError(f"checkpoint {path} is incomplete")
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    state_np = _unflatten(like_state, arrays)
    if shardings is not None:
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state_np, shardings)
    else:
        state = jax.tree_util.tree_map(jax.numpy.asarray, state_np)
    return state, meta


def _cleanup(ckpt_dir: str, keep: int):
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
    # remove stale tmp dirs from crashed saves
    for name in os.listdir(ckpt_dir):
        if name.startswith(".tmp_"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
