"""Atomic, resumable, elastic checkpointing.

Layout:  <dir>/step_<N>/arrays.npz + meta.json + DONE

* atomic: written into a tmp dir, fsync'd, then os.replace'd; a DONE marker
  guards against torn writes (a crash mid-save leaves no valid checkpoint).
* resumable: `meta` carries the data-pipeline cursor and user extras.
* elastic: arrays are saved as FULL (unsharded) numpy arrays and restored
  with jax.device_put against whatever mesh/shardings the new job uses —
  restoring onto a different device count / mesh shape re-shards for free
  (the elastic-scaling path: checkpoint on 512 chips, resume on 256).
* keep-k: old steps are garbage-collected after a successful save.

On a multi-host deployment each host would save only its addressable shards
(jax.experimental.multihost_utils); this container is single-process, so
full-array save/restore is both correct and the simplest elastic format.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

# ---------------------------------------------------------------------------
# Path <-> key encoding (single owner; repro/artifact.py reuses it).
#
# A tree path becomes the "/"-join of one token per path component.  A naive
# str() join is ambiguous: a dict key containing "/" collides with genuine
# nesting ({"a/b": x} vs {"a": {"b": y}}), and an int-like string dict key
# ("0") collides with a positional (list / registered-pytree) child at the
# same spot.  So: "/" and "\" inside string components are backslash-escaped,
# and positional components are rendered "#<idx>" with a leading literal "#"
# in a string component escaped to "\#".
# ---------------------------------------------------------------------------


def _escape(s: str) -> str:
    s = s.replace("\\", "\\\\").replace("/", "\\/")
    return "\\" + s if s.startswith("#") else s


def _component(k) -> str:
    if isinstance(k, jax.tree_util.SequenceKey):
        return f"#{k.idx}"
    if isinstance(k, jax.tree_util.DictKey):
        return _escape(str(k.key))
    if isinstance(k, jax.tree_util.GetAttrKey):
        return _escape(str(k.name))
    # FlattenedIndexKey (registered pytree nodes without keypaths) and any
    # future key type carrying an int position
    inner = getattr(k, "key", getattr(k, "idx", k))
    if isinstance(inner, int):
        return f"#{inner}"
    return _escape(str(inner))


def path_key(path) -> str:
    """Unambiguous flat key for a jax.tree_util key path."""
    return "/".join(_component(k) for k in path)


def split_key(key: str, unescape: bool = True) -> list:
    """Inverse of ``path_key`` up to component *strings*: split on unescaped
    "/".  With ``unescape=True`` each component is unescaped ("#<idx>"
    tokens come back verbatim).  With ``unescape=False`` the raw escaped
    tokens are returned, so a caller can still distinguish a positional
    "#<idx>" token from an escaped dict key "\\#..." before unescaping
    (repro/artifact.py's tree rebuild needs exactly that)."""
    parts, cur, i = [], [], 0
    while i < len(key):
        c = key[i]
        if c == "\\" and i + 1 < len(key):
            if unescape:
                cur.append(key[i + 1])
            else:
                cur.append(c)
                cur.append(key[i + 1])
            i += 2
        elif c == "/":
            parts.append("".join(cur))
            cur = []
            i += 1
        else:
            cur.append(c)
            i += 1
    parts.append("".join(cur))
    return parts


def unescape_component(token: str) -> str:
    """Undo ``_escape`` on a single raw token from split_key(...,
    unescape=False)."""
    out, i = [], 0
    while i < len(token):
        if token[i] == "\\" and i + 1 < len(token):
            out.append(token[i + 1])
            i += 2
        else:
            out.append(token[i])
            i += 1
    return "".join(out)


def flatten_arrays(state) -> dict:
    """Pytree -> {path_key: np.ndarray} (host arrays)."""
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = {}
    for path, leaf in flat:
        key = path_key(path)
        if key in out:
            raise ValueError(f"duplicate flattened key {key!r}")
        out[key] = np.asarray(leaf)
    return out


def unflatten_arrays(like_state, arrays: dict):
    """{path_key: array} -> pytree shaped like ``like_state``."""
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        like_state)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = path_key(path)
        if key not in arrays:
            legacy = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path)
            if legacy in arrays:
                raise KeyError(
                    f"checkpoint missing array for {key!r}, but found the "
                    f"pre-escaping key {legacy!r}: this checkpoint was "
                    f"written before the path-key encoding change and "
                    f"cannot be restored by this version — re-export it "
                    f"with the version that wrote it")
            raise KeyError(f"checkpoint missing array for {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint shape mismatch at {key!r}: "
                f"{arr.shape} vs expected {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def replace_dir(tmp: str, final: str):
    """Install ``tmp`` at ``final`` via rename-aside: any existing dir at
    ``final`` stays valid until the single rename that installs the new
    one, is restored if that rename fails, and is discarded after it
    succeeds.  Shared by checkpoint and artifact persistence (the one
    owner of the overwrite discipline)."""
    trash = None
    if os.path.exists(final):
        trash = f"{final}.old-{os.getpid()}"
        if os.path.exists(trash):
            shutil.rmtree(trash)
        os.replace(final, trash)
    try:
        os.replace(tmp, final)
    except Exception:
        if trash is not None:
            os.replace(trash, final)
        raise
    if trash is not None:
        shutil.rmtree(trash, ignore_errors=True)


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomically persist `state` (any pytree) at `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        arrays = flatten_arrays(jax.device_get(state))
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        meta = {"step": step, "extra": extra or {},
                "n_arrays": len(arrays)}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        open(os.path.join(tmp, "DONE"), "w").close()
        replace_dir(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _cleanup(ckpt_dir, keep)
    return final


def _valid(path: str) -> bool:
    return os.path.exists(os.path.join(path, "DONE"))


def list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and _valid(os.path.join(ckpt_dir, name)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, like_state: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, dict]:
    """Restore onto the current topology.  `like_state` provides the pytree
    structure/shapes (e.g. from jax.eval_shape of the init fn); `shardings`
    (optional pytree of NamedSharding) places each array — pass the NEW
    mesh's shardings to restore elastically onto a different topology."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    if not _valid(path):
        raise FileNotFoundError(f"checkpoint {path} is incomplete")
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    state_np = unflatten_arrays(like_state, arrays)
    if shardings is not None:
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state_np, shardings)
    else:
        state = jax.tree_util.tree_map(jax.numpy.asarray, state_np)
    return state, meta


def _cleanup(ckpt_dir: str, keep: int):
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
    # remove stale tmp/rename-aside dirs from crashed saves
    for name in os.listdir(ckpt_dir):
        if name.startswith(".tmp_") or ".old-" in name:
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
