"""Optimizers, built from scratch (no optax offline).

Interface (optax-like GradientTransformation):

    opt = sgd(1e-5)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

The paper fine-tunes with plain SGD at lr=1e-5; SGD is therefore the default
and — being stateless — composes with LAA's delayed updates for free.  Adam
and momentum are provided for the wider framework; their states are masked on
LAA-skipped batches in train/steps.py so skipped batches leave them untouched.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    if callable(lr):
        return jnp.asarray(lr(step), jnp.float32)
    return jnp.asarray(lr, jnp.float32)


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params)


class SGDState(NamedTuple):
    step: jax.Array


class MomentumState(NamedTuple):
    step: jax.Array
    mu: Any


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def sgd(lr: Schedule = 1e-5) -> Optimizer:
    def init(params):
        del params
        return SGDState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        eta = _lr_at(lr, state.step)
        updates = jax.tree_util.tree_map(lambda g: -eta * g.astype(jnp.float32),
                                         grads)
        return updates, SGDState(step=state.step + 1)

    return Optimizer(init, update)


def momentum(lr: Schedule = 1e-5, beta: float = 0.9,
             nesterov: bool = False) -> Optimizer:
    def init(params):
        mu = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return MomentumState(step=jnp.zeros((), jnp.int32), mu=mu)

    def update(grads, state, params=None):
        del params
        eta = _lr_at(lr, state.step)
        mu = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state.mu, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: -eta * (beta * m + g.astype(jnp.float32)),
                mu, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -eta * m, mu)
        return upd, MomentumState(step=state.step + 1, mu=mu)

    return Optimizer(init, update)


def adam(lr: Schedule = 1e-5, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree_util.tree_map(zeros, params),
                         nu=jax.tree_util.tree_map(zeros, params))

    def update(grads, state, params=None):
        step = state.step + 1
        eta = _lr_at(lr, state.step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def u(m, v, p):
            upd = -eta * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd - eta * weight_decay * p.astype(jnp.float32)
            return upd

        if params is not None and weight_decay:
            updates = jax.tree_util.tree_map(u, mu, nu, params)
        else:
            updates = jax.tree_util.tree_map(lambda m, v: u(m, v, None), mu, nu)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def masked_apply(params, opt_state, new_params, new_opt_state, do_update):
    """Select between (new_params, new_opt_state) and the originals, per
    LAA's do_update flag, without re-tracing."""
    sel = lambda old, new: jax.tree_util.tree_map(
        lambda o, n: jnp.where(do_update, n, o), old, new)
    return sel(params, new_params), sel(opt_state, new_opt_state)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


# -- learning-rate schedules -------------------------------------------------

def cosine_schedule(peak_lr: float, total_steps: int,
                    warmup_steps: int = 0, floor: float = 0.0) -> Schedule:
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)

    return f
