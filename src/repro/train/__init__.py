from repro.train.optimizer import (  # noqa: F401
    adam,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    momentum,
    sgd,
)
