"""Deterministic synthetic corpus with learnable structure.

Real LLaMA/Qwen checkpoints and Alpaca/WikiText2 are unavailable offline, so
the paper's *learning-dynamics* claims are validated on a synthetic language
with genuine structure (DESIGN.md §9):

  * Zipfian unigram marginals,
  * a sparse first-order Markov transition (each token has K preferred
    successors with Zipf-weighted probabilities),
  * copy motifs: segments repeat earlier n-grams with probability p_copy
    (gives in-context structure that rewards a real sequence model).

Everything is a pure function of (seed, step), so the data pipeline is
trivially resumable and identical across hosts — each host slices its own
batch shard (`host_batch_slice`).  A "task" corpus is the same family with a
different seed/transition — fine-tuning moves a pretrained model onto it,
mirroring the paper's pretrain -> fine-tune protocol.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    branching: int = 20       # successors per token
    zipf_a: float = 1.2       # successor weight decay
    p_copy: float = 0.10      # chance to start copying an earlier span
    copy_len: int = 16

    K_MAX = 32  # successor table width; `branching` selects a prefix, so
    #             corpora with the same seed but different branching share
    #             structure (fine-tuning = distribution shift, not a new
    #             language — mirrors the paper's pretrain->task protocol)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        K = self.branching
        assert K <= self.K_MAX
        self.successors = rng.integers(0, V, size=(V, self.K_MAX))[:, :K]
        w = 1.0 / np.arange(1, K + 1) ** self.zipf_a
        self.succ_p = w / w.sum()
        # Zipfian start distribution
        sw = 1.0 / np.arange(1, V + 1) ** 1.1
        self.start_p = sw / sw.sum()
        self.start_ids = rng.permutation(V)

    def _sample_stream(self, rng: np.random.Generator, length: int):
        out = np.empty(length + 1, np.int64)
        out[0] = self.start_ids[rng.choice(self.vocab_size, p=self.start_p)]
        t = 1
        while t <= length:
            if t > self.copy_len * 2 and rng.random() < self.p_copy:
                src = rng.integers(0, t - self.copy_len)
                n = min(self.copy_len, length + 1 - t)
                out[t:t + n] = out[src:src + n]
                t += n
                continue
            nxt = self.successors[out[t - 1],
                                  rng.choice(self.branching, p=self.succ_p)]
            out[t] = nxt
            t += 1
        return out

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        """Batch for a given global step — pure function of (seed, step)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        toks = np.stack([self._sample_stream(rng, seq_len)
                         for _ in range(batch_size)])
        return {"inputs": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def eval_batches(self, n: int, batch_size: int, seq_len: int):
        """Held-out batches (disjoint step space from training)."""
        return [self.batch(10_000_000 + i, batch_size, seq_len)
                for i in range(n)]


def host_batch_slice(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Each host materializes only its slice of the global batch (the
    multi-host data path; on this single-process container n_hosts=1)."""
    def sl(x):
        b = x.shape[0]
        per = b // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


@dataclasses.dataclass
class DataCursor:
    """Resumable pipeline position — checkpointed with the model state."""
    step: int = 0

    def advance(self) -> "DataCursor":
        return DataCursor(self.step + 1)
