"""SEFP-compressed cross-pod gradient reduction (beyond-paper extension).

The paper's own format applied to the slowest links: inter-pod (DCN/ICI)
all-reduce moves bf16 gradients (16 bits/param).  Here each pod packs its
pod-local gradient into SEFP (sign + m-bit mantissa + shared exponent per
64-group ≈ m+1.125 bits), all-gathers the packed representation across the
``pod`` axis, and dequant-sums locally:

    bytes_on_pod_links(m=8) = 9.125/16  ≈ 0.57x of bf16
    bytes_on_pod_links(m=4) = 5.125/16  ≈ 0.32x

Quantization error only affects the *cross-pod* term; within-pod reduction
stays full precision.  Wire-in point: ``train.steps.make_train_step(...,
compress_pods_m=8)`` wraps the whole OTARo step in shard_map over the pod
axis and applies ``compressed_allreduce`` to the pod-local gradients.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import sefp
from repro.kernels import compat

GROUP = 64


def _quant_flat(g: jax.Array, m: int):
    """flatten + pad + SEFP-quantize; returns (codes, exps int8, n)."""
    n = g.size
    pad = (-n) % GROUP
    flat = jnp.pad(g.reshape(-1).astype(jnp.float32), (0, pad))
    grp = flat.reshape(-1, GROUP)
    e = sefp.floor_log2(grp).max(axis=-1, keepdims=True)
    e = jnp.clip(e, sefp.EXP_MIN, sefp.EXP_MAX)
    quantum = sefp.exp2i(e - (m - 1))
    maxmag = float(2 ** m - 1)
    codes = jnp.clip(jnp.round(grp / quantum), -maxmag, maxmag)
    return codes.astype(jnp.int8 if m <= 7 else jnp.int16), \
        e.astype(jnp.int8), n


def _dequant_flat(codes, exps, m: int, n: int, shape, dtype):
    quantum = sefp.exp2i(exps.astype(jnp.int32) - (m - 1))
    flat = (codes.astype(jnp.float32) * quantum).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def compressed_allreduce(grads: Any, axis_name: str, n_shards: int,
                         m: int = 8, mean: bool = True) -> Any:
    """For use INSIDE shard_map over ``axis_name``: SEFP-quantize the local
    gradient, all-gather the packed codes, dequant-sum locally."""

    def one(g):
        shape, dtype = g.shape, g.dtype
        codes, exps, n = _quant_flat(g, m)
        all_codes = lax.all_gather(codes, axis_name)   # packed bits on wire
        all_exps = lax.all_gather(exps, axis_name)
        total = jnp.zeros(g.shape, jnp.float32)
        for p in range(n_shards):
            total = total + _dequant_flat(all_codes[p], all_exps[p], m, n,
                                          shape, jnp.float32)
        if mean:
            total = total / n_shards
        return total.astype(dtype)

    return jax.tree_util.tree_map(one, grads)


def compressed_psum_pods(grads: Any, mesh: Mesh, m: int = 8,
                         mean: bool = False) -> Any:
    """Standalone wrapper (must run under jit): cross-pod reduce a pytree of
    replicated-over-pod... pod-local gradients with compressed traffic."""
    if "pod" not in mesh.axis_names:
        return grads
    n_pods = mesh.shape["pod"]
    if n_pods == 1:
        return grads

    def body(g):
        return compressed_allreduce(g, "pod", n_pods, m=m, mean=mean)

    return compat.shard_map(body, mesh, in_specs=P(), out_specs=P(),
                            manual_axes=("pod",), check=False)(grads)


def compression_ratio(m: int) -> float:
    """bits on the wire per parameter vs bf16."""
    return ((m + 1) + 8.0 / GROUP) / 16.0
