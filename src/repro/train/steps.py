"""Distributed OTARo train-step construction.

Wires together: model loss (model_zoo) -> gradient-accumulation microbatching
-> OTARo policy (BPS + STE quantized loss + LAA + optimizer) -> sharding
(param/batch/state pspecs) -> jit with donation.  Optionally wraps the whole
step in shard_map over the ``pod`` axis with SEFP-compressed cross-pod
gradient reduction (train/compression.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import otaro as otaro_lib
from repro.kernels import compat
from repro.models import model_zoo as Z
from repro.models.config import ModelConfig
from repro.sharding import partition as SH
from repro.sharding.constraints import batch_layout as batch_layout_ctx
from repro.train import compression as CM
from repro.train import optimizer as opt_lib


def microbatched(loss_fn, accum: int):
    """Mean loss over `accum` microbatches via scan — bounds live
    activations to one microbatch (plus remat'd recompute in backward)."""
    if accum <= 1:
        return loss_fn

    def f(params, batch):
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
            batch)

        def body(tot, b):
            return tot + loss_fn(params, b), None

        tot, _ = lax.scan(body, jnp.float32(0), mb)
        return tot / accum

    return f


def make_train_step(
    model_cfg: ModelConfig,
    ocfg: otaro_lib.OTAROConfig,
    optimizer: opt_lib.Optimizer,
    mesh: Optional[Mesh] = None,
    grad_accum: int = 1,
    compress_pods_m: Optional[int] = None,
    donate: bool = True,
    batch_layout: str = "tp",
):
    """Returns (jitted_step, init_fn).

    jitted_step(state, batch) -> (state, metrics)
    init_fn(rng) -> sharded OTAROState
    """
    loss_fn = microbatched(Z.make_loss_fn(model_cfg), grad_accum)

    use_compression = (compress_pods_m is not None and mesh is not None
                       and "pod" in mesh.axis_names
                       and mesh.shape["pod"] > 1)
    if use_compression:
        n_pods = mesh.shape["pod"]
        step_core = otaro_lib.make_otaro_step(
            loss_fn, optimizer, ocfg,
            grad_transform=lambda g: CM.compressed_allreduce(
                g, "pod", n_pods, m=compress_pods_m, mean=True),
            loss_transform=lambda l: lax.pmean(l, "pod"))
    else:
        step_core = otaro_lib.make_otaro_step(loss_fn, optimizer, ocfg)

    def init_fn_host(rng):
        params = Z.init_params(model_cfg, rng)
        return otaro_lib.init_state(params, optimizer, ocfg)

    if mesh is None:
        return jax.jit(step_core, donate_argnums=(0,) if donate else ()), \
            jax.jit(init_fn_host)

    # --- sharded path -----------------------------------------------------
    state_shapes = jax.eval_shape(init_fn_host, jax.random.PRNGKey(0))
    state_specs = SH.state_pspecs(state_shapes, mesh)
    state_shardings = SH.to_named_sharding(state_specs, mesh)

    if use_compression:
        # the step runs pod-manual: every pod sees the full (replicated)
        # state and its own batch shard; data/model stay GSPMD-auto inside.
        def stepper(state, batch):
            with batch_layout_ctx(batch_layout):
                return compat.shard_map(
                    step_core, mesh, in_specs=(P(), P("pod")),
                    out_specs=P(), manual_axes=("pod",), check=False)(
                    state, batch)
    else:
        def stepper(state, batch):
            # trace-time context: in-model sharding constraints must agree
            # with the batch layout (tp vs dp)
            with batch_layout_ctx(batch_layout):
                return step_core(state, batch)

    def make_batch_shardings(batch_shapes):
        # compressed path: dim0 carries only the (manual) pod axis at the
        # jit boundary; data-sharding happens inside the shard_map body via
        # constraints (manual + auto axes cannot share a dim spec)
        input_layout = "pod" if use_compression else batch_layout
        return SH.to_named_sharding(
            SH.batch_pspecs(batch_shapes, mesh, layout=input_layout), mesh)

    def jit_step(batch_shapes):
        return jax.jit(
            stepper,
            in_shardings=(state_shardings, make_batch_shardings(batch_shapes)),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,) if donate else (),
        )

    init_jit = jax.jit(init_fn_host, out_shardings=state_shardings)
    return jit_step, init_jit


def make_eval_step(model_cfg: ModelConfig, ocfg: otaro_lib.OTAROConfig):
    """eval_step(params, batch, m) -> loss at SEFP precision m."""
    loss_fn = Z.make_loss_fn(model_cfg)
    return jax.jit(otaro_lib.make_eval_fn(loss_fn, ocfg))


def train_step_artifacts(
    model_cfg: ModelConfig,
    ocfg: otaro_lib.OTAROConfig,
    optimizer: opt_lib.Optimizer,
    mesh: Mesh,
    batch_shapes,
    grad_accum: int = 1,
    compress_pods_m: Optional[int] = None,
    batch_layout: str = "tp",
    master_dtype=None,
):
    """Everything the dry-run needs: (jitted step, state ShapeDtypeStructs,
    state shardings).  Nothing is allocated.  master_dtype=jnp.bfloat16
    traces the step with bf16 master weights + LAA buffers (the
    memory-capacity variant for very large models)."""
    jit_builder, _ = make_train_step(
        model_cfg, ocfg, optimizer, mesh=mesh, grad_accum=grad_accum,
        compress_pods_m=compress_pods_m, donate=True,
        batch_layout=batch_layout)

    def init_fn_host(rng):
        params = Z.init_params(model_cfg, rng)
        return otaro_lib.init_state(params, optimizer, ocfg)

    state_shapes = jax.eval_shape(init_fn_host, jax.random.PRNGKey(0))
    if master_dtype is not None:
        state_shapes = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, master_dtype)
            if x.dtype == jnp.float32 and len(x.shape) >= 2 else x,
            state_shapes)
    state_specs = SH.state_pspecs(state_shapes, mesh)
    return jit_builder(batch_shapes), state_shapes, \
        SH.to_named_sharding(state_specs, mesh)
