"""Fault-tolerant training runner.

Responsibilities beyond the bare step loop:
  * auto-resume: on start, restore the newest valid checkpoint (params,
    optimizer, BPS, LAA *and* the data cursor) — a preempted/failed job
    relaunches with the same command and continues;
  * periodic + final checkpoints (atomic, keep-k);
  * per-step watchdog: a step that throws (device OOM, numerical panic,
    simulated fault in tests) triggers an emergency checkpoint of the last
    good state, then re-raises for the scheduler to restart the job;
  * metrics: JSONL log (loss, selected bit-width, LAA releases, steps/s).

Straggler/elastic posture at real scale (documented in DESIGN.md §6): SPMD
steps are synchronous, so per-step stragglers are handled below the JAX
level (ICI flow control); *persistent* stragglers and node failures are
handled by this runner's restart path, and elastic resizing works because
checkpoints are topology-free (train/checkpoint.py) — restore with the new
mesh's shardings and keep going.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.train import checkpoint as CKPT


@dataclasses.dataclass
class JobConfig:
    total_steps: int
    out_dir: str
    ckpt_every: int = 200
    keep: int = 3
    log_every: int = 10
    resume: bool = True
    # test hook: raise RuntimeError after this many steps (once)
    simulate_failure_at: Optional[int] = None


class MetricsLogger:
    def __init__(self, path: str):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        self.path = path
        self.history = []

    def log(self, record: dict):
        self.history.append(record)
        with open(self.path, "a") as f:
            f.write(json.dumps(record) + "\n")


def run_training(
    step_fn: Callable,
    init_state_fn: Callable[[], Any],
    batch_fn: Callable[[int], Any],
    job: JobConfig,
    state_shapes: Any = None,
    shardings: Any = None,
    hooks: Optional[dict] = None,
) -> Any:
    """Drive training to job.total_steps with checkpoint/restart semantics.

    step_fn(state, batch) -> (state, metrics);  batch_fn(step) -> batch
    (a pure function of the step index — the resumable data pipeline).

    hooks: "on_log"(record, state) at every log interval;
    "on_complete"(state) exactly once, after the final step and final
    checkpoint — the deployment-export point (repro.api.finetune passes
    repro.artifact's export here so every finished run leaves a servable
    artifact next to its checkpoints).
    """
    ckpt_dir = os.path.join(job.out_dir, "checkpoints")
    logger = MetricsLogger(os.path.join(job.out_dir, "metrics.jsonl"))
    failed_once = {"done": False}

    start_step = 0
    state = None
    if job.resume and CKPT.latest_step(ckpt_dir) is not None:
        like = state_shapes if state_shapes is not None else jax.eval_shape(
            init_state_fn)
        state, meta = CKPT.restore_checkpoint(ckpt_dir, like,
                                              shardings=shardings)
        start_step = int(meta["extra"]["data_step"])
        logger.log({"event": "resumed", "step": start_step})
    if state is None:
        state = init_state_fn()

    last_good = state
    last_good_step = start_step
    t0 = time.time()
    step = start_step
    try:
        while step < job.total_steps:
            if (job.simulate_failure_at is not None
                    and not failed_once["done"]
                    and step == job.simulate_failure_at):
                failed_once["done"] = True
                raise RuntimeError(f"simulated node failure at step {step}")

            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            step += 1

            if step % job.log_every == 0 or step == job.total_steps:
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "m": int(metrics["mantissa_width"]),
                       "did_update": int(metrics["did_update"]),
                       "steps_per_s": job.log_every / max(
                           time.time() - t0, 1e-9)}
                t0 = time.time()
                logger.log(rec)
                if hooks and "on_log" in hooks:
                    hooks["on_log"](rec, state)

            if step % job.ckpt_every == 0 or step == job.total_steps:
                CKPT.save_checkpoint(ckpt_dir, step, state,
                                     extra={"data_step": step},
                                     keep=job.keep)
                last_good = state
                last_good_step = step
    except Exception as e:
        # watchdog: persist the last good state for the restart, then
        # surface the failure to the scheduler.
        logger.log({"event": "failure", "step": step, "error": repr(e)})
        try:
            CKPT.save_checkpoint(ckpt_dir, last_good_step, last_good,
                                 extra={"data_step": last_good_step},
                                 keep=job.keep)
        except Exception:
            pass
        raise

    if hooks and "on_complete" in hooks:
        hooks["on_complete"](state)
    return state, logger.history
