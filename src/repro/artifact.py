"""repro.artifact: the packed-SEFP deployment artifact — ONE file set, ALL
precisions.

The paper's deliverable is a single fine-tuned model that serves every
bit-width.  This module makes that deliverable a concrete on-disk format:
the stacked ``{mag, sign, exp}`` E5M8 master (repro/core/packed.py, the
exact representation the serving engine keeps device-resident) plus a
``meta.json`` carrying everything needed to serve it without the source
fp32 checkpoint.

Layout (atomic, DONE-guarded, same discipline as train/checkpoint.py):

    <dir>/master.npz   flattened packed tree; keys are the escaped path
                       encoding from train/checkpoint.py (path_key); bf16
                       leaves are stored as uint16 bit-views (npz cannot
                       represent bfloat16), recorded in meta under
                       arrays.dtypes and restored bit-exactly on load.
    <dir>/meta.json    format/version, the full ModelConfig, pack constants
                       (master width, group size, min_size), the
                       PrecisionPolicy the model was tuned under, final BPS
                       visit/loss statistics, and provenance.
    <dir>/DONE         marker; a crash mid-export leaves no valid artifact.

Lifecycle:

    train:  ``export_artifact(path, cfg, state, policy=...)`` — the ONE
            fp32 -> pack pass, paid once at the end of training
            (repro/train/runner.py's on_complete hook via repro.api).
    serve:  ``Artifact.load(path).server(policy)`` — the packed arrays go
            device-resident as-is; startup performs no O(params) quantize/
            pack pass (the startup analogue of the engine's O(1) precision
            switch; benchmarks/bench_decode.py measures both constructions).

Because the master tree is dicts all the way down (pack_master_params maps
a nested-dict param tree to nested dicts with ``{mag, sign, exp}`` leaves),
``load`` rebuilds the tree purely from the npz key paths — no model init,
no eval_shape, no dependency on having the architecture code warm.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Optional, Sequence

import jax
import numpy as np

from repro.core import packed as packed_lib
from repro.core import sefp
from repro.models.config import ModelConfig
from repro.policy import PrecisionPolicy
from repro.train import checkpoint as CKPT

ARTIFACT_FORMAT = "repro.artifact"
ARTIFACT_VERSION = 1
_ARRAYS = "master.npz"
_META = "meta.json"
_DONE = "DONE"


def _is_valid(path: str) -> bool:
    return os.path.exists(os.path.join(path, _DONE))


class MissingBPSStats(KeyError):
    """A consumer required the artifact's BPS visit/loss statistics but
    meta.json has none (``bps: null`` — e.g. the artifact was packed from
    bare params rather than an OTARo training state).  A KeyError subclass
    so pre-existing ``except KeyError`` call sites keep working, but named
    so the failure says WHAT is missing and what degrades without it (the
    speculative acceptance estimator falls back to the static draft
    width)."""

    def __init__(self, path_or_hint: Optional[str] = None):
        hint = f" at {path_or_hint!r}" if path_or_hint else ""
        super().__init__(
            f"artifact{hint} carries no BPS visit/loss statistics in "
            f"meta.json (bps is null — it was packed without an OTARo "
            f"training state); stats-driven consumers (e.g. the 'bps' "
            f"speculative acceptance estimator, DESIGN.md §15) degrade "
            f"to static behaviour without them")

    def __str__(self) -> str:  # KeyError quotes its repr; keep it readable
        return self.args[0]


@dataclasses.dataclass
class Artifact:
    """A loaded (or freshly packed) deployment artifact: the stacked-SEFP
    master tree + its metadata.  Construct via ``from_state`` /
    ``from_params`` / ``from_checkpoint`` (train side, pays the one pack
    pass) or ``load`` (serve side, pack-free)."""

    cfg: ModelConfig
    master: Any
    meta: Dict[str, Any]

    # -- construction (train side) -----------------------------------------
    @classmethod
    def from_params(cls, cfg: ModelConfig, params,
                    policy: Optional[PrecisionPolicy] = None,
                    min_size: int = 4096, bps: Any = None,
                    provenance: Optional[dict] = None) -> "Artifact":
        """Pack fp32/bf16 params into the serving master.  This is the one
        place the fp32 -> SEFP quantize/pack pass happens in the unified
        lifecycle."""
        from repro.serve import packed_step as PS
        policy = policy or PrecisionPolicy.all_widths()
        master = PS.pack_master_params(params, min_size=min_size)
        nb = packed_lib.tree_nbytes(master)
        meta = {
            "format": ARTIFACT_FORMAT,
            "version": ARTIFACT_VERSION,
            "model": dataclasses.asdict(cfg),
            "pack": {
                "master_m": packed_lib.MASTER_M,
                "sign_bits": packed_lib.SIGN_BITS,
                "exp_bits": packed_lib.EXP_BITS,
                "group_size": sefp.GROUP_SIZE,
                "min_size": int(min_size),
                "bits_per_param": packed_lib.stream_bits_per_param(
                    packed_lib.MASTER_M),
                "packed_params": nb["packed_params"],
                "n_params": nb["n_params"],
                "total_bytes": nb["total_bytes"],
            },
            "policy": policy.describe(),
            "bps": _bps_meta(bps),
            "provenance": dict(provenance or {},
                               created_unix=time.time(),
                               jax_version=jax.__version__),
        }
        return cls(cfg=cfg, master=master, meta=meta)

    @classmethod
    def from_state(cls, cfg: ModelConfig, state,
                   policy: Optional[PrecisionPolicy] = None,
                   min_size: int = 4096,
                   provenance: Optional[dict] = None) -> "Artifact":
        """From a training state (OTAROState or anything with ``.params``):
        packs the params and records the final BPS visit/loss statistics."""
        params = getattr(state, "params", state)
        prov = dict(provenance or {})
        if hasattr(state, "step"):
            prov.setdefault("train_step", int(state.step))
        return cls.from_params(cfg, params, policy=policy, min_size=min_size,
                               bps=getattr(state, "bps", None),
                               provenance=prov)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, cfg: ModelConfig,
                        step: Optional[int] = None,
                        policy: Optional[PrecisionPolicy] = None,
                        min_size: int = 4096) -> "Artifact":
        """Import a train/checkpoint.py checkpoint and pack it.  Fails with
        a clear error — listing what IS there — when the directory has no
        DONE-marked step, instead of leaving callers to fall through to
        random init."""
        from repro.core import otaro as otaro_lib
        from repro.models import model_zoo as Z
        from repro.train import optimizer as opt_lib

        steps = CKPT.list_steps(ckpt_dir)
        if not steps:
            if not os.path.isdir(ckpt_dir):
                raise FileNotFoundError(
                    f"checkpoint directory {ckpt_dir!r} does not exist")
            raise FileNotFoundError(
                f"no DONE-marked checkpoint step under {ckpt_dir!r} "
                f"(directory contains: {sorted(os.listdir(ckpt_dir))!r}); "
                f"valid checkpoints are written by repro.api.finetune / "
                f"repro.launch.train")
        if step is not None and step not in steps:
            raise FileNotFoundError(
                f"checkpoint step {step} not found under {ckpt_dir!r}; "
                f"available steps: {steps}")

        # the OTARo state layout varies with training hyperparameters in two
        # ways: the BPS arrays are sized by the trained width COUNT, and the
        # LAA buffer is param-shaped for mode "otaro" but scalar otherwise.
        # Read the arm count straight from the stored arrays; the width
        # VALUES are not recoverable from a checkpoint, so a policy whose
        # arm count disagrees must come from the caller — recording a
        # guessed width set would falsify the artifact's provenance.
        explicit_policy = policy is not None
        policy = policy or PrecisionPolicy.all_widths()
        resolved = step if step is not None else steps[-1]
        with np.load(os.path.join(ckpt_dir, f"step_{resolved:010d}",
                                  "arrays.npz")) as z:
            n_arms = (int(z["bps/t_b"].shape[0]) if "bps/t_b" in z.files
                      else len(policy.widths))
        if len(policy.widths) != n_arms:
            whose = ("policy" if explicit_policy
                     else "default all-widths policy")
            raise ValueError(
                f"checkpoint under {ckpt_dir!r} was trained over {n_arms} "
                f"bit-width arm(s), but the {whose} has "
                f"{len(policy.widths)} ({policy.widths}); pass the policy "
                f"the run was trained with (e.g. "
                f"PrecisionPolicy.fixed(m) for a fixed-width run) so the "
                f"artifact records truthful trained widths")
        widths = policy.widths
        last_err = None
        for mode in dict.fromkeys((policy.mode, "otaro", "fixed")):
            ocfg = otaro_lib.OTAROConfig(widths=widths, mode=mode)
            like = jax.eval_shape(lambda oc=ocfg: otaro_lib.init_state(
                Z.init_params(cfg, jax.random.PRNGKey(0)),
                opt_lib.sgd(1e-5), oc))
            try:
                state, meta = CKPT.restore_checkpoint(ckpt_dir, like,
                                                      step=step)
                break
            except (KeyError, ValueError) as e:
                last_err = e
        else:
            raise ValueError(
                f"checkpoint under {ckpt_dir!r} does not match the "
                f"{cfg.name!r} OTARo state layout: {last_err}") \
                from last_err
        return cls.from_state(
            cfg, state, policy=policy, min_size=min_size,
            provenance={"source": f"checkpoint:{ckpt_dir}",
                        "train_step": int(meta["step"])})

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> str:
        """Atomically write the artifact directory (tmpdir + fsync +
        os.replace + DONE, mirroring train/checkpoint.py)."""
        parent = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(parent, exist_ok=True)
        tmp = tempfile.mkdtemp(dir=parent, prefix=".tmp_artifact_")
        try:
            arrays = CKPT.flatten_arrays(jax.device_get(self.master))
            dtypes = {}
            stored = {}
            for k, a in arrays.items():
                if a.dtype.name not in _NPZ_SAFE:
                    dtypes[k] = a.dtype.name
                    a = a.view(_BITS_VIEW[a.dtype.itemsize])
                stored[k] = a
            np.savez(os.path.join(tmp, _ARRAYS), **stored)
            meta = dict(self.meta)
            meta["arrays"] = {"n": len(stored), "dtypes": dtypes}
            with open(os.path.join(tmp, _META), "w") as f:
                json.dump(meta, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            open(os.path.join(tmp, _DONE), "w").close()
            # rename-aside overwrite (checkpoint.replace_dir): the previous
            # DONE-marked artifact stays valid until the single rename that
            # installs the new one, and is restored if that rename fails.
            CKPT.replace_dir(tmp, path)
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        # sweep rename-aside leftovers from crashed earlier overwrites
        # (other pids; replace_dir already removed this pid's)
        for stale in glob.glob(f"{path}.old-*"):
            shutil.rmtree(stale, ignore_errors=True)
        return path

    @classmethod
    def load(cls, path: str) -> "Artifact":
        """Load a packed artifact.  No model init, no fp32 pass: the tree is
        rebuilt from the npz key paths and the packed bytes go straight to
        the device."""
        import jax.numpy as jnp
        import ml_dtypes

        if not os.path.isdir(path):
            raise FileNotFoundError(f"no artifact directory at {path!r}")
        if not _is_valid(path):
            raise FileNotFoundError(
                f"artifact at {path!r} is incomplete (no DONE marker); "
                f"directory contains: {sorted(os.listdir(path))!r}")
        with open(os.path.join(path, _META)) as f:
            meta = json.load(f)
        if meta.get("format") != ARTIFACT_FORMAT:
            raise ValueError(f"{path!r} is not a {ARTIFACT_FORMAT} "
                             f"directory (format={meta.get('format')!r})")
        if meta.get("version", 0) > ARTIFACT_VERSION:
            raise ValueError(
                f"artifact version {meta['version']} is newer than this "
                f"library supports ({ARTIFACT_VERSION})")
        # layout skew is silent garbage, not a crash — refuse it here
        pack = meta.get("pack", {})
        expect = {"master_m": packed_lib.MASTER_M,
                  "sign_bits": packed_lib.SIGN_BITS,
                  "exp_bits": packed_lib.EXP_BITS,
                  "group_size": sefp.GROUP_SIZE}
        skew = {k: (pack[k], want) for k, want in expect.items()
                if k in pack and pack[k] != want}
        if skew:
            raise ValueError(
                f"artifact at {path!r} was packed with different layout "
                f"constants than this library uses "
                f"({{k: (stored, current)}} = {skew}); it cannot be "
                f"decoded correctly — re-export it from its source "
                f"checkpoint with this version")
        dtypes = meta.get("arrays", {}).get("dtypes", {})
        master: dict = {}
        with np.load(os.path.join(path, _ARRAYS)) as npz:
            n_expect = meta.get("arrays", {}).get("n", len(npz.files))
            if len(npz.files) != n_expect:
                raise ValueError(
                    f"artifact at {path!r} is corrupt: meta records "
                    f"{n_expect} arrays, npz holds {len(npz.files)}")
            for key in npz.files:
                a = npz[key]
                if key in dtypes:
                    dt = getattr(ml_dtypes, dtypes[key], None)
                    a = a.view(dt if dt is not None
                               else np.dtype(dtypes[key]))
                _tree_insert(master, CKPT.split_key(key, unescape=False),
                             jnp.asarray(a))
        cfg = ModelConfig(**meta["model"])
        return cls(cfg=cfg, master=master, meta=meta)

    # -- serving / evaluation (serve side) ---------------------------------
    def server(self, policy: Optional[PrecisionPolicy] = None,
               max_len: int = 256, **kw):
        """A SwitchableServer over this artifact's master — pack-free
        startup — with ``policy`` (default: the policy recorded at export)
        installed for per-class and mid-stream scheduling."""
        from repro.serve.engine import SwitchableServer

        srv = SwitchableServer.from_master(self.cfg, self.master,
                                           max_len=max_len, **kw)
        srv.set_policy(policy if policy is not None else self.policy)
        # the BPS stats ride along so stats-driven serving consumers (the
        # speculative acceptance estimator, DESIGN.md §15) can read them
        # without holding the Artifact; None when the artifact has none
        srv.bps_stats = self.bps_stats
        return srv

    def evaluate(self, batch, widths: Optional[Sequence[int]] = None) -> dict:
        """Loss of the DEPLOYED numerics at each width: the master is
        dequantized at m (the serving truncation) and run through the model
        loss.  Returns {m: loss}."""
        import jax.numpy as jnp

        from repro.models import model_zoo as Z

        loss_fn = Z.make_loss_fn(self.cfg)

        @jax.jit
        def at_width(master, b, m):
            return loss_fn(packed_lib.dequantize_master_tree(master, m), b)

        widths = tuple(widths) if widths is not None else self.trained_widths
        return {int(m): float(at_width(self.master, batch, jnp.int32(m)))
                for m in widths}

    def memory_report(self) -> dict:
        return packed_lib.tree_nbytes(self.master)

    # -- metadata accessors -------------------------------------------------
    @property
    def policy(self) -> PrecisionPolicy:
        return PrecisionPolicy.from_meta(self.meta["policy"])

    @property
    def trained_widths(self) -> tuple:
        return tuple(self.meta["policy"]["widths"])

    @property
    def bps_stats(self) -> Optional[dict]:
        """The final BPS visit/loss statistics recorded at export
        (``{"t", "t_b", "loss_b"}``, arms aligned with the policy's
        ``widths`` order), or None for stats-less artifacts — the graceful
        accessor; ``require_bps_stats`` is the loud one."""
        return self.meta.get("bps")

    def require_bps_stats(self) -> dict:
        """The BPS stats, or MissingBPSStats (a NAMED KeyError, not a bare
        one) when the artifact predates them / was packed from bare
        params.  Use this when the stats are load-bearing; use the
        ``bps_stats`` property where degrading to static behaviour is the
        right call."""
        stats = self.meta.get("bps")
        if stats is None:
            raise MissingBPSStats(self.provenance.get("source"))
        return stats

    @property
    def provenance(self) -> dict:
        return self.meta.get("provenance", {})


def export_artifact(path: str, cfg: ModelConfig, state,
                    policy: Optional[PrecisionPolicy] = None,
                    min_size: int = 4096,
                    provenance: Optional[dict] = None) -> Artifact:
    """End-of-training export: pack ``state`` (OTAROState or bare params)
    once and persist the all-precision serving artifact at ``path``."""
    art = Artifact.from_state(cfg, state, policy=policy, min_size=min_size,
                              provenance=provenance)
    art.save(path)
    return art


def load_artifact(path: str) -> Artifact:
    return Artifact.load(path)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

# numpy-native dtypes that survive an npz round-trip; anything else (the
# bf16 raw leaves) is stored as a same-width unsigned-int bit view.
_NPZ_SAFE = frozenset({
    "bool", "int8", "uint8", "int16", "uint16", "int32", "uint32",
    "int64", "uint64", "float16", "float32", "float64",
})
_BITS_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _tree_insert(tree: dict, raw_parts, leaf):
    """Insert a leaf into a nested dict by RAW (still-escaped) path tokens
    from split_key(..., unescape=False).  Master trees are dicts all the
    way down (see module docstring); an unescaped "#<idx>" token means a
    positional (non-dict) container and is a format error — while an
    escaped dict key "\\#..." unescapes back to its literal "#..." name."""
    for p in raw_parts:
        if p.startswith("#"):
            raise ValueError(
                f"artifact key path {raw_parts!r} contains positional "
                f"component {p!r}; master trees must be nested dicts")
    parts = [CKPT.unescape_component(p) for p in raw_parts]
    node = tree
    for p in parts[:-1]:
        node = node.setdefault(p, {})
        if not isinstance(node, dict):
            raise ValueError(f"artifact key path {parts!r} collides with a "
                             f"leaf at {p!r}")
    if parts[-1] in node:
        raise ValueError(f"duplicate artifact key path {parts!r}")
    node[parts[-1]] = leaf


def _bps_meta(bps) -> Optional[dict]:
    if bps is None:
        return None
    return {"t": int(np.asarray(bps.t)),
            "t_b": np.asarray(bps.t_b).tolist(),
            "loss_b": np.asarray(bps.loss_b).astype(float).tolist()}
