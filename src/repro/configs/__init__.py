"""Architecture registry: the 10 assigned architectures (+ paper's own
LLaMA-style configs).  ``get_config(name)`` / ``list_archs()`` are the public
API; ``--arch <id>`` in the launchers resolves here."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS = [
    "minitron_8b",
    "qwen2_0_5b",
    "qwen2_1_5b",
    "yi_9b",
    "zamba2_7b",
    "grok_1_314b",
    "granite_moe_1b_a400m",
    "rwkv6_7b",
    "pixtral_12b",
    "seamless_m4t_large_v2",
    # the paper's own evaluation models (LLaMA-family), used by benchmarks
    "llama3_2_1b",
    "llama3_8b",
]

_ALIASES = {
    "minitron-8b": "minitron_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen2-1.5b": "qwen2_1_5b",
    "yi-9b": "yi_9b",
    "zamba2-7b": "zamba2_7b",
    "grok-1-314b": "grok_1_314b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "rwkv6-7b": "rwkv6_7b",
    "pixtral-12b": "pixtral_12b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama3.2-1b": "llama3_2_1b",
    "llama3-8b": "llama3_8b",
}

# The 10 dry-run architectures (excludes the paper's eval models).
ASSIGNED = ARCH_IDS[:10]


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    if hasattr(mod, "REDUCED"):
        return mod.REDUCED
    return mod.CONFIG.reduced()


def list_archs() -> List[str]:
    return list(ARCH_IDS)
