"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone + shared
attention blocks.

81L Mamba2, d_model=3584, shared attn: 32 heads (GQA kv=32, head_dim=112),
d_ff=14336, vocab=32000, ssm_state=64.  Two shared attention blocks,
alternating, invoked every 6 backbone layers (14 invocations).

Hybrid family: long_500k RUNS (SSM state is O(1); the shared-attn KV cache
is the only length-proportional state and is sharded over the model axis).

Perf note (EXPERIMENTS.md §Perf cell B): the mamba stack is hostile to
tensor parallelism under GSPMD (0.6 TB/step of residual-stream gathers);
train this arch with the pure-DP layout (`--variant dp` in the dry-run,
`batch_layout="dp"` in train/steps.py) — 12.4x fewer collective bytes.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    n_shared_attn_blocks=2,
    rope_theta=10_000.0,
    remat="full",
)

REDUCED = CONFIG.reduced(n_layers=4, n_kv_heads=4)
