"""Qwen2-0.5B [arXiv:2407.10671; hf] — GQA with QKV bias.

24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151936.
head_dim=64.  Note 14 heads are not divisible by the model-parallel axis
(16); the sharding rules fall back to replicated heads and carry TP on the
MLP/vocab dims instead (DESIGN.md §6).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    remat="full",
)

REDUCED = CONFIG.reduced(qkv_bias=True)
