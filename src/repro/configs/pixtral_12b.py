"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409; unverified] — pixtral-ViT
frontend + Mistral-NeMo-style backbone.

Backbone: 40L, d_model=5120, 32 heads (GQA kv=8, head_dim=128), d_ff=14336,
vocab=131072.  The vision frontend is a STUB per the brief: batches carry
precomputed patch embeddings ([B, 256, d_model] prefix); the decoder
backbone (what the shapes exercise) is real.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    frontend="vision_stub",
    n_prefix_embeds=256,
    rope_theta=1_000_000.0,
    remat="full",
)

REDUCED = CONFIG.reduced(n_prefix_embeds=4)
