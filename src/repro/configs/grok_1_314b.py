"""Grok-1 (314B) [hf:xai-org/grok-1; unverified] — MoE, 8 experts top-2.

64L, d_model=6144, 48 heads (GQA kv=8), d_ff=32768 per expert,
vocab=131072.  SEFP's memory win is largest here: ~309B of the 314B params
are expert weights, all packable to ~9.1 bits master / ~5.1 bits at E5M4.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    n_experts=8,
    top_k=2,
    moe_capacity_factor=1.25,
    moe_dispatch="capacity",
    rope_theta=10_000.0,
    remat="full",
)

REDUCED = CONFIG.reduced()
