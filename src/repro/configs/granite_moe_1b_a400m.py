"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base; hf] —
fine-grained MoE, 32 experts top-8.

24L, d_model=1024, 16 heads (GQA kv=8), d_ff=512 per expert, vocab=49155.
vocab 49155 is not divisible by the 16-way model axis; the sharding rules
fall back to replicating the vocab dim (divisibility fallback, DESIGN.md §6)
— at 50M unembed params the replication cost is negligible.

The tiny per-expert d_ff makes one-hot dispatch FLOP-dominant, so this arch
uses the scatter-based capacity dispatch for train/prefill like the others
but profits most from the dense path at decode.

Perf note (EXPERIMENTS.md §Perf cell A): under TP-16 the un-shardable
dispatch math replicates ~8x; train this arch with the pure-DP layout
(`batch_layout="dp"`) — 8x fewer per-device FLOPs.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    n_experts=32,
    top_k=8,
    moe_capacity_factor=1.25,
    moe_dispatch="capacity",
    rope_theta=10_000.0,
    remat="full",
)

REDUCED = CONFIG.reduced(n_experts=8, top_k=2)
