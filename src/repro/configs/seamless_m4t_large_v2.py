"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf] — multimodal enc-dec backbone.

24L encoder + 24L decoder, d_model=1024, 16 heads (kv=16, head_dim=64),
d_ff=8192, vocab=256206.  The speech frontend is a STUB per the brief:
batches carry precomputed frame embeddings [B, S/4, d_model]; decode shapes
run the DECODER (self-attn KV cache + precomputed cross-attention K/V) —
the arch is enc-dec, not encoder-only, so decode cells apply.

vocab 256206 is not divisible by 64/16; embedding quantization groups fall
back to the d_model axis and the vocab dim falls back to replication
(divisibility fallback, DESIGN.md §6).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,           # informational: 24 enc + 24 dec
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    frontend="audio_stub",
    rope_theta=10_000.0,
    remat="full",
    # the 256206 vocab is replicated (non-divisible by TP-16); smaller CE
    # chunks keep the [B, chunk, V] logits transient ~2 GiB/device
    loss_chunk=128,
)

REDUCED = CONFIG.reduced()
