"""RWKV6-World-7B "Finch" [arXiv:2404.05892; hf] — attention-free,
data-dependent decay.

32L, d_model=4096 (64 heads x head_dim 64), d_ff=14336, vocab=65536.
RWKV family: long_500k RUNS (recurrent state is O(1) per token).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # informational: rwkv uses rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65_536,
    rwkv_head_dim=64,
    rwkv_chunk=32,
    remat="full",
)

REDUCED = CONFIG.reduced()
