"""LLaMA3-8B — one of the paper's zero-shot models (Table 1/5) and the
subject of its Table 2 memory/throughput benchmark.  32L, d_model=4096,
32 heads (GQA kv=8, head_dim=128), d_ff=14336, vocab=128256."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    rope_theta=500_000.0,
    remat="full",
)

REDUCED = CONFIG.reduced()
