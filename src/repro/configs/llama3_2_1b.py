"""LLaMA3.2-1B — the paper's task-specific fine-tuning model (Fig. 7 /
Table 8).  16L, d_model=2048, 32 heads (GQA kv=8, head_dim=64), d_ff=8192,
vocab=128256."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128_256,
    rope_theta=500_000.0,
    remat="full",
)

REDUCED = CONFIG.reduced()
