"""PrecisionPolicy: the single first-class precision specification.

Before this module, precision was expressed three incompatible ways —
``OTAROConfig.widths``/``mode`` in training, ``--precision`` /
``--decode-precision`` ints in the serve CLI, and ad-hoc per-request schedule
lists in the examples.  ``PrecisionPolicy`` replaces all three: one immutable
object describes *which* widths a model is tuned for and *how* a server
should pick a width per request and per decode step, and it **compiles** to
each consumer's native form (DESIGN.md §10):

  * train-side lowering: ``OTAROConfig.from_policy(policy)`` maps ``widths``
    to the BPS arm set and ``mode``/``default`` to the OTARo training mode
    (repro/core/otaro.py);
  * serve-side lowering: ``compile_schedule(max_new, request_class)``
    produces the per-step width list that the engine turns into the traced
    ``int32[max_new]`` schedule array of the fused decode scan
    (repro/serve/engine.py) — so a policy switch is data, never a retrace.

A policy covers three serving shapes at once:

  * fixed width — ``PrecisionPolicy.fixed(7)``;
  * per-request-class mapping — ``.with_class("understanding", 3)``; a class
    may map to a width or to a mid-stream plan;
  * mid-stream schedules — ``.with_schedule([(8, 8), (4, None)])``: 8 tokens
    at E5M8, then E5M4 for the rest (the paper's prefill/decode asymmetry).

Plans are tuples of ``(width, count)`` segments; only the final segment may
have ``count=None`` ("the rest").  ``compile_schedule`` expands a plan to
exactly ``max_new`` steps (a too-long plan is truncated, a too-short one is
extended at its last width), so one policy serves any generation length.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

from repro.core.packed import MASTER_M
from repro.core.sefp import MANTISSA_WIDTHS

TRAIN_MODES = ("otaro", "bps_only", "uniform", "fixed", "fp16")

# a serving plan: int width | [(width, count_or_None), ...]
PlanSpec = Union[int, Sequence[Tuple[int, Optional[int]]]]
Plan = Tuple[Tuple[int, Optional[int]], ...]


def _check_width(m: int, what: str) -> int:
    m = int(m)
    if not 1 <= m <= MASTER_M:
        raise ValueError(f"{what} must be a mantissa width in 1..{MASTER_M}, "
                         f"got {m}")
    return m


def _norm_plan(spec: PlanSpec, what: str) -> Plan:
    """Normalize a plan spec to ((width, count|None), ...)."""
    if isinstance(spec, int):
        return ((_check_width(spec, what), None),)
    segs = tuple(spec)
    if not segs:
        raise ValueError(f"{what}: empty schedule")
    out = []
    for i, seg in enumerate(segs):
        try:
            m, n = seg
        except (TypeError, ValueError):
            raise ValueError(
                f"{what}: segment {i} must be (width, count), got {seg!r}")
        m = _check_width(m, f"{what} segment {i}")
        if n is None:
            if i != len(segs) - 1:
                raise ValueError(f"{what}: only the last segment may have "
                                 f"count=None (segment {i})")
        else:
            n = int(n)
            if n <= 0:
                raise ValueError(f"{what}: segment {i} count must be "
                                 f"positive, got {n}")
        out.append((m, n))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """One precision policy for the whole train -> export -> serve lifecycle.

    ``widths``  the supported bit-width set, high -> low.  Training lowers it
                to the BPS arm set; artifacts record it as the set the model
                was tuned for.
    ``mode``    training mode (otaro | bps_only | uniform | fixed | fp16).
    ``default`` the width served when no class / schedule applies (and the
                fixed training width when ``mode == "fixed"``).
    ``plan``    optional default mid-stream plan used instead of ``default``.
    ``classes`` request-class name -> plan (per-request-class serving).
    ``floors``  request-class name -> minimum serving width: the class's
                degradation floor.  Overload policies (the scheduler's
                slo-degrade, DESIGN.md §12) may serve a request *below* its
                wanted width to hold latency SLOs — but never below its
                class floor, so a class can refuse degradation outright
                (floor == its wanted width).  Classes without a floor
                degrade freely down to the policy's lowest width.
    ``speculative``  optional self-speculative decoding spec (DESIGN.md
                §15): a JSON-able dict of SpeculativeConfig fields
                (``{"k", "draft_width", "candidates", ...}``, see
                repro/serve/speculative.py).  A ContinuousScheduler built
                over this policy speculates by default; its own
                ``spec_decode`` argument overrides (False disables).
    """

    widths: Tuple[int, ...] = MANTISSA_WIDTHS
    mode: str = "otaro"
    default: int = MANTISSA_WIDTHS[0]
    plan: Optional[Plan] = None
    classes: Mapping[str, Plan] = dataclasses.field(default_factory=dict)
    floors: Mapping[str, int] = dataclasses.field(default_factory=dict)
    speculative: Optional[Mapping] = None

    def __post_init__(self):
        widths = tuple(_check_width(m, "policy width") for m in self.widths)
        if not widths:
            raise ValueError("policy needs at least one width")
        if len(set(widths)) != len(widths):
            raise ValueError(f"duplicate widths in {widths}")
        object.__setattr__(self, "widths", widths)
        if self.mode not in TRAIN_MODES:
            raise ValueError(f"unknown training mode {self.mode!r}; "
                             f"expected one of {TRAIN_MODES}")
        object.__setattr__(self, "default",
                           _check_width(self.default, "default width"))
        if self.plan is not None:
            object.__setattr__(self, "plan", _norm_plan(self.plan, "plan"))
        norm = {str(k): _norm_plan(v, f"class {k!r}")
                for k, v in dict(self.classes).items()}
        object.__setattr__(self, "classes", norm)
        fl = {str(k): _check_width(v, f"floor for class {k!r}")
              for k, v in dict(self.floors).items()}
        for k in fl:
            if k not in norm:
                raise ValueError(f"floor names unknown class {k!r}; "
                                 f"defined classes: {sorted(norm)}")
        object.__setattr__(self, "floors", fl)
        if self.speculative is not None:
            # stored as a plain JSON-able dict; deep validation happens in
            # SpeculativeConfig (serve/speculative.py) when a scheduler
            # (or with_speculation) lowers it — policy.py stays import-
            # independent of the serve package
            try:
                object.__setattr__(self, "speculative",
                                   dict(self.speculative))
            except (TypeError, ValueError):
                raise ValueError(
                    f"speculative must be a dict of SpeculativeConfig "
                    f"fields or None, got {self.speculative!r}") from None

    # -- constructors -------------------------------------------------------
    @classmethod
    def all_widths(cls, widths: Sequence[int] = MANTISSA_WIDTHS,
                   mode: str = "otaro",
                   default: Optional[int] = None) -> "PrecisionPolicy":
        """The paper's policy: tune once over ``widths`` (BPS over the full
        arm set), serve at ``default`` (highest width unless given)."""
        widths = tuple(widths)
        return cls(widths=widths, mode=mode,
                   default=max(widths) if default is None else default)

    @classmethod
    def fixed(cls, m: int) -> "PrecisionPolicy":
        """A single width everywhere: fixed-precision fine-tuning and a
        constant serving schedule."""
        return cls(widths=(int(m),), mode="fixed", default=int(m))

    # -- functional updates -------------------------------------------------
    def with_default(self, m: int) -> "PrecisionPolicy":
        return dataclasses.replace(self, default=int(m))

    def with_schedule(self, spec: PlanSpec) -> "PrecisionPolicy":
        """Set the default mid-stream plan, e.g. ``[(8, 8), (4, None)]``."""
        return dataclasses.replace(self, plan=_norm_plan(spec, "plan"))

    def with_class(self, name: str, spec: PlanSpec,
                   min_width: Optional[int] = None) -> "PrecisionPolicy":
        """Map a request class to a width or a mid-stream plan.
        ``min_width`` sets the class's degradation floor (see ``floors``):
        overload policies never serve the class below it."""
        classes = dict(self.classes)
        classes[str(name)] = _norm_plan(spec, f"class {name!r}")
        floors = dict(self.floors)
        if min_width is not None:
            floors[str(name)] = _check_width(min_width,
                                             f"floor for class {name!r}")
        return dataclasses.replace(self, classes=classes, floors=floors)

    def with_floor(self, name: str, min_width: int) -> "PrecisionPolicy":
        """Set the degradation floor of an already-defined class."""
        floors = dict(self.floors)
        floors[str(name)] = _check_width(min_width,
                                         f"floor for class {name!r}")
        return dataclasses.replace(self, floors=floors)

    def with_speculation(self, spec=True) -> "PrecisionPolicy":
        """Attach a self-speculative decoding spec (DESIGN.md §15):
        ``True`` for defaults, an int for the draft depth ``k``, a dict of
        SpeculativeConfig fields, or a SpeculativeConfig.  ``False``/None
        detaches.  Schedulers built over the policy speculate by default;
        their ``spec_decode`` argument still overrides per scheduler."""
        # runtime import: policy.py is imported by the serve package, so
        # the serve dependency must stay out of module scope
        from repro.serve.speculative import as_spec
        cfg = as_spec(spec)
        return dataclasses.replace(
            self, speculative=None if cfg is None else cfg.describe())

    # -- serve-side lowering ------------------------------------------------
    def plan_for(self, request_class: Optional[str] = None) -> Plan:
        if request_class is not None:
            if request_class not in self.classes:
                raise KeyError(
                    f"unknown request class {request_class!r}; policy "
                    f"defines {sorted(self.classes) or 'no classes'}")
            return self.classes[request_class]
        return self.plan if self.plan is not None else (
            (self.default, None),)

    def min_width_for(self, request_class: Optional[str] = None) -> int:
        """The degradation floor an overload policy must respect for this
        class: the class's declared floor, else the policy's lowest tuned
        width (no width outside ``widths`` is ever servable — the model
        was not tuned for it)."""
        if request_class is not None and request_class in self.floors:
            return self.floors[request_class]
        return min(self.widths)

    def request_schedule(self, max_new: int,
                         request_class: Optional[str] = None) -> list:
        """The per-step width list ONE request decodes under, resolving in
        serving priority order: request-class plan > default mid-stream plan
        > constant default width.  ``max_new <= 0`` is an empty schedule
        (prefill-only request).  This is the single resolution rule shared
        by the lockstep engine (repro/serve/engine.py) and the continuous
        scheduler (repro/serve/scheduler.py), so a request class means the
        same thing on both serving paths."""
        if max_new <= 0:
            return []
        return self.compile_schedule(max_new, request_class)

    def compile_schedule(self, max_new: int,
                         request_class: Optional[str] = None) -> list:
        """Lower to the per-step width list of length ``max_new`` that the
        serving engine traces as the ``int32[max_new]`` schedule array."""
        if max_new <= 0:
            raise ValueError(f"max_new must be positive, got {max_new}")
        sched: list = []
        plan = self.plan_for(request_class)
        for m, n in plan:
            if len(sched) >= max_new:
                break
            take = max_new - len(sched) if n is None else min(
                n, max_new - len(sched))
            sched.extend([m] * take)
        if len(sched) < max_new:  # finite plan shorter than the generation
            sched.extend([plan[-1][0]] * (max_new - len(sched)))
        return sched

    # -- train-side lowering ------------------------------------------------
    def train_lowering(self) -> dict:
        """The OTAROConfig precision fields (consumed by
        ``OTAROConfig.from_policy`` in repro/core/otaro.py)."""
        return {"widths": self.widths, "mode": self.mode,
                "fixed_m": self.default}

    # -- provenance ---------------------------------------------------------
    def describe(self) -> dict:
        """JSON-ready form, stored in artifact meta and loadable back."""
        return {"widths": list(self.widths), "mode": self.mode,
                "default": self.default,
                "plan": [list(s) for s in self.plan] if self.plan else None,
                "classes": {k: [list(s) for s in v]
                            for k, v in self.classes.items()},
                "floors": dict(self.floors),
                "speculative": (dict(self.speculative)
                                if self.speculative is not None else None)}

    @classmethod
    def from_meta(cls, d: dict) -> "PrecisionPolicy":
        return cls(widths=tuple(d["widths"]), mode=d["mode"],
                   default=d["default"],
                   plan=(tuple((m, n) for m, n in d["plan"])
                         if d.get("plan") else None),
                   classes={k: tuple((m, n) for m, n in v)
                            for k, v in d.get("classes", {}).items()},
                   floors={k: int(v)
                           for k, v in d.get("floors", {}).items()},
                   speculative=d.get("speculative"))
