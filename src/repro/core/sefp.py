"""SEFP (Shared Exponent Floating Point) quantization numerics.

This is the paper's core format (OTARo, AAAI 2026): each group of
``group_size`` (default 64) parameters shares one 5-bit exponent — the maximum
exponent in the group — and each parameter keeps a sign plus an ``m``-bit
mantissa magnitude aligned to that shared exponent.  Every precision
``E5M8 … E5M3`` is a mantissa truncation of the same representation, so
precision switching requires no scaling factors.

Normative definition (see DESIGN.md §4):

    E*      = clamp(max_i floor(log2 |w_i|), EXP_MIN, EXP_MAX)   per group
    quantum = 2^(E* - (m-1))
    code_i  = clamp(round(w_i / quantum), -(2^m - 1), 2^m - 1)
    ŵ_i     = code_i * quantum

Key systems property exploited throughout this framework: ``m`` enters the
computation only through ``2^(m-1)`` and the clamp bound ``2^m - 1``, both of
which are cheap in-graph scalars.  We therefore treat the mantissa width as a
*traced* int32 scalar, so a single compiled executable (train step or serve
step) covers all precisions — no recompilation when BPS switches bit-width
each batch, and no recompilation when an on-device request changes precision.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

# E5 exponent field (FP16-compatible bias range for normals).
EXP_MIN = -14
EXP_MAX = 15

GROUP_SIZE = 64

# The paper's bit-width set B = {E5M8 .. E5M3}; indices into this tuple are
# the canonical "bit-width ids" used by BPS.
MANTISSA_WIDTHS = (8, 7, 6, 5, 4, 3)


def _move_group_axis_last(w: jax.Array, group_axis: int) -> jax.Array:
    if group_axis in (-1, w.ndim - 1):
        return w
    return jnp.moveaxis(w, group_axis, -1)


def _restore_group_axis(w: jax.Array, group_axis: int, ndim: int) -> jax.Array:
    if group_axis in (-1, ndim - 1):
        return w
    return jnp.moveaxis(w, -1, group_axis)


def floor_log2(x: jax.Array) -> jax.Array:
    """Exact floor(log2(|x|)) for positive finite x, via frexp.

    frexp returns (mant, exp) with |x| = mant * 2^exp, mant in [0.5, 1), so
    floor(log2|x|) = exp - 1 exactly (no log rounding pitfalls at powers of 2).
    Zeros map to a very small exponent so they never win the group max.
    """
    x = jnp.abs(x)
    _, e = jnp.frexp(x)
    e = e.astype(jnp.int32) - 1
    return jnp.where(x > 0, e, jnp.int32(-127))


def group_shared_exponent(
    w: jax.Array,
    group_size: int = GROUP_SIZE,
    group_axis: int = -1,
) -> jax.Array:
    """Per-group shared exponent E* (int32), shape = w.shape with the group
    axis reduced by ``group_size``.  Group axis length must be divisible by
    ``group_size`` (configs guarantee this; pad upstream otherwise)."""
    wl = _move_group_axis_last(w, group_axis)
    *lead, n = wl.shape
    if n % group_size != 0:
        raise ValueError(f"group axis length {n} not divisible by {group_size}")
    g = wl.reshape(*lead, n // group_size, group_size)
    e = floor_log2(g).max(axis=-1)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    return e


def exp2i(e: jax.Array) -> jax.Array:
    """Exact 2**e for integer e in [-126, 127], built by placing e in the
    fp32 exponent field.  (jnp.exp2 is NOT exact on all backends — it may
    lower to exp(e*ln2) — and SEFP requires power-of-two quanta to be exact
    or truncation/round-trip identities break.)"""
    e = jnp.asarray(e, jnp.int32)
    bits = (e + 127) << 23
    return lax.bitcast_convert_type(bits.astype(jnp.int32), jnp.float32)


def sefp_quantize(
    w: jax.Array,
    m: jax.Array | int,
    group_size: int = GROUP_SIZE,
    group_axis: int = -1,
    rounding: str = "nearest",
) -> jax.Array:
    """Fake-quantize ``w`` to SEFP E5M``m`` and return the dequantized array.

    ``m`` may be a Python int or a traced int32 scalar (dynamic precision).
    ``rounding``: "nearest" (round-half-even, training; Eq. 11's [.]) or
    "trunc" (round-toward-zero, deployment truncation semantics).
    """
    orig_dtype = w.dtype
    ndim = w.ndim
    wf = w.astype(jnp.float32)
    wl = _move_group_axis_last(wf, group_axis)
    *lead, n = wl.shape
    g = wl.reshape(*lead, n // group_size, group_size)

    e = floor_log2(g).max(axis=-1, keepdims=True)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)

    m = jnp.asarray(m, jnp.int32)
    quantum = exp2i(e - (m - 1))  # [..., G, 1]
    maxmag = exp2i(m) - 1.0  # 2^m - 1, exact

    scaled = g / quantum
    if rounding == "nearest":
        code = jnp.round(scaled)  # round-half-to-even
    elif rounding == "trunc":
        code = jnp.trunc(scaled)
    else:
        raise ValueError(f"unknown rounding mode {rounding!r}")
    code = jnp.clip(code, -maxmag, maxmag)

    out = (code * quantum).reshape(*lead, n)
    out = _restore_group_axis(out, group_axis, ndim)
    return out.astype(orig_dtype)


def sefp_quantize_ste(
    w: jax.Array,
    m: jax.Array | int,
    group_size: int = GROUP_SIZE,
    group_axis: int = -1,
    rounding: str = "nearest",
) -> jax.Array:
    """Straight-through-estimator variant: forward = Q(w, m), dw = identity
    (paper Eq. 1-3)."""
    q = sefp_quantize(w, m, group_size=group_size, group_axis=group_axis,
                      rounding=rounding)
    return w + lax.stop_gradient(q - w)


# ---------------------------------------------------------------------------
# Pytree application: quantize all eligible weights of a model.
# ---------------------------------------------------------------------------

def _is_eligible(path: tuple, leaf: jax.Array, min_size: int,
                 exclude_substrings: Sequence[str]) -> bool:
    if not isinstance(leaf, jax.Array) and not hasattr(leaf, "shape"):
        return False
    if leaf.ndim < 2:          # biases, norms, scalar gates stay full precision
        return False
    if leaf.size < min_size:
        return False
    name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    for s in exclude_substrings:
        if s in name:
            return False
    return True


# Parameters whose names contain these substrings are never SEFP-quantized:
# SSM/RWKV recurrence parameters gate exponentials (see DESIGN.md §5) and
# norm scales / biases are tiny.
DEFAULT_EXCLUDE = ("A_log", "ssm_dt", "decay", "time_", "norm", "scale",
                   "bias", "ln_")


def quantize_tree(
    params,
    m: jax.Array | int,
    group_size: int = GROUP_SIZE,
    group_axis: int = 0,
    min_size: int = 4096,
    exclude_substrings: Sequence[str] = DEFAULT_EXCLUDE,
    ste: bool = True,
):
    """Apply SEFP fake-quant (with STE by default) to every eligible weight in
    a parameter pytree.  2-D+ weights are grouped along ``group_axis``
    (default 0 = contraction axis of ``x @ W`` weights).  Returns a new pytree.
    """
    fn = sefp_quantize_ste if ste else sefp_quantize

    def visit(path, leaf):
        if not _is_eligible(path, leaf, min_size, exclude_substrings):
            return leaf
        ax = group_axis if leaf.shape[group_axis] % group_size == 0 else (
            -1 if leaf.shape[-1] % group_size == 0 else None)
        if ax is None:
            return leaf  # no groupable axis; leave full precision
        return fn(leaf, m, group_size=group_size, group_axis=ax)

    return jax.tree_util.tree_map_with_path(visit, params)


def eligible_param_fraction(params, **kw) -> float:
    """Fraction of total parameters that quantize_tree() would quantize —
    used by benchmarks/memory accounting."""
    total = 0
    quant = 0
    min_size = kw.get("min_size", 4096)
    excl = kw.get("exclude_substrings", DEFAULT_EXCLUDE)

    def visit(path, leaf):
        nonlocal total, quant
        size = int(leaf.size)
        total += size
        if _is_eligible(path, leaf, min_size, excl):
            quant += size
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return quant / max(total, 1)


@functools.partial(jax.jit, static_argnames=("group_size", "group_axis",
                                             "rounding"))
def sefp_quantize_jit(w, m, group_size=GROUP_SIZE, group_axis=-1,
                      rounding="nearest"):
    return sefp_quantize(w, m, group_size=group_size, group_axis=group_axis,
                         rounding=rounding)
