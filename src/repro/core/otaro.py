"""OTARo step policy: BPS bit-width selection -> quantized-loss gradient (STE)
-> LAA delayed accumulation -> optimizer update.  (Paper Algorithm 1.)

`make_otaro_step` builds a *pure* step function `(state, batch) -> (state,
metrics)` suitable for `jax.jit` / `pjit`.  The selected mantissa width is a
dynamic scalar, so one compiled executable covers every precision in B —
BPS can switch precision every batch with zero recompilation (DESIGN.md §3).

Training modes (used by the paper's baselines and ablations):
  - "otaro"    : BPS + LAA (the full method)
  - "bps_only" : BPS without LAA (ablation, Fig. 8)
  - "uniform"  : cycle uniformly through B (Fig. 3 baseline)
  - "fixed"    : a single fixed bit-width (fixed-precision fine-tuning)
  - "fp16"     : no quantization in the loss (FP16 fine-tuning baseline)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import bps as bps_lib
from repro.core import laa as laa_lib
from repro.core import sefp
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class OTAROConfig:
    widths: Sequence[int] = sefp.MANTISSA_WIDTHS   # mantissa widths, high->low
    lam: float = 5.0                  # BPS exploration coefficient (paper: 5)
    laa_n: int = 10                   # LAA delay steps (paper: 10)
    laa_threshold_m: int = 4          # widths <= this are "ultra-low"
    laa_average: bool = False         # False = paper's summed update (Eq. 18)
    group_size: int = sefp.GROUP_SIZE
    group_axis: int = 0
    min_size: int = 4096
    exclude_substrings: Sequence[str] = sefp.DEFAULT_EXCLUDE
    mode: str = "otaro"
    fixed_m: int = 8                  # used when mode == "fixed"
    loss_ema: float = 1.0             # BPS real-time loss (1.0 = latest)
    grad_clip: Optional[float] = None

    @classmethod
    def from_policy(cls, policy, **overrides) -> "OTAROConfig":
        """Train-side lowering of a repro.policy.PrecisionPolicy: its width
        set becomes the BPS arm set, its mode/default the training mode and
        fixed width.  Duck-typed (anything with ``train_lowering()``) so the
        core stays importable without the policy layer; ``overrides`` set
        the remaining hyperparameters (lam, laa_n, ...)."""
        kw = policy.train_lowering()
        kw.update(overrides)
        return cls(**kw)


class OTAROState(NamedTuple):
    params: Any
    opt_state: Any
    bps: bps_lib.BPSState
    laa: laa_lib.LAAState
    step: jax.Array


def init_state(params, optimizer: opt_lib.Optimizer,
               cfg: OTAROConfig) -> OTAROState:
    return OTAROState(
        params=params,
        opt_state=optimizer.init(params),
        bps=bps_lib.init(len(cfg.widths)),
        laa=_empty_laa(params, cfg),
        step=jnp.zeros((), jnp.int32),
    )


def _empty_laa(params, cfg: OTAROConfig) -> laa_lib.LAAState:
    """Modes without LAA keep a zero-size buffer to preserve the state pytree
    structure (checkpoint compatibility across modes)."""
    if cfg.mode == "otaro":
        return laa_lib.init(params)
    buf = jax.tree_util.tree_map(lambda p: jnp.zeros((), jnp.float32), params)
    return laa_lib.LAAState(buf=buf, count=jnp.zeros((), jnp.int32))


def make_otaro_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    optimizer: opt_lib.Optimizer,
    cfg: OTAROConfig,
    grad_transform: Optional[Callable[[Any], Any]] = None,
    loss_transform: Optional[Callable[[jax.Array], jax.Array]] = None,
):
    """loss_fn(params_quantized, batch) -> scalar loss.

    grad_transform/loss_transform: distribution hooks applied right after
    the backward pass — e.g. the SEFP-compressed cross-pod all-reduce
    (train/compression.py) when the step runs shard_map'ed over the pod
    axis, paired with a pod-mean of the loss so BPS state stays replicated.
    """
    widths = tuple(cfg.widths)

    def quantized_loss(params, batch, m):
        qp = sefp.quantize_tree(
            params, m, group_size=cfg.group_size, group_axis=cfg.group_axis,
            min_size=cfg.min_size, exclude_substrings=cfg.exclude_substrings,
            ste=True)
        return loss_fn(qp, batch)

    def step_fn(state: OTAROState, batch):
        # --- 1. bit-width selection -------------------------------------
        if cfg.mode in ("otaro", "bps_only"):
            arm, m = bps_lib.select(state.bps, cfg.lam, widths)
        elif cfg.mode == "uniform":
            arm, m = bps_lib.uniform_select(state.step, widths)
        elif cfg.mode == "fixed":
            arm = jnp.asarray(widths.index(cfg.fixed_m), jnp.int32)
            m = jnp.asarray(cfg.fixed_m, jnp.int32)
        elif cfg.mode == "fp16":
            arm = jnp.zeros((), jnp.int32)
            m = jnp.asarray(max(widths), jnp.int32)
        else:
            raise ValueError(f"unknown mode {cfg.mode!r}")

        # --- 2. quantized-loss gradient (STE) ---------------------------
        if cfg.mode == "fp16":
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            loss, grads = jax.value_and_grad(quantized_loss)(
                state.params, batch, m)

        if grad_transform is not None:
            grads = grad_transform(grads)
        if loss_transform is not None:
            loss = loss_transform(loss)

        if cfg.grad_clip is not None:
            grads, _ = opt_lib.clip_by_global_norm(grads, cfg.grad_clip)

        # --- 3. BPS bookkeeping ------------------------------------------
        new_bps = bps_lib.update(state.bps, arm, loss, cfg.loss_ema)

        # --- 4. LAA delayed accumulation ---------------------------------
        if cfg.mode == "otaro":
            is_low = m <= cfg.laa_threshold_m
            eff_grads, do_update, new_laa = laa_lib.step(
                state.laa, grads, is_low, cfg.laa_n, cfg.laa_average)
        else:
            eff_grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
            do_update = jnp.asarray(True)
            new_laa = state.laa

        # --- 5. optimizer (masked on LAA-held batches) --------------------
        updates, new_opt = optimizer.update(
            eff_grads, state.opt_state, state.params)
        new_params = opt_lib.apply_updates(state.params, updates)
        params, opt_state = opt_lib.masked_apply(
            state.params, state.opt_state, new_params, new_opt, do_update)

        metrics = {
            "loss": loss,
            "mantissa_width": m,
            "did_update": do_update.astype(jnp.int32),
            "laa_count": new_laa.count,
            "bps_t_b": new_bps.t_b,
        }
        new_state = OTAROState(params=params, opt_state=opt_state,
                               bps=new_bps, laa=new_laa,
                               step=state.step + 1)
        return new_state, metrics

    return step_fn


def make_eval_fn(loss_fn: Callable[[Any, Any], jax.Array],
                 cfg: OTAROConfig):
    """Evaluation at an arbitrary precision: eval_fn(params, batch, m).
    m = None-like sentinel is not supported — pass max(width) for 'fp' eval
    with quantization, or use loss_fn directly for true full precision."""

    def eval_fn(params, batch, m):
        qp = sefp.quantize_tree(
            params, m, group_size=cfg.group_size, group_axis=cfg.group_axis,
            min_size=cfg.min_size, exclude_substrings=cfg.exclude_substrings,
            ste=False)
        return loss_fn(qp, batch)

    return eval_fn
