"""OTARo core: SEFP quantization + BPS bit-width search + LAA accumulation."""

from repro.core.sefp import (  # noqa: F401
    EXP_MAX,
    EXP_MIN,
    GROUP_SIZE,
    MANTISSA_WIDTHS,
    quantize_tree,
    sefp_quantize,
    sefp_quantize_ste,
)
from repro.core.packed import (  # noqa: F401
    MASTER_M,
    PackedSEFP,
    dequantize,
    dequantize_master_tree,
    dequantize_stacked,
    dequantize_tree,
    pack,
    pack_stacked,
    pack_tree,
    stream_bits_per_param,
)
from repro.core.otaro import (  # noqa: F401
    OTAROConfig,
    OTAROState,
    init_state,
    make_eval_fn,
    make_otaro_step,
)
