"""PackedSEFP: the deployable SEFP master representation.

Master format per weight tensor: the group axis is moved to the FRONT and
arrays are stored "k-major" — exactly the layout the serving matmul kernel
(repro/kernels/sefp_matmul) consumes without any transposition:

  mag       uint8  [n, *rest]        M8 mantissa magnitudes (0..255)
  sign_bits uint8  [n//8, *rest]     bit-packed signs along the group axis
                                     (bit j of byte i -> element 8i + j; 1=neg)
  exp       int8   [n//64, *rest]    per-group shared exponent E* (E5 range)

For a 2-D weight W[K, N] grouped along the contraction axis (group_axis=0,
the default used throughout the framework) this is mag[K, N],
sign_bits[K//8, N], exp[K//64, N].

Bits/param = 8 + 1 + 8/64 = 9.125 (paper: ~9.08 for E5M8).  Truncating the
master to E5Mk is ``mag >> (8-k)`` — the paper's Fig. 1/2 mechanism — and is
performed *on the fly* (fused into the serving matmul kernel), so switching
precision at runtime moves zero bytes.

Dequantized value: (1-2*sign) * (mag >> (8-k)) * 2^(E* - (k-1)).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sefp

MASTER_M = 8   # master mantissa width
SIGN_BITS = 1  # one bit-packed sign per parameter
EXP_BITS = 8   # int8 storage per shared group exponent


def stream_bits_per_param(m: int | float,
                          group_size: int = sefp.GROUP_SIZE) -> float:
    """Streaming bits/param when serving at mantissa width ``m``: the kernel
    reads the truncated magnitude lane-compressed to m bits, the sign bit,
    and the amortized group exponent.  ``m = MASTER_M`` gives the resident
    master footprint (9.125 for E5M8 / group 64) — the single place this
    constant is derived, so accounting can't drift from the format."""
    return (m + SIGN_BITS) + EXP_BITS / group_size


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedSEFP:
    """Packed SEFP tensor. ``shape``/``group_axis`` describe the logical
    (unpacked) tensor; arrays are stored with the group axis moved to the
    front (k-major)."""

    mag: jax.Array        # uint8 [n, *rest]
    sign_bits: jax.Array  # uint8 [n//8, *rest]
    exp: jax.Array        # int8  [n//group_size, *rest]
    shape: tuple          # logical shape
    group_axis: int
    group_size: int

    def tree_flatten(self):
        return (self.mag, self.sign_bits, self.exp), (
            self.shape, self.group_axis, self.group_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        mag, sign_bits, exp = children
        shape, group_axis, group_size = aux
        return cls(mag, sign_bits, exp, shape, group_axis, group_size)

    @property
    def nbytes_packed(self) -> int:
        """True deployed size in bytes (bit-packed accounting)."""
        return int(self.mag.size + self.sign_bits.size + self.exp.size)

    def bits_per_param(self, m: int = MASTER_M) -> float:
        """Streaming bits/param when serving at mantissa width m."""
        return stream_bits_per_param(m, self.group_size)


def _norm_axis(axis: int, ndim: int) -> int:
    return axis % ndim


def pack(w: jax.Array, group_size: int = sefp.GROUP_SIZE,
         group_axis: int = 0) -> PackedSEFP:
    """Quantize ``w`` to the E5M8 master and pack it (k-major layout)."""
    shape = tuple(w.shape)
    ga = _norm_axis(group_axis, w.ndim)
    wf = jnp.moveaxis(w.astype(jnp.float32), ga, 0)
    n, *rest = wf.shape
    if n % group_size != 0 or n % 8 != 0:
        raise ValueError(f"group axis length {n} must be divisible by "
                         f"{group_size}")
    g = wf.reshape(n // group_size, group_size, *rest)
    e = sefp.floor_log2(g).max(axis=1, keepdims=True)
    e = jnp.clip(e, sefp.EXP_MIN, sefp.EXP_MAX)
    quantum = sefp.exp2i(e - (MASTER_M - 1))
    code = jnp.clip(jnp.round(g / quantum), -255.0, 255.0)
    mag = jnp.abs(code).astype(jnp.uint8).reshape(n, *rest)
    sign = (code < 0).astype(jnp.uint8).reshape(n, *rest)

    sign8 = sign.reshape(n // 8, 8, *rest)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint32)).reshape(
        1, 8, *([1] * len(rest)))
    sign_bits = (sign8.astype(jnp.uint32) * weights).sum(axis=1).astype(
        jnp.uint8)

    exp = e.reshape(n // group_size, *rest).astype(jnp.int8)
    return PackedSEFP(mag=mag, sign_bits=sign_bits, exp=exp, shape=shape,
                      group_axis=ga, group_size=group_size)


def unpack_signs(sign_bits: jax.Array) -> jax.Array:
    """uint8 [n//8, *rest] -> float32 sign multipliers (+1/-1) [n, *rest]."""
    nb, *rest = sign_bits.shape
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, *([1] * len(rest)))
    bits = (sign_bits[:, None] >> shifts) & jnp.uint8(1)
    bits = bits.reshape(nb * 8, *rest)
    return 1.0 - 2.0 * bits.astype(jnp.float32)


def dequantize(p: PackedSEFP, m: jax.Array | int = MASTER_M,
               dtype=jnp.float32) -> jax.Array:
    """Dequantize the packed master at mantissa width ``m`` (<= 8, may be a
    traced scalar).  Pure-jnp reference path; the serving hot path is the
    Pallas kernel in repro/kernels/sefp_matmul."""
    m = jnp.asarray(m, jnp.int32)
    shift = (MASTER_M - m).astype(jnp.uint8)

    n, *rest = p.mag.shape
    magk = (p.mag >> shift).astype(jnp.float32)
    signs = unpack_signs(p.sign_bits)
    quantum = sefp.exp2i(p.exp.astype(jnp.int32) - (m - 1))
    # group-broadcast multiply instead of jnp.repeat: no materialized [n,...]
    # quantum tensor; XLA fuses the broadcast into the consumer.
    out = (signs * magk).reshape(n // p.group_size, p.group_size, *rest)
    out = (out * quantum[:, None]).reshape(n, *rest)
    out = jnp.moveaxis(out, 0, p.group_axis)
    return out.reshape(p.shape).astype(dtype)


def to_int8_codes(p: PackedSEFP, m: jax.Array | int) -> tuple[jax.Array, jax.Array]:
    """Truncate the master to width m<=7 and return (codes int8, exp int8)
    in the k-major layout (codes [n, *rest], exp [n//64, *rest])."""
    m = jnp.asarray(m, jnp.int32)
    shift = (MASTER_M - m).astype(jnp.uint8)
    magk = (p.mag >> shift).astype(jnp.int16)
    signs = unpack_signs(p.sign_bits).astype(jnp.int16)
    codes = (signs * magk).astype(jnp.int8)
    return codes, p.exp


# ---------------------------------------------------------------------------
# Stacked master layout: the serving representation.
#
# A scanned-over-layers weight is stored as a plain dict of raw master arrays
# with the contraction (group) axis at position -2 and arbitrary leading
# batch dims (layer, expert):
#
#   {"mag":  uint8 [..., K, N],
#    "sign": uint8 [..., K//8, N],
#    "exp":  int8  [..., K//group, N]}
#
# For a 2-D [K, N] weight this is exactly PackedSEFP's (mag, sign_bits, exp)
# field layout, so the serving matmul kernel consumes it directly; for a
# stacked [L, K, N] weight, lax.scan slices the leading axis and each slice
# is again a valid 2-D master.  Dicts (not PackedSEFP) so scan/tree_map
# slicing keeps metadata-free leaves and partition rules see named children.
# ---------------------------------------------------------------------------

MASTER_KEYS = frozenset({"mag", "sign", "exp"})


def is_master_leaf(x) -> bool:
    return isinstance(x, dict) and set(x) == MASTER_KEYS


def to_stacked(p: PackedSEFP) -> dict:
    """PackedSEFP (group axis at front) -> stacked master dict (group axis
    at -2, leading batch dims restored).  Requires the logical group axis to
    be the contraction axis ``ndim - 2`` (the x @ W convention used for every
    packed weight in this framework)."""
    ndim = len(p.shape)
    if p.group_axis != ndim - 2:
        raise ValueError(
            f"stacked master layout needs group_axis == ndim-2, got "
            f"group_axis={p.group_axis} for shape {p.shape}")
    return {"mag": jnp.moveaxis(p.mag, 0, -2),
            "sign": jnp.moveaxis(p.sign_bits, 0, -2),
            "exp": jnp.moveaxis(p.exp, 0, -2)}


def packed_view(leaf: dict) -> PackedSEFP:
    """Zero-copy PackedSEFP view of a 2-D stacked master leaf [K, N] — the
    form the sefp_matmul kernels take."""
    if leaf["mag"].ndim != 2:
        raise ValueError(f"packed_view needs a 2-D leaf, got mag shape "
                         f"{leaf['mag'].shape}")
    return PackedSEFP(mag=leaf["mag"], sign_bits=leaf["sign"],
                      exp=leaf["exp"], shape=tuple(leaf["mag"].shape),
                      group_axis=0, group_size=sefp.GROUP_SIZE)


def pack_stacked(w: jax.Array, group_size: int = sefp.GROUP_SIZE) -> dict:
    """Quantize a [..., K, N] weight to the E5M8 master, grouped along the
    contraction axis K (axis -2), in the stacked layout."""
    return to_stacked(pack(w, group_size=group_size, group_axis=w.ndim - 2))


def dequantize_stacked(leaf: dict, m: jax.Array | int,
                       dtype=jnp.bfloat16) -> jax.Array:
    """Dequantize a stacked master leaf at mantissa width ``m`` (python int
    or traced int32 scalar) — the in-scan serving dequant.  Pure broadcast
    arithmetic (no jnp.repeat): the sign unpack and the per-group quantum
    stay group-shaped and XLA fuses them into the consuming matmul."""
    m = jnp.asarray(m, jnp.int32)
    shift = (MASTER_M - m).astype(jnp.uint8)
    mag, sign_bits, e = leaf["mag"], leaf["sign"], leaf["exp"]
    *lead, k_dim, n_dim = mag.shape
    magk = (mag >> shift).astype(jnp.float32)

    # signs: bit (row % 8) of byte (row // 8) along axis -2, via broadcast
    bit_idx = jnp.arange(8, dtype=jnp.uint8)[:, None]        # [8, 1]
    bits = (sign_bits[..., :, None, :] >> bit_idx) & jnp.uint8(1)
    sign = 1.0 - 2.0 * bits.reshape(*lead, k_dim, n_dim).astype(jnp.float32)

    groups = e.shape[-2]
    quantum = sefp.exp2i(e.astype(jnp.int32) - (m - 1))      # [..., G, N]
    out = (sign * magk).reshape(*lead, groups, k_dim // groups, n_dim)
    out = (out * quantum[..., :, None, :]).reshape(*lead, k_dim, n_dim)
    return out.astype(dtype)


def dequantize_master_tree(tree, m: jax.Array | int, dtype=jnp.bfloat16):
    """Dequantize every stacked-master leaf of a pytree at width m."""

    def visit(leaf):
        if is_master_leaf(leaf):
            return dequantize_stacked(leaf, m, dtype=dtype)
        return leaf

    return jax.tree_util.tree_map(visit, tree, is_leaf=is_master_leaf)


def pack_tree(params, group_size: int = sefp.GROUP_SIZE, group_axis: int = 0,
              min_size: int = 4096,
              exclude_substrings=sefp.DEFAULT_EXCLUDE) -> Any:
    """Pack every eligible weight of a pytree; ineligible leaves pass through
    unchanged (they stay in their original dtype)."""

    def visit(path, leaf):
        if not sefp._is_eligible(path, leaf, min_size, exclude_substrings):
            return leaf
        ax = group_axis if leaf.shape[group_axis] % group_size == 0 else (
            -1 if leaf.shape[-1] % group_size == 0 else None)
        if ax is None:
            return leaf
        return pack(leaf, group_size=group_size, group_axis=ax)

    return jax.tree_util.tree_map_with_path(visit, params)


def dequantize_tree(packed_params, m: jax.Array | int, dtype=jnp.bfloat16):
    """Materialize a full pytree at precision m from a packed pytree."""

    def visit(leaf):
        if isinstance(leaf, PackedSEFP):
            return dequantize(leaf, m, dtype=dtype)
        return leaf

    return jax.tree_util.tree_map(
        visit, packed_params,
        is_leaf=lambda x: isinstance(x, PackedSEFP))


def tree_nbytes(packed_params) -> dict:
    """Byte and parameter accounting for a (possibly partially) packed tree.
    Handles PackedSEFP leaves and stacked-master dict leaves alike; packed
    parameter counts let callers derive the streamed footprint at any width
    from ``stream_bits_per_param`` without re-deriving the layout."""
    packed_b = 0
    raw_b = 0
    packed_params_n = 0
    raw_params_n = 0

    def visit(leaf):
        nonlocal packed_b, raw_b, packed_params_n, raw_params_n
        if isinstance(leaf, PackedSEFP):
            packed_b += leaf.nbytes_packed
            packed_params_n += int(leaf.mag.size)
        elif is_master_leaf(leaf):
            packed_b += int(leaf["mag"].nbytes + leaf["sign"].nbytes
                            + leaf["exp"].nbytes)
            packed_params_n += int(leaf["mag"].size)
        elif hasattr(leaf, "nbytes"):
            raw_b += int(leaf.nbytes)
            raw_params_n += int(leaf.size)
        return leaf

    jax.tree_util.tree_map(
        visit, packed_params,
        is_leaf=lambda x: isinstance(x, PackedSEFP) or is_master_leaf(x))
    return {"packed_bytes": packed_b, "raw_bytes": raw_b,
            "total_bytes": packed_b + raw_b,
            "packed_params": packed_params_n, "raw_params": raw_params_n,
            "n_params": packed_params_n + raw_params_n}
