"""BPS — Exploitation-Exploration Bit-Width Path Search (paper Eq. 5-9).

A UCB-style bandit over the bit-width set B = {E5M8 .. E5M3}:

    Score(b) = lambda * sqrt(ln t / t_b) - L_b

where t is the global batch counter, t_b the number of times b was selected
and L_b the latest observed training loss at b.  Bit-widths never tried have
infinite score (must-explore).  As t grows the exploration term vanishes and
the path converges to the higher bit-widths (smaller loss), matching the
paper's convergence argument (Eq. 6-9).

The controller state is a small pytree of replicated scalars and lives
*inside* the jitted train step: selection, loss bookkeeping and the counter
updates are all traced, so BPS adds no host round-trip and no recompilation
(the selected mantissa width is a dynamic scalar — see core/sefp.py).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.sefp import MANTISSA_WIDTHS


class BPSState(NamedTuple):
    t: jax.Array        # int32   — global batch counter (selections made)
    t_b: jax.Array      # int32[B] — per-bit-width selection counts
    loss_b: jax.Array   # float32[B] — latest (or EMA) loss per bit-width


def init(num_widths: int = len(MANTISSA_WIDTHS)) -> BPSState:
    return BPSState(
        t=jnp.zeros((), jnp.int32),
        t_b=jnp.zeros((num_widths,), jnp.int32),
        loss_b=jnp.zeros((num_widths,), jnp.float32),
    )


def scores(state: BPSState, lam: float) -> jax.Array:
    """Paper Eq. 5.  Unvisited arms get +inf (forced exploration)."""
    t = jnp.maximum(state.t, 1).astype(jnp.float32)
    t_b = state.t_b.astype(jnp.float32)
    explore = lam * jnp.sqrt(jnp.log(t) / jnp.maximum(t_b, 1.0))
    s = explore - state.loss_b
    return jnp.where(state.t_b == 0, jnp.inf, s)


def select(state: BPSState, lam: float = 5.0,
           widths: Sequence[int] = MANTISSA_WIDTHS) -> tuple[jax.Array, jax.Array]:
    """Pick the arm with the highest score.  Returns (arm_index int32,
    mantissa_width int32).  Ties break toward the first (highest) width."""
    idx = jnp.argmax(scores(state, lam)).astype(jnp.int32)
    m = jnp.asarray(widths, jnp.int32)[idx]
    return idx, m


def update(state: BPSState, arm: jax.Array, loss: jax.Array,
           loss_ema: float = 1.0) -> BPSState:
    """Record the observed loss for the selected arm and bump counters.
    loss_ema=1.0 reproduces the paper's 'real-time loss' (latest value)."""
    onehot = jax.nn.one_hot(arm, state.t_b.shape[0], dtype=jnp.int32)
    loss = loss.astype(jnp.float32)
    old = state.loss_b[arm]
    seen = state.t_b[arm] > 0
    new_val = jnp.where(seen, loss_ema * loss + (1.0 - loss_ema) * old, loss)
    loss_b = state.loss_b.at[arm].set(new_val)
    return BPSState(
        t=state.t + 1,
        t_b=state.t_b + onehot,
        loss_b=loss_b,
    )


def uniform_select(step: jax.Array,
                   widths: Sequence[int] = MANTISSA_WIDTHS) -> tuple[jax.Array, jax.Array]:
    """The paper's 'uniform sampling' baseline (Fig. 3): cycle through B."""
    idx = (step % len(widths)).astype(jnp.int32)
    m = jnp.asarray(widths, jnp.int32)[idx]
    return idx, m
