"""LAA — Low-Precision Asynchronous Accumulation (paper Algorithm 1, Eq. 16-18).

At ultra-low bit-widths the SEFP quantization error is a sawtooth in each
weight (period and amplitude 1/2^m, Appendix A), which injects a zero-mean
residual perturbation Y into the gradients (paper Eq. 14-15).  LAA
accumulates gradients produced by ultra-low-bit batches — *asynchronously*,
i.e. across non-contiguous batches, the buffer survives interleaved
high-precision steps — and releases one delayed update every N such batches,
shrinking the relative perturbation like 1/sqrt(N) (Eq. 17).

Implemented as a pure state machine usable inside a jitted step:

    effective_grad, do_update, new_state = laa.step(state, grads, is_low)

- ``is_low`` False  -> effective_grad = grads, do_update = True (standard path)
- ``is_low`` True   -> grads go into the buffer; do_update is True only on the
  N-th accumulated low-bit batch, and then effective_grad is the buffered
  *sum* (Eq. 18 updates with the summed gradient).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class LAAState(NamedTuple):
    buf: Any            # pytree like grads (fp32) — the asynchronous accumulator
    count: jax.Array    # int32 — low-bit batches accumulated since last release


def init(grad_shapes: Any, dtype=jnp.float32) -> LAAState:
    buf = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, dtype), grad_shapes)
    return LAAState(buf=buf, count=jnp.zeros((), jnp.int32))


def step(state: LAAState, grads: Any, is_low: jax.Array, n_delay: int,
         average: bool = False):
    """One LAA transition.  All branches are data-dependent `where`s so the
    function stays a single traced program (no recompiles when BPS switches
    precision).

    Returns (effective_grad, do_update: bool[], new_state).
    """
    is_low = jnp.asarray(is_low, jnp.bool_)
    count1 = jnp.where(is_low, state.count + 1, state.count)
    release = jnp.logical_and(is_low, count1 >= n_delay)
    do_update = jnp.logical_or(jnp.logical_not(is_low), release)

    lowf = is_low.astype(jnp.float32)
    relf = release.astype(jnp.float32)

    def upd(buf, g):
        g32 = g.astype(buf.dtype)
        acc = buf + lowf * g32           # accumulate only on low-bit batches
        return acc * (1.0 - relf)        # clear on release

    def eff(buf, g):
        g32 = g.astype(jnp.float32)
        acc = buf + g32                   # buffered sum incl. this batch
        scale = jnp.where(
            jnp.asarray(average, jnp.bool_),
            1.0 / jnp.maximum(count1.astype(jnp.float32), 1.0), 1.0)
        low_grad = acc * scale
        return jnp.where(relf > 0, low_grad, jnp.where(lowf > 0, 0.0, g32))

    effective = jax.tree_util.tree_map(eff, state.buf, grads)
    new_buf = jax.tree_util.tree_map(upd, state.buf, grads)
    new_count = jnp.where(release, 0, count1)
    return effective, do_update, LAAState(buf=new_buf, count=new_count)
