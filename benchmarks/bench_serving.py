"""Serving-path perf: continuous batching vs lockstep on a staggered-arrival,
ragged-length, mixed-precision-class workload.

The lockstep engine serves equal batches to the longest member's max_new
and admits nothing until the whole batch finishes; the continuous scheduler
(repro/serve/scheduler.py) admits each request into a free slot the step it
arrives and retires it the step it finishes, so no step is spent padding a
finished or not-yet-arrived request.  Four serving modes over the SAME
workload and weights:

  lockstep       sequential fixed-size batches via engine.generate; a batch
                 launches when the engine is idle and >= 1 request is
                 pending, takes up to B pending requests (rows padded with
                 repeats when fewer), runs max(member max_new) steps at the
                 per-step max of member wanted widths.
  continuous     ContinuousScheduler, max-width policy (every active slot
                 commits every step, width = max wanted — the same quality
                 semantics as the lockstep batch).
  continuous_rr  ContinuousScheduler, width-rr policy (width groups served
                 round-robin AT their wanted width with aging/fairness).
  heterogeneous  ContinuousScheduler, heterogeneous policy (DESIGN.md §14):
                 every active slot commits EVERY step at its own wanted
                 width through the fused per-row-width decode step — exact
                 per-class fidelity (like width-rr) at commit rate 1.0
                 (like max-width), each request bitwise its lockstep run.

The workload cycles over FOUR precision classes (widths 8/6/4/3), so the
rotation tax the heterogeneous step removes is structural: width-rr serves
one width group per step and pays ~4x the steps.

Metrics per mode: useful tokens/sec (wall), total decode steps, p50/p95
request latency in *scheduler steps* (deterministic, hardware-independent:
submit -> finish on a shared step clock where idle gaps tick once); plus
occupancy / commit rate / per-width step counts / per-width COMMITTED token
counts (``tokens_by_width``) / starvation for the continuous modes.  The
heterogeneous entry also replays a deterministic sample of its finished
requests on a single-width oracle (``oracle_bitwise`` must be True — a
numerics drift in the fused per-row step fails the bench and ``--check``,
as does heterogeneous tokens/s falling under width-rr's, commit rate under
1.0, or any starvation).  The oracle engine is recorded per entry
(``oracle_engine``): smoke replays on the lockstep ``generate`` path;
full mode replays the request SOLO through the scalar (single-width)
continuous step at the same slot count, because XLA CPU matmul numerics
are batch-shape-dependent — at d512 a decode row computed in a B=8 batch
is not bitwise the same row computed at B=1 (measured; at the smoke and
tier-1 config sizes they coincide).  The bitwise contract is therefore
stated at MATCHED batch shapes: per-row hetero == the scalar step at
that row's width, same B — which the solo scalar replay checks exactly,
prefill chunking and paged decode included.  ``speedup_continuous_vs_lockstep`` is the
headline:
continuous wins exactly by backfilling the arrival gaps and the ragged
tail.  Absolute numbers are CPU-relative (DESIGN.md §9) — the *structure*
(steps saved, occupancy) is what transfers.

``--faults`` additionally runs the resilience scenarios (DESIGN.md §12)
through the fault-injection harness (repro/serve/faults.py) and records a
``faults`` section: an arrival flood against the slo-degrade policy
(degraded-mode tokens/s, width-downshift counts, SLO-hold rate, floor
violations), NaN-logits and cache-corruption quarantine (co-resident
streams must be bitwise equal to a no-fault run), and a stall driving the
latency-EWMA trigger.  Every scenario runs under a drain watchdog and a
set of hard checks — a hang, a crossed min_width floor, a perturbed
co-resident, or a broken lockstep-oracle replay fails the bench (and the
CI leg that runs it).

``--long-context`` additionally runs the paged-KV capacity scenario
(DESIGN.md §13) and records a ``long_context`` section: a mixed workload
of long-document m=4 requests sharing one document prefix beside short
m=8 chat requests, all under a FIXED page budget.  The headline is
``concurrency_per_byte_vs_dense``: how many requests the paged scheduler
holds concurrently vs how many dense ``max_len`` cache rows the same KV
byte budget could back (``>= 2x`` is the acceptance bar).  The section
also reports page occupancy, the prefix-cache hit rate (must be > 0 —
the long documents share pages), chunked-prefill counts, and
``decode_stall_steps`` (must be 0: a long prefill interleaves with the
decode clock, it never stalls it).  ``--check`` hard-fails on zero reuse
hits, any decode stall (in the long-context run AND the staggered
continuous modes), or a concurrency ratio under 2x.

``--speculative`` additionally runs the self-speculative decode scenario
(DESIGN.md §15) and records a ``speculative`` section: the staggered
workload served entirely at m=8 twice — plain continuous vs draft–verify
speculative (the packed master drafting for itself at a low SEFP width,
verifying all k+1 positions in one batched step at m=8).  The greedy
speculative run must be token-identical to the plain baseline, the
acceptance accounting must balance exactly (drafted == accepted + wasted,
per draft width and in total, and per finished request), a sample replays
on the lockstep oracle, and the smoke run must clear the
``SPEC_SPEEDUP_BAR`` tokens/s ratio over the plain baseline (the win is
structural on the dispatch-bound smoke size: one host sync per macro-step
instead of per token).  ``--check`` hard-fails on oracle divergence, an
accounting mismatch, or a failed check.

``--telemetry`` additionally runs the observability scenario (DESIGN.md
§16) and records a ``telemetry`` section: ONE mixed workload — self-
speculative m=8 generation beside plain m=4 understanding under the
heterogeneous policy with slo-degrade composed, plus an arrival flood
that forces a queue-pressure escalation — served twice, identically
except for telemetry (full ``Telemetry`` vs the ``NullTelemetry``
default).  The instrumented run must produce a valid Prometheus text
exposition, a structurally valid Chrome trace (per-track timestamp
ordering, matched B/E request spans) whose per-request width timeline
reconciles EXACTLY with ``FinishedRequest.width_counts()`` and the
scheduler's ``tokens_by_width``, and per-precision-class TTFT / inter-
token-latency histograms; both runs must produce token-identical output
(telemetry is passive), and the overhead contract is a hard bar:
tokens/s with telemetry on must stay >= ``TEL_OVERHEAD_BAR`` (0.95) x
telemetry off (warmup + best-of-3 walls on both sides).  The Chrome
trace is written to ``--trace-out`` — open it at ui.perfetto.dev — and
CI uploads it as an artifact on every PR.

``--check`` validates any JSON from schema v3 up: sections a run did not
produce (``faults`` / ``long_context`` / ``speculative`` / ``telemetry``
null or absent, or a pre-v4 document without the heterogeneous mode) are
skipped, not errors — only what a run recorded is held to its bars.

Writes BENCH_serving.json at the repo root.  CI runs ``--smoke`` then
``--check`` and uploads the JSON, extending the serving perf trajectory;
further CI legs run ``--faults --smoke --check``,
``--long-context --smoke --check``, ``--speculative --smoke --check``
and ``--telemetry --smoke --check``.

    PYTHONPATH=src python benchmarks/bench_serving.py [--smoke] [--out PATH]
    PYTHONPATH=src python benchmarks/bench_serving.py --faults [--smoke]
    PYTHONPATH=src python benchmarks/bench_serving.py --long-context [--smoke]
    PYTHONPATH=src python benchmarks/bench_serving.py --speculative [--smoke]
    PYTHONPATH=src python benchmarks/bench_serving.py --telemetry [--smoke]
    PYTHONPATH=src python benchmarks/bench_serving.py --check PATH
"""

from __future__ import annotations

import argparse
import json
import sys
import time

SCHEMA_VERSION = 6
# oldest schema --check still accepts: optional sections (the heterogeneous
# mode entry, faults, long_context, speculative) are validated only when the
# checked document actually produced them, so older perf-trajectory JSONs
# stay checkable after a schema grows a new section
MIN_SCHEMA_VERSION = 3
MODES = ("lockstep", "continuous", "continuous_rr", "heterogeneous")
# mode entries that older schemas may lack entirely (v3 predates the
# heterogeneous fused per-row step)
OPTIONAL_MODES = ("heterogeneous",)
# speculative decode must beat the plain m=8 continuous baseline by this
# factor on the smoke workload (dispatch-bound: the macro-step's one host
# sync per ~k committed tokens is the structural win being pinned)
SPEC_SPEEDUP_BAR = 1.3
# telemetry overhead contract (DESIGN.md §16): tokens/s with full
# Telemetry recording on must stay >= this fraction of the NullTelemetry
# run over the SAME workload (warmup + best-of-3 walls on both sides)
TEL_OVERHEAD_BAR = 0.95
FAULT_SCENARIOS = ("flood", "nan_slot", "cache_corruption", "stall")
# per-token service budget (scheduler steps) the flood scenario must hold
SLO_STEPS_PER_TOKEN = 1.5
# serving KV page size (must divide max_len; scheduler default)
PAGE_SIZE = 16


# ---------------------------------------------------------------------------
# schema (the --check contract; keep in sync with emit())
# ---------------------------------------------------------------------------

def check_schema(doc: dict) -> list:
    errs = []

    def need(d, key, typ, where):
        if key not in d:
            errs.append(f"{where}: missing key {key!r}")
            return None
        if not isinstance(d[key], typ):
            errs.append(f"{where}.{key}: expected {typ}, got "
                        f"{type(d[key]).__name__}")
        return d[key]

    ver = need(doc, "schema_version", int, "$")
    if isinstance(ver, int) and not (MIN_SCHEMA_VERSION <= ver
                                     <= SCHEMA_VERSION):
        errs.append(f"$.schema_version: {ver} outside supported range "
                    f"[{MIN_SCHEMA_VERSION}, {SCHEMA_VERSION}]")
    need(doc, "bench", str, "$")
    need(doc, "mode", str, "$")
    cfg = need(doc, "config", dict, "$") or {}
    for k in ("name", "family", "n_layers", "d_model", "vocab_size",
              "slots"):
        need(cfg, k, (int, str), "$.config")
    wl = need(doc, "workload", dict, "$") or {}
    for k in ("requests", "prompt_len", "max_new_min", "max_new_max",
              "arrival_gap", "useful_tokens"):
        need(wl, k, int, "$.workload")
    need(wl, "classes", dict, "$.workload")
    modes = need(doc, "modes", dict, "$") or {}
    for mode in MODES:
        if mode in OPTIONAL_MODES and mode not in modes:
            continue  # section not produced by that (older) run
        entry = need(modes, mode, dict, "$.modes") or {}
        for k in ("tokens_per_sec", "wall_seconds", "latency_steps_p50",
                  "latency_steps_p95"):
            need(entry, k, (int, float), f"$.modes.{mode}")
        need(entry, "total_steps", int, f"$.modes.{mode}")
        if mode != "lockstep":
            for k in ("occupancy", "commit_rate"):
                need(entry, k, (int, float), f"$.modes.{mode}")
            need(entry, "width_steps", dict, f"$.modes.{mode}")
            need(entry, "tokens_by_width", dict, f"$.modes.{mode}")
            need(entry, "starvation", dict, f"$.modes.{mode}")
            # chunked prefill must never stall the decode clock — a
            # regression here fails --check even outside --long-context
            stalls = need(entry, "decode_stall_steps", int,
                          f"$.modes.{mode}")
            if stalls:
                errs.append(f"$.modes.{mode}.decode_stall_steps: "
                            f"{stalls} != 0")
    # the heterogeneous mode's structural claims are hard --check bars:
    # everyone commits every step, nobody starves, the fused per-row step
    # is bitwise the single-width oracle (lockstep generate in smoke, the
    # shape-matched solo scalar-step replay in full — module docstring),
    # and removing the width-rr rotation must not cost throughput
    het = modes.get("heterogeneous") or {}
    if het:
        if het.get("commit_rate") != 1.0:
            errs.append(f"$.modes.heterogeneous.commit_rate: "
                        f"{het.get('commit_rate')} != 1.0")
        if het.get("starvation"):
            errs.append(f"$.modes.heterogeneous.starvation: "
                        f"{het.get('starvation')} != {{}}")
        if het.get("oracle_bitwise") is not True:
            errs.append("$.modes.heterogeneous.oracle_bitwise: "
                        f"{het.get('oracle_bitwise')!r} is not True")
        if het.get("oracle_engine") not in ("lockstep", "scalar-step"):
            errs.append("$.modes.heterogeneous.oracle_engine: "
                        f"{het.get('oracle_engine')!r} not in "
                        "('lockstep', 'scalar-step')")
        rr = modes.get("continuous_rr") or {}
        if rr and het.get("tokens_per_sec", 0) < rr.get("tokens_per_sec", 0):
            errs.append(
                f"$.modes.heterogeneous.tokens_per_sec: "
                f"{het.get('tokens_per_sec')} < continuous_rr's "
                f"{rr.get('tokens_per_sec')}")
    need(doc, "speedup_continuous_vs_lockstep", (int, float), "$")
    need(doc, "steps_saved_vs_lockstep", int, "$")
    # faults: null when the run skipped --faults; older JSONs may lack the
    # key entirely — absent means "not produced", never an error
    if doc.get("faults") is not None:
        fl = doc["faults"]
        if not isinstance(fl, dict):
            errs.append(f"$.faults: expected dict, got "
                        f"{type(fl).__name__}")
            return errs
        need(fl, "slo_steps_per_token", (int, float), "$.faults")
        for scen in FAULT_SCENARIOS:
            need(fl, scen, dict, "$.faults")
        fld = fl.get("flood") or {}
        for k in ("slo_hold_rate", "tokens_per_sec_degraded",
                  "p95_service_steps_per_token"):
            need(fld, k, (int, float), "$.faults.flood")
        for k in ("downshifted_slot_steps", "escalations",
                  "floor_violations", "oracle_checked"):
            need(fld, k, int, "$.faults.flood")
        checks = need(fl, "checks", dict, "$.faults") or {}
        for name, ok in checks.items():
            if ok is not True:
                errs.append(f"$.faults.checks.{name}: failed ({ok!r})")
    # long_context: same optional-section rule as faults
    if doc.get("long_context") is not None:
        lc = doc["long_context"]
        if not isinstance(lc, dict):
            errs.append(f"$.long_context: expected dict, got "
                        f"{type(lc).__name__}")
            return errs
        for k in ("page_size", "n_pages", "max_len", "bytes_per_page",
                  "kv_budget_bytes", "peak_concurrent_requests",
                  "dense_slots_same_budget", "prefix_hits",
                  "reused_pages", "decode_stall_steps", "prefill_chunks",
                  "page_high_water"):
            need(lc, k, int, "$.long_context")
        for k in ("concurrency_per_byte_vs_dense", "page_occupancy",
                  "prefix_hit_rate", "tokens_per_sec"):
            need(lc, k, (int, float), "$.long_context")
        need(lc, "workload", dict, "$.long_context")
        if lc.get("prefix_hits", 0) <= 0:
            errs.append("$.long_context.prefix_hits: zero prefix reuse")
        if lc.get("decode_stall_steps", 1) != 0:
            errs.append("$.long_context.decode_stall_steps: "
                        f"{lc.get('decode_stall_steps')} != 0")
        if lc.get("concurrency_per_byte_vs_dense", 0) < 2.0:
            errs.append("$.long_context.concurrency_per_byte_vs_dense: "
                        f"{lc.get('concurrency_per_byte_vs_dense')} < 2.0")
        checks = need(lc, "checks", dict, "$.long_context") or {}
        for name, ok in checks.items():
            if ok is not True:
                errs.append(f"$.long_context.checks.{name}: "
                            f"failed ({ok!r})")
    # speculative: same optional-section rule; when present the acceptance
    # accounting must balance exactly (drafted == accepted + wasted, per
    # width and in total) and the greedy speculative run must be
    # token-identical to the plain m=8 baseline (oracle divergence or an
    # accounting mismatch fails --check)
    if doc.get("speculative") is not None:
        sp = doc["speculative"]
        if not isinstance(sp, dict):
            errs.append(f"$.speculative: expected dict, got "
                        f"{type(sp).__name__}")
            return errs
        for k in ("k", "verify_width", "macro_steps", "drafted",
                  "accepted", "wasted", "bonus_tokens",
                  "committed_tokens", "oracle_checked"):
            need(sp, k, int, "$.speculative")
        for k in ("acceptance_rate", "speedup_vs_plain"):
            need(sp, k, (int, float), "$.speculative")
        need(sp, "estimator", str, "$.speculative")
        plain = need(sp, "plain", dict, "$.speculative") or {}
        spec = need(sp, "spec", dict, "$.speculative") or {}
        for side, entry in (("plain", plain), ("spec", spec)):
            for k in ("tokens_per_sec", "wall_seconds"):
                need(entry, k, (int, float), f"$.speculative.{side}")
            need(entry, "total_steps", int, f"$.speculative.{side}")
        if (sp.get("drafted", 0)
                != sp.get("accepted", 0) + sp.get("wasted", 0)):
            errs.append(
                f"$.speculative: acceptance accounting mismatch — "
                f"drafted {sp.get('drafted')} != accepted "
                f"{sp.get('accepted')} + wasted {sp.get('wasted')}")
        by_width = need(sp, "by_width", dict, "$.speculative") or {}
        for w, row in by_width.items():
            if not isinstance(row, dict):
                errs.append(f"$.speculative.by_width.{w}: expected dict")
                continue
            if (row.get("drafted", 0)
                    != row.get("accepted", 0) + row.get("wasted", 0)):
                errs.append(
                    f"$.speculative.by_width.{w}: drafted "
                    f"{row.get('drafted')} != accepted + wasted")
        checks = need(sp, "checks", dict, "$.speculative") or {}
        for name, ok in checks.items():
            if ok is not True:
                errs.append(f"$.speculative.checks.{name}: failed ({ok!r})")
    # telemetry: same optional-section rule; when present the instrumented
    # run's trace must have reconciled with the scheduler's accounting and
    # the overhead bar must have held (all recorded as checks), and the
    # exported width tallies must agree with each other in the document
    if doc.get("telemetry") is not None:
        tl = doc["telemetry"]
        if not isinstance(tl, dict):
            errs.append(f"$.telemetry: expected dict, got "
                        f"{type(tl).__name__}")
            return errs
        for k in ("requests", "useful_tokens", "trace_events",
                  "trace_dropped", "exposition_lines", "spec_drafted",
                  "slo_escalations"):
            need(tl, k, int, "$.telemetry")
        for k in ("tokens_per_sec_on", "tokens_per_sec_off",
                  "overhead_ratio", "overhead_bar"):
            need(tl, k, (int, float), "$.telemetry")
        need(tl, "trace_path", str, "$.telemetry")
        for k in ("ttft_counts", "itl_counts", "tokens_by_width",
                  "trace_token_widths"):
            need(tl, k, dict, "$.telemetry")
        if (isinstance(tl.get("tokens_by_width"), dict)
                and tl.get("tokens_by_width")
                != tl.get("trace_token_widths")):
            errs.append(
                f"$.telemetry: trace_token_widths "
                f"{tl.get('trace_token_widths')} != tokens_by_width "
                f"{tl.get('tokens_by_width')} — the per-request width "
                f"timeline must reconcile exactly")
        checks = need(tl, "checks", dict, "$.telemetry") or {}
        for name, ok in checks.items():
            if ok is not True:
                errs.append(f"$.telemetry.checks.{name}: failed ({ok!r})")
    return errs


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------

def make_workload(n_requests: int, prompt_len: int, max_new_lo: int,
                  max_new_hi: int, arrival_gap: int, vocab: int,
                  classes: dict, seed: int = 0) -> list:
    """Staggered arrivals (one request every ``arrival_gap`` steps), ragged
    max_new, round-robin over the precision classes."""
    import numpy as np

    rng = np.random.default_rng(seed)
    names = sorted(classes)
    reqs = []
    for i in range(n_requests):
        reqs.append({
            "prompt": rng.integers(0, vocab, (prompt_len,)).astype(np.int32),
            "max_new": int(rng.integers(max_new_lo, max_new_hi + 1)),
            "request_class": names[i % len(names)],
            "arrival": i * arrival_gap,
            "seed": i,
        })
    return reqs


def _pctl(xs, q):
    import numpy as np
    return float(np.percentile(np.asarray(xs, np.float64), q))


# ---------------------------------------------------------------------------
# lockstep baseline driver
# ---------------------------------------------------------------------------

def run_lockstep(server, reqs, batch: int, policy) -> dict:
    """Sequential fixed-size lockstep batches over the arrival stream.  A
    batch launches when the engine is idle and something is pending, takes
    up to ``batch`` pending requests (rows padded with repeats of the last
    one — the fixed shape is what keeps ONE compiled executable), runs to
    the longest member's max_new at the per-step max of member wanted
    widths, and only then admits again.  Latency is on the same step clock
    the continuous modes use (idle gaps tick once)."""
    import numpy as np

    latencies = []
    useful = 0
    clock = 0
    steps = 0
    i = 0
    t0 = time.perf_counter()
    while i < len(reqs):
        pend = [r for r in reqs[i:] if r["arrival"] <= clock]
        if not pend:
            clock += 1  # idle: nothing has arrived yet
            continue
        members = reqs[i:i + min(batch, len(pend))]
        i += len(members)
        max_new = max(r["max_new"] for r in members)
        # per-step width: the max any member wants at that step (the
        # lockstep analogue of the max-width policy)
        scheds = [policy.request_schedule(max_new, r["request_class"])
                  for r in members]
        sched = [max(s[t] for s in scheds) for t in range(max_new)]
        rows = [r["prompt"] for r in members]
        while len(rows) < batch:  # fixed shape: pad with repeats
            rows.append(members[-1]["prompt"])
        server.generate(np.stack(rows), max_new=max_new,
                        precision_schedule=sched)
        clock += max_new
        steps += max_new
        for r in members:
            useful += r["max_new"]  # padded tail tokens are discarded
            latencies.append(clock - r["arrival"])
    wall = time.perf_counter() - t0
    return {
        "tokens_per_sec": useful / max(wall, 1e-9),
        "wall_seconds": wall,
        "total_steps": steps,
        "latency_steps_p50": _pctl(latencies, 50),
        "latency_steps_p95": _pctl(latencies, 95),
    }, useful


# ---------------------------------------------------------------------------
# continuous driver
# ---------------------------------------------------------------------------

def run_continuous(server, reqs, slots: int, width_policy: str,
                   oracle: str | None = None, oracle_cap: int = 6) -> dict:
    sched = server.continuous(slots=slots, width_policy=width_policy)
    t0 = time.perf_counter()
    done = sched.replay(reqs)  # the same arrival-clock loop the CLI uses
    wall = time.perf_counter() - t0
    stats = sched.stats
    useful = sum(len(fr.tokens) for fr in done.values())
    lat = [fr.finish_step - fr.submit_step for fr in done.values()]
    entry = {
        "tokens_per_sec": useful / max(wall, 1e-9),
        "wall_seconds": wall,
        "total_steps": stats["steps"],
        "latency_steps_p50": _pctl(lat, 50),
        "latency_steps_p95": _pctl(lat, 95),
        "occupancy": stats["occupancy"],
        "commit_rate": stats["commit_rate"],
        "width_steps": {str(k): v for k, v in stats["width_steps"].items()},
        "tokens_by_width": {str(k): v
                            for k, v in stats["tokens_by_width"].items()},
        "starvation": {str(k): v for k, v in stats["starvation"].items()},
        "decode_stall_steps": stats["decode_stall_steps"],
        "prefill_chunks": stats["prefill_chunks"],
        "pages_high_water": (stats["pages"] or {}).get("high_water"),
    }
    if oracle:
        # replay a deterministic sample on the single-width oracle (replay()
        # submits in arrival order, so sorted rids line up with the
        # arrival-sorted workload); capped because each distinct max_new
        # compiles a new lockstep scan length.  "lockstep" replays on the
        # fused generate scan (bitwise at smoke/tier-1 sizes);
        # "scalar-step" replays SOLO through the scalar continuous step at
        # the same slot count — the batch-shape-matched oracle (module
        # docstring: XLA CPU matmuls are not batch-shape-invariant).
        ordered = sorted(reqs, key=lambda r: int(r.get("arrival", 0)))
        pairs = list(zip(sorted(done), ordered))[:oracle_cap]
        entry["oracle_checked"] = len(pairs)
        entry["oracle_engine"] = oracle
        if oracle == "lockstep":
            entry["oracle_bitwise"] = all(
                _oracle_ok(server, done[rid], r["prompt"])
                for rid, r in pairs)
        else:
            entry["oracle_bitwise"] = all(
                _oracle_ok_scalar_step(server, done[rid], r, slots)
                for rid, r in pairs)
    return entry, useful


# ---------------------------------------------------------------------------
# long-context paged-KV scenario (--long-context; DESIGN.md §13)
# ---------------------------------------------------------------------------

def run_long_context(artifact, policy, smoke: bool) -> dict:
    """Mixed long-document / short-chat workload under a fixed KV page
    budget.  The long documents share one document prefix (warmed into
    the prefix cache by a retired priming request), decode at m=4 while
    the short chat decodes at m=8, and every prompt prefills in chunks
    interleaved with the decode clock.  The headline ratio compares the
    peak number of concurrently-resident requests against the number of
    dense max_len cache rows the SAME byte budget could back."""
    import numpy as np

    ps = PAGE_SIZE
    if smoke:
        max_len, doc_len, q_len = 128, 64, 16
        n_long, long_new = 3, 8
        n_short, short_plen, short_new = 6, 16, 8
        n_pages, chunk = 25, 16      # 24 usable pages + the null page
    else:
        max_len, doc_len, q_len = 256, 160, 16
        n_long, long_new = 4, 16
        n_short, short_plen, short_new = 12, 16, 12
        n_pages, chunk = 49, 32      # 48 usable pages + the null page
    slots = n_long + n_short
    server = artifact.server(policy, max_len=max_len)
    vocab = server.cfg.vocab_size
    rng = np.random.default_rng(42)
    doc = rng.integers(0, vocab, (doc_len,)).astype(np.int32)
    longs = [np.concatenate(
        [doc, rng.integers(0, vocab, (q_len,)).astype(np.int32)])
        for _ in range(n_long)]
    shorts = [rng.integers(0, vocab, (short_plen,)).astype(np.int32)
              for _ in range(n_short)]

    sched = server.continuous(slots=slots, page_size=ps, n_pages=n_pages,
                              prefill_chunk=chunk, width_policy="width-rr")
    bytes_per_page = sched.memory_report()["kv_cache"]["bytes_per_page"]
    budget_pages = n_pages - 1        # page 0 is the null page
    budget_bytes = budget_pages * bytes_per_page
    # the same byte budget as dense per-slot rows of max_len positions
    dense_bound = budget_pages // (max_len // ps)

    # prime: serve the bare document once so its full prompt pages sit in
    # the prefix cache when the measured workload arrives (published pages
    # outlive the request that produced them)
    sched.submit(doc, max_new=1, request_class="understanding", seed=99)
    sched.drain(max_steps=2_000)

    # interleave the classes in FIFO submit order: long, short, short, ...
    order = [(p, long_new, "understanding") for p in longs] \
        + [(p, short_new, "generation") for p in shorts]
    stride = 1 + n_short // max(n_long, 1)
    order = [order[i] for g in range(stride)
             for i in range(g, len(order), stride)]
    rids = [sched.submit(p, max_new=mn, request_class=cls, seed=i)
            for i, (p, mn, cls) in enumerate(order)]

    peak = 0
    n = 0
    t0 = time.perf_counter()
    while sched.pending or sched.active:
        sched.step()
        peak = max(peak, sched.active)
        n += 1
        if n > 2_000:
            raise RuntimeError("long-context drain exceeded watchdog")
    wall = time.perf_counter() - t0
    done = sched.drain()
    stats = sched.stats
    pg = stats["pages"]
    pc = pg["prefix_cache"]
    useful = sum(len(done[r].tokens) for r in rids)
    lat = [done[r].finish_step - done[r].submit_step for r in rids]
    ratio = peak / max(dense_bound, 1)
    hit_rate = pc["hits"] / max(pc["hits"] + pc["misses"], 1)
    checks = {
        "prefix_reuse": pc["hits"] > 0 and pg["reused_pages"] > 0,
        "no_decode_stalls": stats["decode_stall_steps"] == 0,
        "concurrency_2x_vs_dense": ratio >= 2.0,
        "within_page_budget": pg["high_water"] <= budget_pages,
        "chunked_prefill_ran": stats["prefill_chunks"] > 0,
        "all_finished_ok": all(done[r].status == "ok" for r in rids),
    }
    return {
        "page_size": ps,
        "n_pages": n_pages,
        "max_len": max_len,
        "bytes_per_page": int(bytes_per_page),
        "kv_budget_bytes": int(budget_bytes),
        "workload": {
            "n_long": n_long, "doc_len": doc_len,
            "long_prompt_len": doc_len + q_len, "long_max_new": long_new,
            "long_width": 4, "n_short": n_short,
            "short_prompt_len": short_plen, "short_max_new": short_new,
            "short_width": 8},
        "peak_concurrent_requests": int(peak),
        "dense_slots_same_budget": int(dense_bound),
        "concurrency_per_byte_vs_dense": ratio,
        "page_high_water": int(pg["high_water"]),
        "page_occupancy": pg["high_water"] / budget_pages,
        "prefix_hits": int(pc["hits"]),
        "prefix_misses": int(pc["misses"]),
        "prefix_hit_rate": hit_rate,
        "reused_pages": int(pg["reused_pages"]),
        "page_blocked_admissions": int(pg["page_blocked_admissions"]),
        "prefill_chunks": int(stats["prefill_chunks"]),
        "prefill_only_steps": int(stats["prefill_only_steps"]),
        "decode_stall_steps": int(stats["decode_stall_steps"]),
        "total_steps": int(stats["steps"]),
        "tokens_per_sec": useful / max(wall, 1e-9),
        "wall_seconds": wall,
        "latency_steps_p50": _pctl(lat, 50),
        "latency_steps_p95": _pctl(lat, 95),
        "checks": checks,
    }


# ---------------------------------------------------------------------------
# fault-injection scenarios (--faults; DESIGN.md §12)
# ---------------------------------------------------------------------------

def _oracle_ok(server, fr, prompt) -> bool:
    """Bitwise lockstep-oracle replay of one finished request."""
    import numpy as np

    sched, pm = fr.oracle_schedule()
    solo = server.generate(np.asarray(prompt)[None], max_new=len(fr.tokens),
                           precision_schedule=sched, prefill_precision=pm)
    return bool(np.array_equal(fr.tokens, solo.tokens[0]))


def _oracle_ok_scalar_step(server, fr, req, slots: int) -> bool:
    """Bitwise SHAPE-MATCHED single-width replay of one finished request:
    the request runs alone through a fresh scalar-step (max-width)
    continuous scheduler at the same slot count, so every matmul sees the
    same batch shape as the heterogeneous run and only the per-row width
    machinery differs.  Requires a constant realized width (true for the
    bench workload — no SLO clamp in this mode)."""
    import numpy as np

    widths = set(fr.decode_widths)
    assert len(widths) == 1, f"non-constant realized widths: {widths}"
    solo = server.continuous(slots=slots, width_policy="max-width")
    rid = solo.submit(req["prompt"], max_new=req["max_new"],
                      request_class=req["request_class"],
                      seed=req.get("seed"))
    done = solo.drain()
    return bool(np.array_equal(fr.tokens, done[rid].tokens))


def _service_steps_per_token(fr) -> float:
    return (fr.finish_step - fr.admit_step) / max(len(fr.tokens), 1)


def run_faults(server, policy, smoke: bool) -> dict:
    """The resilience scenarios.  Every drain runs under a max_steps
    watchdog (a hung scheduler raises instead of wedging CI), and the
    returned ``checks`` dict must be all-True — ``main`` asserts it, so a
    crossed floor, a perturbed co-resident, a missed SLO or a broken
    oracle fails the bench."""
    import numpy as np

    from repro.serve.faults import (
        ArrivalFlood,
        CacheCorruptionFault,
        NaNLogitsFault,
        StallFault,
    )
    from repro.serve.scheduler import SLODegradePolicy

    vocab = server.cfg.vocab_size
    watchdog = 2_000
    checks = {}
    out = {"slo_steps_per_token": SLO_STEPS_PER_TOKEN}

    def P(n, seed):
        return np.random.default_rng(seed).integers(
            0, vocab, (n,)).astype(np.int32)

    # the faults policy adds a degradation-refusing class (floor 8) on top
    # of the bench classes; passed per-scheduler, the server is untouched
    fpolicy = policy.with_class("pinned", 8, min_width=8)

    # -- flood: degrade under queue pressure, hold the SLO, respect floors
    slots = 4
    flood_n = 8 if smoke else 16
    flood_new = 5 if smoke else 8
    sd = SLODegradePolicy(queue_high=3, hold_steps=2)

    # one single-request flood per arrival, classes alternating, so FIFO
    # admission puts BOTH width groups in the slots at once — that's what
    # makes width-rr genuinely rotate (~2 steps/token) in the contrast run
    # while commit-everyone degradation holds ~1
    def make_floods():
        return [ArrivalFlood(at_step=1, n=1, prompt_len=8,
                             max_new=flood_new,
                             request_class=("generation" if j % 2 == 0
                                            else "understanding"),
                             seed=5 + j)
                for j in range(flood_n)]

    floods = make_floods()
    sched = server.continuous(slots=slots, width_policy=sd, policy=fpolicy,
                              faults=floods)
    pinned_prompts = [P(8, seed=100 + i) for i in range(2)]
    pinned = [sched.submit(pinned_prompts[i], 4, request_class="pinned",
                           seed=i) for i in range(2)]
    t0 = time.perf_counter()
    done = sched.drain(max_steps=watchdog)
    wall = time.perf_counter() - t0
    deg = sd.degradation
    flood_pairs = [(rid, fl.prompts[j])
                   for fl in floods for j, rid in enumerate(fl.rids)]
    decoded = [fr for fr in done.values() if fr.tokens.size]
    hold = [fr for fr in decoded
            if _service_steps_per_token(fr) <= SLO_STEPS_PER_TOKEN]
    floor_violations = sum(
        sum(1 for w in done[rid].decode_widths if w < 8) for rid in pinned)
    # oracle replay: the pinned (non-degraded) requests always, plus a
    # deterministic sample of the degraded flood (cap the lockstep cost)
    oracle_pairs = ([(rid, pinned_prompts[i])
                     for i, rid in enumerate(pinned)]
                    + flood_pairs[:4 if smoke else 8])
    oracle_ok = all(_oracle_ok(server, done[rid], pr)
                    for rid, pr in oracle_pairs)
    useful = sum(len(fr.tokens) for fr in done.values())
    out["flood"] = {
        "requests": len(done),
        "flood_requests": len(flood_pairs),
        "escalations": int(deg["escalations"]),
        "max_shift_seen": int(deg["max_shift_seen"]),
        "degraded_steps": int(deg["degraded_steps"]),
        "downshifted_slot_steps": int(deg["downshifted_slot_steps"]),
        "width_steps": {str(k): v
                        for k, v in sched.stats["width_steps"].items()},
        "tokens_per_sec_degraded": useful / max(wall, 1e-9),
        "slo_hold_rate": len(hold) / max(len(decoded), 1),
        "p95_service_steps_per_token": _pctl(
            [_service_steps_per_token(fr) for fr in decoded], 95),
        "floor_violations": int(floor_violations),
        "oracle_checked": len(oracle_pairs),
        "statuses": {s: sum(fr.status == s for fr in done.values())
                     for s in {fr.status for fr in done.values()}},
    }
    checks["flood_escalated"] = deg["escalations"] >= 1
    checks["flood_downshifted"] = deg["downshifted_slot_steps"] > 0
    checks["flood_slo_hold"] = out["flood"]["slo_hold_rate"] >= 0.9
    checks["floors_respected"] = floor_violations == 0
    checks["oracle_bitwise"] = oracle_ok

    # contrast: the same flood under plain width-rr (fidelity, no
    # degradation) — shows the SLO hold is the policy's doing
    rr = server.continuous(slots=slots, width_policy="width-rr",
                           policy=fpolicy, faults=make_floods())
    for i in range(2):
        rr.submit(pinned_prompts[i], 4, request_class="pinned", seed=i)
    rr_done = rr.drain(max_steps=watchdog)
    rr_decoded = [fr for fr in rr_done.values() if fr.tokens.size]
    out["flood"]["slo_hold_rate_width_rr"] = (
        sum(_service_steps_per_token(fr) <= SLO_STEPS_PER_TOKEN
            for fr in rr_decoded) / max(len(rr_decoded), 1))

    # -- nan_slot / cache_corruption: quarantine containment, bitwise
    upolicy = policy.with_default(6)
    base_prompts = [P(12, seed=10 + i) for i in range(3)]

    def run_trio(faults):
        s = server.continuous(slots=3, policy=upolicy, faults=faults)
        rids = [s.submit(base_prompts[i], 8, seed=i) for i in range(3)]
        d = s.drain(max_steps=watchdog)
        return s, [d[r] for r in rids]

    _, base = run_trio([])
    for scen, fault, victim_slot in (
            ("nan_slot", NaNLogitsFault(slot=1, step=2), 1),
            ("cache_corruption", CacheCorruptionFault(slot=2, step=3), 2)):
        s, frs = run_trio([fault])
        victim = frs[victim_slot]
        survivors_equal = all(
            np.array_equal(frs[i].tokens, base[i].tokens)
            for i in range(3) if i != victim_slot)
        prefix_equal = np.array_equal(
            victim.tokens, base[victim_slot].tokens[:len(victim.tokens)])
        out[scen] = {
            "fired": len(fault.fired),
            "victim_status": victim.status,
            "victim_tokens": int(len(victim.tokens)),
            "co_resident_bitwise_equal": bool(survivors_equal),
            "victim_prefix_equal": bool(prefix_equal),
            "poisoned": int(s.stats["poisoned"]),
            "leaked_slots": int(s.active),
        }
        checks[f"{scen}_quarantined"] = (victim.status == "poisoned"
                                         and s.stats["poisoned"] == 1)
        checks[f"{scen}_contained"] = survivors_equal and prefix_equal
        checks[f"{scen}_no_leak"] = s.active == 0

    # -- stall: the latency-EWMA trigger (queue depth can't exercise it)
    stall_policy = SLODegradePolicy(slo_step_seconds=0.05,
                                    queue_high=10_000, hold_steps=3)
    stall = StallFault([1, 2], 0.4)
    s = server.continuous(slots=2, width_policy=stall_policy,
                          faults=[stall])
    rids = [s.submit(P(10, seed=50 + i), 6, seed=i) for i in range(2)]
    d = s.drain(max_steps=watchdog)
    out["stall"] = {
        "fired": len(stall.fired),
        "escalations": int(stall_policy.degradation["escalations"]),
        "all_ok": all(d[r].status == "ok" for r in rids),
    }
    checks["stall_escalated"] = out["stall"]["escalations"] >= 1
    checks["stall_finished_ok"] = out["stall"]["all_ok"]

    checks["no_hangs"] = True  # every drain above returned under watchdog
    out["checks"] = checks
    return out


# ---------------------------------------------------------------------------
# self-speculative decode scenario (--speculative; DESIGN.md §15)
# ---------------------------------------------------------------------------

def run_speculative(artifact, policy, smoke: bool,
                    oracle_cap: int = 4) -> dict:
    """Self-speculative decode vs the plain m=8 continuous baseline on the
    same staggered-arrival workload, served twice: the two runs differ
    ONLY in ``spec_decode``.  Speculation engages when the realized step
    width equals the verify width, so every request is the m=8
    ``generation`` class.  Greedy speculative output must be
    token-identical to the plain run (that's the subsystem's whole
    contract), the acceptance accounting must balance (drafted ==
    accepted + wasted), a sample replays on the lockstep oracle (spec
    tokens record realized width 8, so ``oracle_schedule`` is unchanged),
    and on the dispatch-bound smoke size the macro-step structure — one
    scheduler step and ONE host sync per ~k committed tokens — must
    deliver >= SPEC_SPEEDUP_BAR x tokens/s.  The full-size run records
    ``speedup_vs_plain`` without the bar: at compute-bound sizes the
    draft+verify FLOP overhead (~(2k+1)/k model evals per committed
    token) eats the dispatch win, and DESIGN.md §9 absolute CPU numbers
    never transfer anyway.

    The scenario serves a LONGER staggered workload than the headline
    modes (decodes of 48-96 tokens, not 3-10): a draft run of depth k
    only amortizes when requests live for several macro-steps.  The
    candidate draft widths sit high on the ladder (6/7, not the 3/4 a
    tuned deployment would pick): a randomly-initialized master has no
    BPS training aligning its low-width argmax with m=8, so acceptance
    at m<=4 is near-chance here — the bench pins the machinery
    (bookkeeping, rollback, bitwise identity, throughput structure), not
    model quality."""
    import numpy as np

    ps = PAGE_SIZE
    prompt_len = 16
    # denser arrivals and longer decodes than the headline modes: the
    # speculative scheduler drains ~k tokens per slot-step, so a sparse
    # arrival stream leaves it idling at the arrival clock (both runs must
    # stay work-bound for the tokens/s ratio to measure the decode path)
    if smoke:
        # 3 slots, not the headline 4: the plain baseline is host-bound
        # (one dispatch + one sync per committed token-row), so fewer
        # slots raise its per-token cost while the device-bound macro-step
        # barely notices — the dispatch-amortization win the smoke bar
        # certifies is clearest here and the ratio is stable run-to-run
        n_requests, slots = 16, 3
        max_new_lo, max_new_hi, arrival_gap = 48, 96, 1
    else:
        n_requests, slots = 16, 8
        max_new_lo, max_new_hi, arrival_gap = 48, 96, 1
    max_len = prompt_len + max_new_hi + 1
    max_len += -max_len % ps
    server = artifact.server(policy, max_len=max_len)
    spec_reqs = make_workload(n_requests, prompt_len, max_new_lo,
                              max_new_hi, arrival_gap,
                              server.cfg.vocab_size, {"generation": 8},
                              seed=7)
    spec_cfg = {"k": 4, "draft_width": 7, "candidates": (4, 6, 7)}

    def drive(spec_decode):
        sched = server.continuous(slots=slots, width_policy="max-width",
                                  spec_decode=spec_decode)
        t0 = time.perf_counter()
        done = sched.replay(spec_reqs)
        wall = time.perf_counter() - t0
        return done, wall, sched.stats

    for sd in (False, spec_cfg):
        drive(sd)  # warmup: compile both executables before timing
    repeats = 3  # best-of-3: the ratio bar needs low wall-clock variance
    best = {}
    for name, sd in (("plain", False), ("spec", spec_cfg)):
        for _ in range(repeats):
            done, wall, stats = drive(sd)
            if name not in best or wall < best[name][1]:
                best[name] = (done, wall, stats)
    plain_done, plain_wall, plain_stats = best["plain"]
    spec_done, spec_wall, spec_stats = best["spec"]

    useful = sum(len(fr.tokens) for fr in spec_done.values())
    assert useful == sum(len(fr.tokens) for fr in plain_done.values())
    token_identical = all(
        np.array_equal(spec_done[r].tokens, plain_done[r].tokens)
        for r in spec_done)
    # oracle replay of a deterministic sample of the SPEC run: spec-
    # committed tokens record realized width = verify width, so the
    # oracle schedule is the plain m=8 schedule.  Same engine split as
    # the headline modes (DESIGN.md §14): smoke (d128) replays on the
    # lockstep engine, the full size must replay SHAPE-MATCHED through
    # the scalar-step scheduler (XLA CPU matmuls are not batch-shape-
    # invariant at d512, so a B=1 lockstep row diverges bitwise from the
    # same row inside the serving batch — for plain and spec equally)
    ordered = sorted(spec_reqs, key=lambda r: int(r.get("arrival", 0)))
    pairs = list(zip(sorted(spec_done), ordered))[:oracle_cap]
    if smoke:
        oracle_ok = all(_oracle_ok(server, spec_done[rid], r["prompt"])
                        for rid, r in pairs)
    else:
        oracle_ok = all(
            _oracle_ok_scalar_step(server, spec_done[rid], r, slots)
            for rid, r in pairs)

    sp = spec_stats["speculative"]
    plain_tps = useful / max(plain_wall, 1e-9)
    spec_tps = useful / max(spec_wall, 1e-9)
    speedup = spec_tps / max(plain_tps, 1e-9)
    spec_frs = [fr for fr in spec_done.values() if fr.spec is not None]
    per_request_balanced = all(
        fr.spec["drafted"] == fr.spec["accepted"] + fr.spec["rejected"]
        for fr in spec_frs)
    checks = {
        "token_identical_to_plain": bool(token_identical),
        "oracle_bitwise": bool(oracle_ok),
        "speculation_engaged": sp["drafted"] > 0,
        "accounting_balanced": (
            sp["drafted"] == sp["accepted"] + sp["wasted"]),
        "per_request_accounting_balanced": bool(per_request_balanced),
    }
    if smoke:
        checks[f"speedup_ge_{SPEC_SPEEDUP_BAR}x"] = (
            speedup >= SPEC_SPEEDUP_BAR)
    return {
        "k": int(sp["k"]),
        "verify_width": int(sp["verify_width"]),
        "estimator": sp["estimator"],
        "oracle_engine": "lockstep" if smoke else "scalar-step",
        "macro_steps": int(sp["macro_steps"]),
        "drafted": int(sp["drafted"]),
        "accepted": int(sp["accepted"]),
        "wasted": int(sp["wasted"]),
        "bonus_tokens": int(sp["bonus_tokens"]),
        "committed_tokens": int(sp["committed_tokens"]),
        "acceptance_rate": float(sp["acceptance_rate"] or 0.0),
        "by_width": sp["by_width"],
        "useful_tokens": int(useful),
        "plain": {"tokens_per_sec": plain_tps,
                  "wall_seconds": plain_wall,
                  "total_steps": int(plain_stats["steps"])},
        "spec": {"tokens_per_sec": spec_tps,
                 "wall_seconds": spec_wall,
                 "total_steps": int(spec_stats["steps"])},
        "speedup_vs_plain": speedup,
        "speedup_bar": SPEC_SPEEDUP_BAR if smoke else None,
        "oracle_checked": len(pairs),
        "checks": checks,
    }


# ---------------------------------------------------------------------------
# telemetry overhead + export-validity scenario (--telemetry; DESIGN.md §16)
# ---------------------------------------------------------------------------

def run_telemetry(artifact, policy, smoke: bool,
                  trace_out: str = "BENCH_serving_trace.json") -> dict:
    """One mixed workload — speculative m=8 generation beside plain m=4
    understanding under heterogeneous(slo-degrade), plus an arrival flood
    that forces a queue-pressure escalation — served twice, identical
    except for telemetry.  Scheduling here is deterministic (the degrade
    trigger is queue-depth only, never wall clock), so both runs must
    produce token-identical output and the tokens/s ratio isolates the
    recording overhead.  The instrumented run's exports are then held to
    the §16 validity bars: parseable Prometheus exposition, structurally
    valid Chrome trace, and EXACT reconciliation of the trace's token
    width timeline against ``width_counts()`` / ``tokens_by_width``."""
    import collections as cl

    import numpy as np

    from repro.serve.faults import ArrivalFlood
    from repro.serve.scheduler import HeterogeneousPolicy, SLODegradePolicy
    from repro.serve.telemetry import (
        Telemetry,
        parse_prometheus,
        validate_trace,
    )

    ps = PAGE_SIZE
    prompt_len = 16
    # calm early phase (spaced arrivals: the m=8 rows speculate at shift
    # 0), then a one-burst flood deep enough to cross queue_high — the
    # escalation downshifts below the verify width, so the same run also
    # exercises the plain degraded path
    if smoke:
        n_requests, slots = 10, 3
        max_new_lo, max_new_hi, arrival_gap = 32, 56, 4
        flood_at, flood_n, flood_new = 48, 6, 8
    else:
        n_requests, slots = 12, 4
        max_new_lo, max_new_hi, arrival_gap = 32, 64, 4
        flood_at, flood_n, flood_new = 60, 8, 12
    max_len = prompt_len + max_new_hi + 1
    max_len += -max_len % ps
    server = artifact.server(policy, max_len=max_len)
    classes = {"generation": 8, "understanding": 4}
    reqs = make_workload(n_requests, prompt_len, max_new_lo, max_new_hi,
                         arrival_gap, server.cfg.vocab_size, classes,
                         seed=11)
    spec_cfg = {"k": 3, "draft_width": 6, "candidates": (4, 6)}

    def drive(on):
        tel = Telemetry(max_events=1 << 17) if on else None
        sched = server.continuous(
            slots=slots,
            width_policy=HeterogeneousPolicy(
                degrade=SLODegradePolicy(queue_high=3, hold_steps=2)),
            spec_decode=spec_cfg,
            faults=[ArrivalFlood(at_step=flood_at, n=flood_n,
                                 prompt_len=8, max_new=flood_new,
                                 request_class="understanding", seed=77)],
            telemetry=tel)
        t0 = time.perf_counter()
        done = sched.replay(reqs, max_steps=20_000)
        wall = time.perf_counter() - t0
        return sched, tel, done, wall

    for on in (False, True):
        drive(on)  # warmup: compile before timing
    best = {}
    for name, on in (("off", False), ("on", True)):
        for _ in range(5):  # best-of-5: the ratio bar needs low variance
            got = drive(on)
            if name not in best or got[3] < best[name][3]:
                best[name] = got
    sched_off, _, done_off, wall_off = best["off"]
    sched_on, tel, done_on, wall_on = best["on"]

    useful = sum(len(fr.tokens) for fr in done_on.values())
    tps_off = sum(len(fr.tokens)
                  for fr in done_off.values()) / max(wall_off, 1e-9)
    tps_on = useful / max(wall_on, 1e-9)
    ratio = tps_on / max(tps_off, 1e-9)
    tokens_identical = (set(done_on) == set(done_off) and all(
        np.array_equal(done_on[r].tokens, done_off[r].tokens)
        for r in done_on))

    # export validity (the instrumented side)
    stats = sched_on.stats
    evs = tel.tracer.events()
    trace_errs = validate_trace(evs)
    trace_widths = cl.Counter(e["args"]["width"] for e in evs
                              if e["name"] == "token")
    agg = cl.Counter()
    for fr in done_on.values():
        agg.update(fr.width_counts())
    exposition = sched_on.metrics.render_prometheus()
    try:
        parse_prometheus(exposition)
        exposition_valid = True
    except ValueError:
        exposition_valid = False
    ttft_counts = {k[0]: ch.count for k, ch in sched_on.metrics.series(
        "otaro_serve_ttft_seconds").items()}
    itl_counts = {k[0]: ch.count for k, ch in sched_on.metrics.series(
        "otaro_serve_itl_seconds").items()}
    deg = stats["degradation"]
    sp = stats["speculative"]
    tel.tracer.write_chrome_trace(trace_out)

    checks = {
        "tokens_identical_on_vs_off": bool(tokens_identical),
        f"overhead_le_{round((1 - TEL_OVERHEAD_BAR) * 100)}pct":
            tps_on >= TEL_OVERHEAD_BAR * tps_off,
        "exposition_valid": exposition_valid,
        "trace_valid": not trace_errs,
        "trace_widths_reconcile": (
            dict(trace_widths) == dict(agg) == stats["tokens_by_width"]),
        "ttft_recorded_per_class": (
            set(ttft_counts) == set(classes)
            and all(v > 0 for v in ttft_counts.values())),
        "itl_recorded_per_class": (
            set(itl_counts) == set(classes)
            and all(v > 0 for v in itl_counts.values())),
        "spec_engaged": sp["drafted"] > 0,
        "slo_escalated": deg["escalations"] >= 1,
        "no_trace_drops": tel.tracer.dropped == 0,
    }
    return {
        "requests": int(len(done_on)),
        "useful_tokens": int(useful),
        "tokens_per_sec_on": tps_on,
        "tokens_per_sec_off": tps_off,
        "overhead_ratio": ratio,
        "overhead_bar": TEL_OVERHEAD_BAR,
        "trace_events": int(len(evs)),
        "trace_dropped": int(tel.tracer.dropped),
        "trace_path": trace_out,
        "exposition_lines": int(len(exposition.splitlines())),
        "ttft_counts": ttft_counts,
        "itl_counts": itl_counts,
        "tokens_by_width": {str(k): v for k, v in
                            sorted(stats["tokens_by_width"].items())},
        "trace_token_widths": {str(k): v for k, v in
                               sorted(trace_widths.items())},
        "spec_drafted": int(sp["drafted"]),
        "slo_escalations": int(deg["escalations"]),
        "workload": {"requests": n_requests, "prompt_len": prompt_len,
                     "max_new_min": max_new_lo, "max_new_max": max_new_hi,
                     "arrival_gap": arrival_gap, "flood_at": flood_at,
                     "flood_n": flood_n,
                     "classes": {k: int(v) for k, v in classes.items()}},
        "checks": checks,
    }


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def run(smoke: bool = False, faults: bool = False,
        long_context: bool = False, speculative: bool = False,
        telemetry: bool = False,
        trace_out: str = "BENCH_serving_trace.json") -> dict:
    import jax

    from repro import api
    from repro.models.config import ModelConfig

    # Full mode must be big enough that per-step model compute dominates
    # the continuous scheduler's per-step dispatch+sync overhead — on a
    # CPU-sized model the fused lockstep scan otherwise wins on pure
    # overhead even while running 1.6x more decode steps (measured: 2
    # layers/d128 -> 0.3x, 8 layers/d512 -> 1.2x).  Smoke mode exists to
    # exercise the drivers and pin the schema in CI, not to claim a
    # speedup (DESIGN.md §9: absolute CPU numbers never transfer anyway).
    slots = 4 if smoke else 8
    prompt_len = 16
    n_requests = 8 if smoke else 24
    max_new_lo, max_new_hi = (3, 10) if smoke else (4, 48)
    arrival_gap = 2 if smoke else 1
    # four precision classes spanning the serving ladder: the width-rr
    # rotation tax (and the heterogeneous mode's removal of it) is measured
    # on a genuinely mixed batch, not a two-way split
    classes = {"generation": 8, "balanced": 6, "understanding": 4,
               "draft": 3}
    if smoke:
        cfg = ModelConfig(
            name="bench-serving", family="dense", n_layers=2, d_model=128,
            n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
            q_block=16, kv_block=16, loss_chunk=32, remat="none",
            dtype="bfloat16")
    else:
        cfg = ModelConfig(
            name="bench-serving", family="dense", n_layers=8, d_model=512,
            n_heads=4, n_kv_heads=2, head_dim=128, d_ff=1024,
            vocab_size=2048, q_block=16, kv_block=16, loss_chunk=32,
            remat="none", dtype="bfloat16")
    # paged KV requires page_size | max_len (the decode view must be able
    # to equal max_len for the bitwise lockstep oracle) — round up
    max_len = prompt_len + max_new_hi + 1
    max_len += -max_len % PAGE_SIZE

    policy = api.PrecisionPolicy.all_widths()
    for name, w in classes.items():
        policy = policy.with_class(name, w)
    artifact = api.Artifact.from_params(
        cfg, api.init_params(cfg, jax.random.PRNGKey(0)), policy=policy)
    server = artifact.server(policy, max_len=max_len)

    reqs = make_workload(n_requests, prompt_len, max_new_lo, max_new_hi,
                         arrival_gap, cfg.vocab_size, classes)

    drivers = {
        "lockstep": lambda: run_lockstep(server, reqs, slots, policy),
        "continuous": lambda: run_continuous(server, reqs, slots,
                                             "max-width"),
        "continuous_rr": lambda: run_continuous(server, reqs, slots,
                                                "width-rr"),
        "heterogeneous": lambda: run_continuous(
            server, reqs, slots, "heterogeneous",
            oracle="lockstep" if smoke else "scalar-step"),
    }
    repeats = 2
    modes = {}
    useful = {}
    for name, fn in drivers.items():
        fn()  # warmup: compile every (shape, mode) the driver touches
        best = None
        for _ in range(repeats):
            entry, u = fn()
            if best is None or entry["wall_seconds"] < best["wall_seconds"]:
                best, useful[name] = entry, u
        modes[name] = best

    # every mode serves every request in full
    assert len(set(useful.values())) == 1, useful

    doc = {
        "schema_version": SCHEMA_VERSION,
        "bench": "serving",
        "mode": "smoke" if smoke else "full",
        "config": {"name": cfg.name, "family": cfg.family,
                   "n_layers": cfg.n_layers, "d_model": cfg.d_model,
                   "vocab_size": cfg.vocab_size, "slots": slots},
        "workload": {"requests": n_requests, "prompt_len": prompt_len,
                     "max_new_min": max_new_lo, "max_new_max": max_new_hi,
                     "arrival_gap": arrival_gap,
                     "useful_tokens": useful["lockstep"],
                     "classes": {k: int(v) for k, v in classes.items()}},
        "modes": modes,
        "speedup_continuous_vs_lockstep": (
            modes["continuous"]["tokens_per_sec"]
            / max(modes["lockstep"]["tokens_per_sec"], 1e-9)),
        "steps_saved_vs_lockstep": (modes["lockstep"]["total_steps"]
                                    - modes["continuous"]["total_steps"]),
        "faults": run_faults(server, policy, smoke) if faults else None,
        "long_context": (run_long_context(artifact, policy, smoke)
                         if long_context else None),
        "speculative": (run_speculative(artifact, policy, smoke)
                        if speculative else None),
        "telemetry": (run_telemetry(artifact, policy, smoke,
                                    trace_out=trace_out)
                      if telemetry else None),
    }
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (CI leg): few requests, short decodes")
    ap.add_argument("--faults", action="store_true",
                    help="also run the fault-injection scenarios and "
                    "record the 'faults' section (hard-fails on a hang, "
                    "crossed floor, or broken bitwise oracle)")
    ap.add_argument("--long-context", action="store_true",
                    help="also run the paged-KV long-context scenario "
                    "and record the 'long_context' section (hard-fails "
                    "on zero prefix reuse, a decode stall, or < 2x "
                    "concurrency per KV byte vs dense)")
    ap.add_argument("--speculative", action="store_true",
                    help="also run the self-speculative decode scenario "
                    "and record the 'speculative' section (hard-fails on "
                    "oracle divergence from the plain m=8 run, an "
                    "acceptance-accounting mismatch, or — in smoke — "
                    f"speedup under {SPEC_SPEEDUP_BAR}x)")
    ap.add_argument("--telemetry", action="store_true",
                    help="also run the observability scenario and record "
                    "the 'telemetry' section (hard-fails on a tokens/s "
                    f"overhead ratio under {TEL_OVERHEAD_BAR}x, an invalid "
                    "Prometheus exposition or Chrome trace, or a trace "
                    "width timeline that does not reconcile with the "
                    "scheduler's accounting); writes the Perfetto-loadable "
                    "trace to --trace-out")
    ap.add_argument("--trace-out", default="BENCH_serving_trace.json",
                    help="where --telemetry writes the Chrome trace "
                    "(open at ui.perfetto.dev)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="validate an existing JSON against the schema "
                    "and exit (no benchmark run)")
    args = ap.parse_args()

    if args.check:
        with open(args.check) as f:
            doc = json.load(f)
        errs = check_schema(doc)
        if errs:
            print("\n".join(errs))
            sys.exit(1)
        print(f"{args.check}: schema v{doc['schema_version']} OK "
              f"(mode={doc['mode']}, continuous/lockstep speedup "
              f"{doc['speedup_continuous_vs_lockstep']:.2f}x)")
        return

    doc = run(smoke=args.smoke, faults=args.faults,
              long_context=args.long_context,
              speculative=args.speculative,
              telemetry=args.telemetry, trace_out=args.trace_out)
    errs = check_schema(doc)
    assert not errs, errs
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} (mode={doc['mode']})")
    for name in MODES:
        e = doc["modes"][name]
        extra = (f"  occ {e['occupancy']:.2f}"
                 if "occupancy" in e else "")
        print(f"  {name:14s} {e['tokens_per_sec']:8.1f} tok/s  "
              f"{e['total_steps']:4d} steps  p50/p95 latency "
              f"{e['latency_steps_p50']:.0f}/{e['latency_steps_p95']:.0f}"
              f" steps{extra}")
    print(f"  continuous vs lockstep: "
          f"{doc['speedup_continuous_vs_lockstep']:.2f}x tokens/s, "
          f"{doc['steps_saved_vs_lockstep']} decode steps saved")
    het = doc["modes"].get("heterogeneous")
    if het:
        rr = doc["modes"]["continuous_rr"]
        tbw = ", ".join(f"m{k}: {v}"
                        for k, v in sorted(het["tokens_by_width"].items(),
                                           reverse=True))
        print(f"  heterogeneous vs width-rr: "
              f"{het['tokens_per_sec'] / max(rr['tokens_per_sec'], 1e-9):.2f}"
              f"x tokens/s at exact per-class fidelity "
              f"(commit rate {het['commit_rate']:.2f}, "
              f"starvation {het['starvation'] or '{}'}, oracle bitwise: "
              f"{het.get('oracle_bitwise')})")
        print(f"  heterogeneous tokens by width: {tbw}")
    fl = doc.get("faults")
    if fl:
        f = fl["flood"]
        print(f"  faults/flood: SLO-hold {f['slo_hold_rate']:.2f} "
              f"(width-rr {f['slo_hold_rate_width_rr']:.2f}), "
              f"{f['escalations']} escalations, "
              f"{f['downshifted_slot_steps']} downshifted slot-steps, "
              f"{f['tokens_per_sec_degraded']:.1f} tok/s degraded, "
              f"{f['floor_violations']} floor violations")
        for scen in ("nan_slot", "cache_corruption"):
            s = fl[scen]
            print(f"  faults/{scen}: victim {s['victim_status']}, "
                  f"co-resident bitwise equal: "
                  f"{s['co_resident_bitwise_equal']}")
        print(f"  faults/stall: {fl['stall']['escalations']} escalations "
              f"from latency EWMA")
        bad = [k for k, v in fl["checks"].items() if v is not True]
        print(f"  faults/checks: "
              f"{'ALL PASS' if not bad else 'FAILED: ' + ', '.join(bad)}")
    lc = doc.get("long_context")
    if lc:
        print(f"  long-context: {lc['peak_concurrent_requests']} "
              f"concurrent requests in a "
              f"{lc['kv_budget_bytes'] / 1024:.0f} kB KV budget "
              f"(dense rows of max_len={lc['max_len']}: "
              f"{lc['dense_slots_same_budget']}) -> "
              f"{lc['concurrency_per_byte_vs_dense']:.1f}x per byte")
        print(f"  long-context: prefix hit rate "
              f"{lc['prefix_hit_rate']:.2f} "
              f"({lc['prefix_hits']} hits, {lc['reused_pages']} pages "
              f"reused), page occupancy {lc['page_occupancy']:.2f}, "
              f"{lc['prefill_chunks']} prefill chunks, "
              f"{lc['decode_stall_steps']} decode stalls")
        bad = [k for k, v in lc["checks"].items() if v is not True]
        print(f"  long-context/checks: "
              f"{'ALL PASS' if not bad else 'FAILED: ' + ', '.join(bad)}")
    sp = doc.get("speculative")
    if sp:
        byw = ", ".join(
            f"m{w}: {row['acceptance_rate']:.2f}"
            for w, row in sorted(sp["by_width"].items(), reverse=True)
            if row.get("acceptance_rate") is not None)
        print(f"  speculative: {sp['spec']['tokens_per_sec']:.1f} tok/s vs "
              f"plain m=8 {sp['plain']['tokens_per_sec']:.1f} -> "
              f"{sp['speedup_vs_plain']:.2f}x "
              f"(k={sp['k']}, estimator={sp['estimator']})")
        print(f"  speculative: acceptance {sp['acceptance_rate']:.2f} "
              f"({byw}), {sp['drafted']} drafted = {sp['accepted']} "
              f"accepted + {sp['wasted']} wasted, "
              f"{sp['bonus_tokens']} bonus, "
              f"{sp['macro_steps']} macro-steps")
        bad = [k for k, v in sp["checks"].items() if v is not True]
        print(f"  speculative/checks: "
              f"{'ALL PASS' if not bad else 'FAILED: ' + ', '.join(bad)}")
    tl = doc.get("telemetry")
    if tl:
        print(f"  telemetry: {tl['tokens_per_sec_on']:.1f} tok/s on vs "
              f"{tl['tokens_per_sec_off']:.1f} off -> "
              f"{tl['overhead_ratio']:.3f}x "
              f"(bar {tl['overhead_bar']:.2f}x)")
        print(f"  telemetry: {tl['trace_events']} trace events "
              f"({tl['trace_dropped']} dropped) -> {tl['trace_path']}, "
              f"{tl['exposition_lines']} exposition lines, "
              f"token widths {tl['trace_token_widths']} reconcile, "
              f"ttft counts {tl['ttft_counts']}")
        bad = [k for k, v in tl["checks"].items() if v is not True]
        print(f"  telemetry/checks: "
              f"{'ALL PASS' if not bad else 'FAILED: ' + ', '.join(bad)}")


if __name__ == "__main__":
    main()
