"""Paper Table 2 — memory consumption and decode throughput, FP16 vs SEFP.

Two layers of evidence (the container is CPU-only; TPU wall-clock cannot be
measured, DESIGN.md §9):

1. MEMORY (exact, bit-level accounting on the real llama3-8b weight shapes,
   the paper's subject): fp16 bytes vs SEFP-E5M4 streamed bits incl. the
   KV cache at the paper's 2000-token setting.  Paper: 15.20 GB -> 4.77 GB
   (69% down).

2. THROUGHPUT (mechanism): decode is weight-streaming-bound, so throughput
   scales ~ 1/bytes.  We report the bytes-ratio-implied speedup for E5M4
   (paper measured x2.45 on its runtime) and microbenchmark the fused
   sefp_matmul kernel vs the bf16 jnp matmul on CPU to validate numerics +
   show the per-call dequant overhead is small relative to the projected
   bandwidth win (kernel timing on CPU interpret mode is NOT a TPU proxy
   and is labeled as such).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as CM
from repro import configs as C
from repro.core import packed as packed_lib
from benchmarks import costmodel


def memory_table(log=print) -> dict:
    cfg = C.get_config("llama3_8b")
    n_params, _ = costmodel.param_counts(cfg)
    ctx = 2000          # paper's "input of 2000 tokens"
    batch = 1
    kv_bytes_fp16 = 2.0 * cfg.n_layers * batch * ctx * cfg.n_kv_heads \
        * cfg.hd * 2
    fp16 = n_params * 2 + kv_bytes_fp16

    m = 4
    bits = (m + 1) + 8.0 / 64           # SEFP-E5M4 streamed bits/param
    sefp_w = n_params * bits / 8
    # paper quantizes the KV cache to the same format
    sefp_kv = kv_bytes_fp16 / 2 * bits / 8 / 1.0  # fp16->sefp per element
    sefp_kv = 2.0 * cfg.n_layers * batch * ctx * cfg.n_kv_heads * cfg.hd \
        * bits / 8
    sefp = sefp_w + sefp_kv
    red = 1 - sefp / fp16

    log("\n== bench_memory_speed (paper Table 2 analog, llama3-8b) ==")
    log(f"FP16 total: {fp16/2**30:6.2f} GiB   (paper: 15.20 GB)")
    log(f"SEFP-E5M4 : {sefp/2**30:6.2f} GiB   (paper:  4.77 GB)")
    log(f"reduction : {100*red:5.1f}%        (paper:  69%)")
    speedup = fp16 / sefp
    log(f"bytes-ratio decode speedup bound: x{speedup:.2f} "
        f"(paper measured x2.45 end-to-end)")
    return {"fp16_bytes": fp16, "sefp_bytes": sefp, "reduction": red,
            "speedup_bound": speedup}


def kernel_microbench(log=print) -> dict:
    """Fused sefp_matmul vs bf16 matmul: numerics + CPU-relative cost
    (interpret mode — NOT a TPU timing; see module docstring)."""
    from repro.kernels.sefp_matmul import sefp_matmul

    K, N, B = 512, 512, 8
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, K)), jnp.float32)
    p = packed_lib.pack(w, group_axis=0)

    wb = w.astype(jnp.bfloat16)
    f_ref = jax.jit(lambda x: (x.astype(jnp.bfloat16) @ wb).astype(
        jnp.float32))
    t_ref = CM.timed(f_ref, x, n_iter=10)
    out_k = sefp_matmul(x, p, 4)
    t_k = CM.timed(lambda x: sefp_matmul(x, p, 4), x, n_iter=3, warmup=1)
    err = float(jnp.abs(out_k - f_ref(x)).mean()
                / jnp.abs(f_ref(x)).mean())
    log(f"kernel microbench (CPU interpret — numerics check only): "
        f"bf16 matmul {t_ref:.0f}us, fused sefp_matmul {t_k:.0f}us, "
        f"rel err {err:.4f}")
    log(f"TPU-projected: weight bytes/elt 2.0 (bf16) -> "
        f"{p.bits_per_param(4)/8:.2f} (E5M4 stream): "
        f"x{16/ (p.bits_per_param(4)):.2f} HBM-bound decode speedup")
    return {"ref_us": t_ref, "kernel_us": t_k, "rel_err": err}


def run(log=print) -> dict:
    out = memory_table(log)
    out.update(kernel_microbench(log))
    return out


if __name__ == "__main__":
    run()
