"""Paper Fig. 7 / Table 8 — task-specific fine-tuning PPL across bit-widths.

LLaMA3.2-1B + WikiText2 in the paper; the CPU analog fine-tunes the
pretrained bench LM on the task corpus with each method and reports PPL at
every SEFP width.  Expected qualitative reproduction (paper Table 8):
  * every fine-tuning method beats "before" at every width;
  * OTARo has the lowest AVG and STD across widths;
  * OTARo's margin is largest at E5M4/E5M3.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common as CM


def run(steps: int = 300, log=print) -> dict:
    params0 = CM.pretrain()
    results = {}

    # before fine-tuning
    results["before"] = {m: CM.eval_ppl(params0, m) for m in CM.WIDTHS}

    # FP16 fine-tuning (no quantized loss)
    st, _ = CM.finetune(params0, "fp16", steps=steps)
    results["fp16"] = {m: CM.eval_ppl(st.params, m) for m in CM.WIDTHS}

    # fixed-precision fine-tuning: one run per width, evaluated at its width
    results["fixed"] = {}
    for m in CM.WIDTHS:
        st, _ = CM.finetune(params0, "fixed", fixed_m=m, steps=steps)
        results["fixed"][m] = CM.eval_ppl(st.params, m)

    # OTARo: once for all widths
    st, _ = CM.finetune(params0, "otaro", steps=steps)
    results["otaro"] = {m: CM.eval_ppl(st.params, m) for m in CM.WIDTHS}

    log("\n== bench_task_ppl (paper Fig.7 / Table 8 analog) ==")
    log(f"{'method':8s} " + " ".join(f"E5M{m:<4d}" for m in CM.WIDTHS)
        + "   AVG    STD")
    for name in ("before", "fp16", "fixed", "otaro"):
        vals = [results[name][m] for m in CM.WIDTHS]
        log(f"{name:8s} " + " ".join(f"{v:7.3f}" for v in vals)
            + f" {np.mean(vals):6.3f} {np.std(vals):6.3f}")
    return results


if __name__ == "__main__":
    run()
