"""Paper Figs. 4/5/6 — gradient-space analyses of SEFP quantization.

Fig. 4: cosine similarity between gradients at different bit-widths (per
        projector) — higher widths align better with everything.
Fig. 5: error of gradient norms ||grad_sefp|| - ||grad_fp|| across widths —
        oscillation grows as width shrinks.
Fig. 6 / Appendix B: LSM fit grad_sefp = X grad_fp + Y over batches;
        E[Y] ~ 0 (the property LAA exploits).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as CM
from repro.core import otaro as otaro_lib
from repro.core import sefp
from repro.models import model_zoo as Z


_GRAD_CACHE = {}


def _jitted_grads(loss_fn):
    """ONE jitted gradient function with a dynamic mantissa width (m = 0
    selects the unquantized fp path) — avoids recompiling per (batch, m),
    which exhausts the CPU JIT after ~150 executables."""
    key = id(loss_fn)
    if key not in _GRAD_CACHE:
        def f(p, batch, m):
            def quantized(p):
                qp = sefp.quantize_tree(p, m, ste=True)
                return loss_fn(qp, batch)

            def full(p):
                return loss_fn(p, batch)

            return jax.lax.cond(m > 0,
                                lambda p: jax.grad(quantized)(p),
                                lambda p: jax.grad(full)(p), p)
        _GRAD_CACHE[key] = jax.jit(f)
    return _GRAD_CACHE[key]


def _grad_at_width(loss_fn, params, batch, m):
    return _jitted_grads(loss_fn)(params, batch, jnp.int32(m))


def _grad_fp(loss_fn, params, batch):
    return _jitted_grads(loss_fn)(params, batch, jnp.int32(0))


def _flat(tree, path_filter=None):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if path_filter is None or path_filter in name:
            out.append(np.asarray(leaf, np.float64).ravel())
    return np.concatenate(out)


def run(n_batches: int = 24, log=print) -> dict:
    cfg = CM.BENCH_LM
    params = CM.pretrain()
    loss_fn = Z.make_loss_fn(cfg)
    _, task = CM.corpora()

    def batch(i):
        return {k: jnp.asarray(v) for k, v in task.batch(i, 8, 64).items()}

    # ---- Fig. 4: cosine similarity matrix (q-projector analog: wq) --------
    b0 = batch(0)
    grads = {m: _grad_at_width(loss_fn, params, b0, m) for m in CM.WIDTHS}
    cos = np.zeros((len(CM.WIDTHS), len(CM.WIDTHS)))
    for i, mi in enumerate(CM.WIDTHS):
        gi = _flat(grads[mi], "attn/wq")
        for j, mj in enumerate(CM.WIDTHS):
            gj = _flat(grads[mj], "attn/wq")
            cos[i, j] = gi @ gj / (np.linalg.norm(gi) * np.linalg.norm(gj))

    log("\n== bench_gradients: Fig.4 analog — grad cosine (wq) ==")
    log("      " + " ".join(f"M{m}  " for m in CM.WIDTHS))
    for i, mi in enumerate(CM.WIDTHS):
        log(f"M{mi}: " + " ".join(f"{cos[i, j]:.3f}" for j in
                                  range(len(CM.WIDTHS))))

    # paper's key observation: adjacency with HIGHER widths is stronger
    hi_band = np.mean([cos[i, j] for i in range(3) for j in range(3)
                       if i != j])
    lo_vs_hi = np.mean([cos[0, -1], cos[1, -1]])
    log(f"high-width mutual cos {hi_band:.3f} vs M8/M7-to-M3 {lo_vs_hi:.3f}")

    # ---- Fig. 5: ||g_sefp|| - ||g_fp|| oscillation across batches ---------
    norm_err = {m: [] for m in CM.WIDTHS}
    ys = {m: [] for m in (4, 3)}
    gfps = []
    gsefps = {m: [] for m in (4, 3)}
    for i in range(n_batches):
        bi = batch(i)
        gfp = _flat(_grad_fp(loss_fn, params, bi), "attn/wq")
        gfps.append(gfp)
        for m in CM.WIDTHS:
            gs = _flat(_grad_at_width(loss_fn, params, bi, m), "attn/wq")
            norm_err[m].append(np.linalg.norm(gs) - np.linalg.norm(gfp))
            if m in gsefps:
                gsefps[m].append(gs)

    log("\nFig.5 analog — std of ||g_sefp||-||g_fp|| across batches:")
    for m in CM.WIDTHS:
        log(f"  E5M{m}: std={np.std(norm_err[m]):.5f} "
            f"mean={np.mean(norm_err[m]):+.5f}")

    # ---- Fig. 6 / Appendix B: LSM residual Y, E[Y] ~ 0 ---------------------
    G_fp = np.stack(gfps)                       # [N, d]
    results_y = {}
    for m in (4, 3):
        G = np.stack(gsefps[m])                 # [N, d]
        # scalar-X LSM per paper's linear-mapping idea (X diagonal-free):
        # X = argmin ||G - G_fp X||_F over scalar -> <G_fp,G>/<G_fp,G_fp>
        x = float((G_fp * G).sum() / (G_fp * G_fp).sum())
        Y = G - x * G_fp
        results_y[m] = {
            "X": x,
            "E[Y]": float(Y.mean()),
            "E[|Y|]": float(np.abs(Y).mean()),
            "ratio": float(abs(Y.mean()) / (np.abs(Y).mean() + 1e-12)),
        }
        log(f"\nFig.6 analog (E5M{m}): X={x:.4f}  E[Y]={Y.mean():+.2e}  "
            f"E[|Y|]={np.abs(Y).mean():.2e}  |E[Y]|/E[|Y|]="
            f"{results_y[m]['ratio']:.4f} (≈0 ⇒ LAA averaging works)")

    osc = {m: float(np.std(norm_err[m])) for m in CM.WIDTHS}
    return {"cos": cos.tolist(), "norm_err_std": osc, "lsm": results_y}


if __name__ == "__main__":
    run()
