"""Shared benchmark harness: a CPU-sized analog of the paper's protocol.

The paper fine-tunes pretrained LLaMA/Qwen checkpoints.  Offline, we create
the analog: a small LM is PRETRAINED on a base synthetic language, then each
method FINE-TUNES it on a shifted task language (different transition seed),
and evaluation measures PPL / next-token accuracy across all SEFP widths —
the same 4-method x 6-width grid as the paper's tables.

Methods (paper names):
  before      — pretrained, no fine-tuning ("Before Fine-Tuning")
  fp16        — fine-tune without quantized loss ("FP16 Fine-Tuning")
  fixed       — per-width fixed-precision fine-tuning (one model per width)
  otaro       — BPS + LAA, once for all widths ("Ours")
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import otaro as otaro_lib
from repro.core import sefp
from repro.models import model_zoo as Z
from repro.models.config import ModelConfig
from repro.train import data as data_lib
from repro.train import optimizer as opt_lib

WIDTHS = sefp.MANTISSA_WIDTHS  # (8, 7, 6, 5, 4, 3)

BENCH_LM = ModelConfig(
    name="bench-lm", family="dense", n_layers=4, d_model=160, n_heads=4,
    n_kv_heads=2, head_dim=40, d_ff=416, vocab_size=512, q_block=64,
    kv_block=64, loss_chunk=64, remat="none", dtype="float32")

BASE_SEED = 11
TASK_SEED = 11   # same chain as pretraining...


def corpora(vocab=BENCH_LM.vocab_size):
    base = data_lib.SyntheticCorpus(vocab_size=vocab, seed=BASE_SEED)
    # ...but a shifted distribution over it (narrower branching, fewer copy
    # motifs) — fine-tuning adapts, it does not relearn a language.
    task = data_lib.SyntheticCorpus(vocab_size=vocab, seed=TASK_SEED,
                                    p_copy=0.05, branching=8, zipf_a=1.6)
    return base, task


@dataclasses.dataclass
class Trained:
    params: object
    mode: str
    fixed_m: Optional[int] = None


_PRETRAIN_CACHE: dict = {}


def pretrain(cfg: ModelConfig = BENCH_LM, steps: int = 300, batch: int = 16,
             seq: int = 64, lr: float = 3e-3, seed: int = 0):
    """Pretrain the base model (cached per process)."""
    key = (cfg.name, steps, batch, seq, lr, seed)
    if key in _PRETRAIN_CACHE:
        return _PRETRAIN_CACHE[key]
    base, _ = corpora(cfg.vocab_size)
    loss_fn = Z.make_loss_fn(cfg)
    params = Z.init_params(cfg, jax.random.PRNGKey(seed))
    opt = opt_lib.adam(lr)
    ocfg = otaro_lib.OTAROConfig(mode="fp16")
    step = jax.jit(otaro_lib.make_otaro_step(loss_fn, opt, ocfg))
    state = otaro_lib.init_state(params, opt, ocfg)
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in base.batch(i, batch, seq).items()}
        state, _ = step(state, b)
    _PRETRAIN_CACHE[key] = state.params
    return state.params


def finetune(params0, mode: str, cfg: ModelConfig = BENCH_LM,
             steps: int = 300, batch: int = 16, seq: int = 64,
             lr: float = 1e-2, fixed_m: int = 8, lam: float = 5.0,
             laa_n: int = 10, seed: int = 1, corpus=None, widths=WIDTHS):
    """Fine-tune on the task corpus with the given method.  SGD like the
    paper (lr scaled for the small model)."""
    _, task = corpora(cfg.vocab_size)
    corpus = corpus or task
    loss_fn = Z.make_loss_fn(cfg)
    opt = opt_lib.sgd(lr)
    ocfg = otaro_lib.OTAROConfig(mode=mode, fixed_m=fixed_m, lam=lam,
                                 laa_n=laa_n, widths=widths)
    step = jax.jit(otaro_lib.make_otaro_step(loss_fn, opt, ocfg))
    state = otaro_lib.init_state(params0, opt, ocfg)
    metrics_hist = []
    for i in range(steps):
        b = {k: jnp.asarray(v)
             for k, v in corpus.batch(1000 + seed * 131 + i, batch,
                                      seq).items()}
        state, m = step(state, b)
        metrics_hist.append({"loss": float(m["loss"]),
                             "m": int(m["mantissa_width"])})
    return state, metrics_hist


_EVAL_CACHE: dict = {}


def _eval_fns(cfg: ModelConfig):
    """Jitted (loss, accuracy) eval fns with dynamic width — compiled once
    per config (not per call; repeated jax.jit would exhaust the CPU JIT)."""
    if cfg.name in _EVAL_CACHE:
        return _EVAL_CACHE[cfg.name]
    from repro.models import layers as L
    from repro.models import transformer as T

    loss_fn = Z.make_loss_fn(cfg)
    evalf = jax.jit(otaro_lib.make_eval_fn(loss_fn, otaro_lib.OTAROConfig()))

    @jax.jit
    def acc_fn(params, batch, m):
        qp = sefp.quantize_tree(params, m, ste=False)
        x = L.embed(qp["embed"], batch["inputs"], jnp.float32)
        h = T.lm_apply_hidden(qp, x, cfg)
        logits = h @ qp["unembed"]["w_unembed"]
        pred = jnp.argmax(logits, -1)
        return jnp.mean((pred == batch["targets"]).astype(jnp.float32))

    _EVAL_CACHE[cfg.name] = (evalf, acc_fn)
    return _EVAL_CACHE[cfg.name]


def eval_ppl(params, m_width: int, cfg: ModelConfig = BENCH_LM,
             n_batches: int = 4, batch: int = 16, seq: int = 64,
             corpus=None) -> float:
    """Perplexity at SEFP width m on held-out task data."""
    _, task = corpora(cfg.vocab_size)
    corpus = corpus or task
    evalf, _ = _eval_fns(cfg)
    losses = []
    for b in corpus.eval_batches(n_batches, batch, seq):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        losses.append(float(evalf(params, b, jnp.int32(m_width))))
    return float(np.exp(np.mean(losses)))


def eval_accuracy(params, m_width: int, cfg: ModelConfig = BENCH_LM,
                  n_batches: int = 4, batch: int = 16, seq: int = 64,
                  corpus=None) -> float:
    """Next-token top-1 accuracy at SEFP width m (the zero-shot analog)."""
    _, task = corpora(cfg.vocab_size)
    corpus = corpus or task
    _, acc_fn = _eval_fns(cfg)
    accs = []
    for b in corpus.eval_batches(n_batches, batch, seq):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        accs.append(float(acc_fn(params, b, jnp.int32(m_width))))
    return float(np.mean(accs))


def timed(fn, *args, n_iter: int = 20, warmup: int = 3) -> float:
    """us per call (block_until_ready)."""
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n_iter * 1e6
