"""Paper Fig. 3 — uniform sampling vs BPS vs fixed-precision fine-tuning.

Reports PPL change of uniform/BPS RELATIVE to per-width fixed-precision
fine-tuning (negative = better than fixed).  Paper finding: uniform sampling
falls short of fixed at several widths; BPS matches or beats fixed.
Also dumps the BPS selection path (which width each batch trained on).
"""

from __future__ import annotations

import numpy as np

from benchmarks import common as CM


def run(steps: int = 300, log=print) -> dict:
    params0 = CM.pretrain()

    fixed = {}
    for m in CM.WIDTHS:
        st, _ = CM.finetune(params0, "fixed", fixed_m=m, steps=steps)
        fixed[m] = CM.eval_ppl(st.params, m)

    st_u, _ = CM.finetune(params0, "uniform", steps=steps)
    uniform = {m: CM.eval_ppl(st_u.params, m) for m in CM.WIDTHS}

    st_b, hist = CM.finetune(params0, "bps_only", steps=steps)
    bps = {m: CM.eval_ppl(st_b.params, m) for m in CM.WIDTHS}

    path = [h["m"] for h in hist]
    counts = {m: path.count(m) for m in CM.WIDTHS}

    log("\n== bench_bps_path (paper Fig.3 analog; dPPL vs fixed) ==")
    log(f"{'method':8s} " + " ".join(f"E5M{m:<6d}" for m in CM.WIDTHS))
    for name, vals in (("uniform", uniform), ("bps", bps)):
        ds = [vals[m] - fixed[m] for m in CM.WIDTHS]
        log(f"{name:8s} " + " ".join(f"{d:+8.4f}" for d in ds))
    log(f"BPS selection counts over {steps} steps: {counts}")
    log(f"BPS path last 40: {path[-40:]}")
    return {"fixed": fixed, "uniform": uniform, "bps": bps,
            "bps_counts": counts}


if __name__ == "__main__":
    run()
