"""Roofline analysis: three-term model per (arch x shape) on the single-pod
production mesh (16x16 = 256 TPU v5e chips).

    compute term    = FLOPs / (chips x 197e12 FLOP/s bf16)
    memory term     = HBM bytes / (chips x 819e9 B/s)
    collective term = wire bytes per chip / 50e9 B/s per ICI link

Primary source: the documented analytic cost model (benchmarks/costmodel.py)
— XLA's cost_analysis counts while-loop bodies once (probe recorded in
EXPERIMENTS.md §Dry-run), so HLO flops understate scanned stacks by ~L.  The
dry-run's compiled artifacts supply per-device memory (loop-aware) and the
collective op inventory; HLO collective bytes are reported with loop-body
ops scaled by the dominant trip count as a cross-check.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--csv out]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Optional

from repro import configs as C
from repro.models.config import SHAPES, shape_applicable

from benchmarks import costmodel

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
CHIPS = 256
DP, TP = 16, 16

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def load_artifact(arch: str, shape: str, mesh: str) -> Optional[dict]:
    p = os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def roofline_row(arch: str, shape_name: str, mesh: str = "single") -> dict:
    cfg = C.get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    row = {"arch": arch, "shape": shape_name}
    if not ok:
        row.update(status="skipped", reason=reason)
        return row

    cost = costmodel.cell_cost(cfg, shape, n_pods=1, tp=TP, dp=DP)
    t_compute = cost.flops / (CHIPS * PEAK_FLOPS)
    t_memory = cost.hbm_bytes / (CHIPS * HBM_BW)
    # wire bytes per chip: TP all-reduce ~ 2x shard bytes (ring), shard =
    # whole-tensor bytes / dp; FSDP/DP terms are already per-chip scale.
    wire_model = 2.0 * cost.coll_bytes_model / DP
    wire_data = cost.coll_bytes_data
    t_coll = (wire_model + wire_data) / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())  # perfect-overlap bound
    mfu_bound = cost.model_flops / (CHIPS * PEAK_FLOPS) / step_time

    row.update(
        status="ok",
        n_params=cost.n_params,
        n_active=cost.n_active_params,
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_coll,
        dominant=dominant,
        model_flops=cost.model_flops,
        analytic_flops=cost.flops,
        useful_flops_frac=cost.model_flops / max(cost.flops, 1.0),
        roofline_fraction=mfu_bound,
    )

    art = load_artifact(arch, shape_name, mesh)
    if art and art.get("status") == "ok":
        L = cfg.n_layers
        coll = art["collectives"]
        row.update(
            hlo_flops_per_dev=art.get("flops"),
            hlo_bytes_per_dev=art.get("bytes_accessed"),
            hlo_mem_per_dev_gib=art["memory"]["per_device_total"] / 2 ** 30,
            hlo_coll_bytes_raw=coll.get("total_bytes"),
            hlo_coll_bytes_loop_scaled=(coll.get("top_level_bytes", 0)
                                        + coll.get("loop_bytes", 0) * L),
            compile_s=art.get("compile_s"),
        )
    return row


def full_table(mesh: str = "single"):
    rows = []
    for arch in C.ASSIGNED:
        for shape_name in SHAPES:
            rows.append(roofline_row(arch, shape_name, mesh))
    return rows


def format_table(rows) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'dom':10s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} {'useful':>7s} {'roofl%':>7s} "
           f"{'HLOmem/dev':>11s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} SKIPPED "
                         f"({r.get('reason', '')[:60]})")
            continue
        mem = r.get("hlo_mem_per_dev_gib")
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['dominant']:10s} "
            f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
            f"{r['t_collective_s']:9.2e} {r['useful_flops_frac']:7.2f} "
            f"{100 * r['roofline_fraction']:6.1f}% "
            f"{(f'{mem:8.2f}GiB' if mem is not None else '      n/a'):>11s}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = full_table(args.mesh)
    print(format_table(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
