"""Paper Fig. 8 — ablations: strategies (BPS ± LAA), exploration coefficient
lambda, LAA delay N.

Paper findings to reproduce qualitatively:
  * BPS+LAA (full OTARo) >= BPS-only, biggest gap at low widths;
  * lambda = 5 balances exploration vs exploitation (3..7 sweep);
  * N = 10 beats 5 (too little smoothing) and 20 (too few updates).
"""

from __future__ import annotations

import numpy as np

from benchmarks import common as CM


def _avg_ppl(params):
    vals = [CM.eval_ppl(params, m) for m in CM.WIDTHS]
    return float(np.mean(vals)), {m: v for m, v in zip(CM.WIDTHS, vals)}


def run(steps: int = 300, log=print) -> dict:
    params0 = CM.pretrain()
    out = {}

    # --- strategies ---------------------------------------------------------
    st_b, _ = CM.finetune(params0, "bps_only", steps=steps)
    avg_b, per_b = _avg_ppl(st_b.params)
    st_o, _ = CM.finetune(params0, "otaro", steps=steps)
    avg_o, per_o = _avg_ppl(st_o.params)
    out["strategies"] = {"bps_only": avg_b, "otaro": avg_o,
                         "bps_only_per": per_b, "otaro_per": per_o}
    log("\n== bench_ablation (paper Fig.8 analog) ==")
    log(f"strategies: BPS-only avgPPL={avg_b:.3f}  "
        f"BPS+LAA avgPPL={avg_o:.3f}  "
        f"(low-width E5M3: {per_b[3]:.3f} vs {per_o[3]:.3f})")

    # --- lambda sweep --------------------------------------------------------
    out["lambda"] = {}
    for lam in (3.0, 4.0, 5.0, 6.0, 7.0):
        st, _ = CM.finetune(params0, "otaro", steps=steps, lam=lam)
        avg, _ = _avg_ppl(st.params)
        out["lambda"][lam] = avg
    log("lambda sweep (avg PPL): " +
        "  ".join(f"λ={k}:{v:.3f}" for k, v in out["lambda"].items()))

    # --- N sweep --------------------------------------------------------------
    out["N"] = {}
    for n in (5, 10, 20):
        st, _ = CM.finetune(params0, "otaro", steps=steps, laa_n=n)
        avg, _ = _avg_ppl(st.params)
        out["N"][n] = avg
    log("LAA N sweep (avg PPL):  " +
        "  ".join(f"N={k}:{v:.3f}" for k, v in out["N"].items()))
    return out


if __name__ == "__main__":
    run()
