"""Analytic per-cell cost model for the roofline analysis.

Why analytic: XLA's ``compiled.cost_analysis()`` counts each while-loop body
ONCE regardless of trip count (verified by probe in EXPERIMENTS.md §Dry-run;
a jit'd 8-iteration scan of matmuls reports exactly 1 matmul of flops).
Every deep stack here is a scan-over-layers, so HLO flops/bytes understate
per-step cost by ~n_layers.  The roofline therefore uses this documented
analytic model for FLOPs/HBM-bytes/collective-bytes, and the dry-run's HLO
numbers are recorded alongside for cross-checks (per-device memory from
``memory_analysis()`` IS loop-aware and is used directly).

Conventions:
  * FLOPs = 2 x MACs; attention scores are counted over FULL SxS blocks
    (what the blockwise implementation executes — causal-block skipping is
    listed as a perf opportunity, not silently assumed);
  * train cost = 3x forward (1 fwd + 2 bwd) + SEFP fake-quant overhead
    (elementwise, ~6 flops/param, negligible) ;
  * bytes are per-step whole-model; the roofline divides by chip count;
  * collective model (per step): FSDP params all-gather + grads
    reduce-scatter (~2x param bytes), TP 2 activation all-reduces per layer,
    DP/pod gradient all-reduce when the pod axis exists.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class CellCost:
    flops: float               # per step, whole model (all chips together)
    hbm_bytes: float           # per step, whole model
    coll_bytes_model: float    # TP collectives (over the `model` axis)
    coll_bytes_data: float     # FSDP/DP collectives (over `data` + `pod`)
    model_flops: float         # 6*N(_active)*D reference
    n_params: int
    n_active_params: int
    detail: Dict[str, float]


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total params, active params per token) from the config algebra."""
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    L = cfg.n_layers

    def attn_params():
        return d * H * hd + 2 * d * KV * hd + H * hd * d

    emb = V * d * 2  # embed + unembed
    if cfg.family in ("dense", "vlm"):
        per_layer = attn_params() + 3 * d * f
        total = emb + L * per_layer
        return total, total
    if cfg.family == "moe":
        e, k = cfg.n_experts, cfg.top_k
        expert = 3 * d * f
        per_layer = attn_params() + d * e + e * expert
        per_layer_active = attn_params() + d * e + k * expert
        return emb + L * per_layer, emb + L * per_layer_active
    if cfg.family == "rwkv":
        per_layer = 5 * d * d + 2 * d * 64 + (2 * d * f + d * d)
        total = emb + L * per_layer
        return total, total
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        N = cfg.ssm_state
        Hs = d_in // cfg.ssm_head_dim
        mamba = d * (2 * d_in + 2 * N + Hs) + d_in * d
        shared = cfg.n_shared_attn_blocks * (
            2 * d * d + attn_params() + 3 * d * f)
        total = emb + L * mamba + shared
        return total, total
    if cfg.family == "encdec":
        enc = cfg.n_enc_layers * (attn_params() + 3 * d * f)
        dec = cfg.n_dec_layers * (2 * attn_params() + 3 * d * f)
        total = emb + enc + dec
        return total, total
    raise ValueError(cfg.family)


def _attn_flops(B, S, S_kv, d, H, KV, hd, causal_note_full=True):
    proj = 2 * B * S * (d * H * hd + 2 * d * KV * hd + H * hd * d)
    scores = 2 * B * H * S * S_kv * hd * 2  # qk^T + pv
    return proj, scores


def forward_flops(cfg: ModelConfig, B: int, S: int, kind: str) -> Dict[str, float]:
    """Whole-model forward FLOPs by component.  kind: train/prefill => full
    sequence; decode/long_decode => one token vs a cache of length S."""
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    L = cfg.n_layers
    decode = kind in ("decode", "long_decode")
    T = B * (1 if decode else S)
    S_q = 1 if decode else S
    S_kv = S

    out: Dict[str, float] = {}
    if cfg.family in ("dense", "vlm", "moe"):
        proj, scores = _attn_flops(B, S_q, S_kv, d, H, KV, hd)
        out["attn_proj"] = L * proj
        out["attn_scores"] = L * scores
        if cfg.family == "moe":
            e, k = cfg.n_experts, cfg.top_k
            if decode:
                # dense-dispatch decode: all experts computed
                out["moe_ffn"] = L * 2 * T * 3 * d * f * e
            else:
                cap = k * cfg.moe_capacity_factor
                out["moe_ffn"] = L * 2 * T * cap * 3 * d * f
            out["router"] = L * 2 * T * d * e
        else:
            out["mlp"] = L * 2 * T * 3 * d * f
    elif cfg.family == "rwkv":
        out["proj"] = L * 2 * T * 5 * d * d
        Lc = cfg.rwkv_chunk if not decode else 1
        # intra-chunk pairwise decay + A@v + state update
        out["wkv"] = L * (2 * T * Lc * d * 2 + 2 * T * d * cfg.rwkv_head_dim * 2)
        out["cmix"] = L * 2 * T * (2 * d * f + d * d)
    elif cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        N = cfg.ssm_state
        out["ssm_proj"] = L * 2 * T * (d * (2 * d_in + 2 * N +
                                            d_in // cfg.ssm_head_dim)
                                       + d_in * d)
        Lc = cfg.ssm_chunk if not decode else 1
        out["ssd"] = L * (2 * T * Lc * (N + d_in) + 2 * T * d_in * N * 2)
        n_inv = math.ceil(L / cfg.attn_every)
        proj, scores = _attn_flops(B, S_q, S_kv, d, H, KV, hd)
        out["shared_attn"] = n_inv * (proj + scores + 2 * B * S_q * (
            3 * d * f + 2 * d * d))
    elif cfg.family == "encdec":
        S_enc = max(64, S // 4)
        T_enc = B * S_enc
        proj_e, scores_e = _attn_flops(B, S_enc, S_enc, d, H, KV, hd)
        out["encoder"] = 0 if decode else cfg.n_enc_layers * (
            proj_e + scores_e + 2 * T_enc * 3 * d * f)
        proj_d, scores_d = _attn_flops(B, S_q, S_kv, d, H, KV, hd)
        _, scores_x = _attn_flops(B, S_q, S_enc, d, H, KV, hd)
        proj_x = 2 * B * S_q * (d * H * hd + H * hd * d) + (
            0 if decode else 2 * T_enc * 2 * d * KV * hd)
        out["decoder"] = cfg.n_dec_layers * (
            proj_d + scores_d + proj_x + scores_x + 2 * T * 3 * d * f)
    else:
        raise ValueError(cfg.family)

    out["logits"] = 2 * (B if decode else T) * d * V
    return out


def cell_cost(cfg: ModelConfig, shape: ShapeConfig,
              n_pods: int = 1, tp: int = 16, dp: int = 16,
              layout: str = "tp") -> CellCost:
    """layout="tp" (default): megatron TP over the model axis — 2 activation
    all-reduces/layer.  layout="dp": pure data/FSDP parallelism — no TP
    collectives; per-chip wire cost = per-layer weight all-gather (bf16)
    + gradient reduce-scatter (fp32) over all chips (the §Perf dp variant)."""
    B, S, kind = shape.global_batch, shape.seq_len, shape.kind
    n_params, n_active = param_counts(cfg)
    comp = forward_flops(cfg, B, S, kind)
    fwd = sum(comp.values())
    decode = kind in ("decode", "long_decode")
    train = kind == "train"

    if train:
        flops = 3 * fwd + 8 * n_params  # fwd + 2x bwd + fake-quant elementwise
        tokens = B * S
        model_flops = 6.0 * n_active * tokens
    else:
        flops = fwd
        tokens = B * (1 if decode else S)
        model_flops = 2.0 * n_active * tokens

    # ---- HBM bytes (whole model per step) --------------------------------
    d = cfg.d_model
    act_layers = cfg.n_layers + getattr(cfg, "n_dec_layers", 0)
    if train:
        # fp32 master read (fwd+bwd) + grad/LAA write + bf16 activations
        weight_traffic = n_params * 4 * 4
        act_traffic = 3 * tokens * d * act_layers * 2 * 4  # saved+recompute
        cache_traffic = 0.0
    elif kind == "prefill":
        weight_traffic = n_params * 2
        act_traffic = tokens * d * act_layers * 2 * 4
        cache_traffic = _cache_bytes(cfg, B, S)
    else:
        weight_traffic = n_active * 2          # bf16 stream (active weights)
        act_traffic = tokens * d * act_layers * 2 * 8
        cache_traffic = _cache_bytes(cfg, B, S) * 1.0   # read the full cache
    hbm = weight_traffic + act_traffic + cache_traffic

    # ---- collectives ------------------------------------------------------
    if train:
        # FSDP all-gather (bf16 compute copies) + reduce-scatter grads (fp32)
        coll_data = n_params * 2 + n_params * 4
        if n_pods > 1:
            coll_data += n_params * 4  # cross-pod grad all-reduce
        if layout == "dp":
            coll_model = 0.0  # no TP activation collectives
        else:
            # TP: 2 activation all-reduces per layer, fwd+bwd
            coll_model = 2 * act_layers * tokens * d * 2 * 3
    elif kind == "prefill":
        coll_data = 0.0
        coll_model = 2 * act_layers * tokens * d * 2
    else:
        coll_data = 0.0
        coll_model = 2 * act_layers * tokens * d * 2
        # seq-sharded KV decode: per-layer partial-softmax combine
        coll_model += act_layers * B * cfg.n_heads * cfg.hd * 4 * 2
    return CellCost(flops=flops, hbm_bytes=hbm,
                    coll_bytes_model=coll_model, coll_bytes_data=coll_data,
                    model_flops=model_flops, n_params=n_params,
                    n_active_params=n_active,
                    detail={k: float(v) for k, v in comp.items()})


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    if cfg.family in ("dense", "vlm", "moe"):
        return 2.0 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd * 2
    if cfg.family == "rwkv":
        hd = cfg.rwkv_head_dim
        H = cfg.d_model // hd
        return cfg.n_layers * B * (H * hd * hd * 4 + 2 * cfg.d_model * 2)
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        Hs = d_in // cfg.ssm_head_dim
        ssm = cfg.n_layers * B * (Hs * cfg.ssm_head_dim * cfg.ssm_state * 4)
        n_inv = math.ceil(cfg.n_layers / cfg.attn_every)
        attn = 2.0 * n_inv * B * S * cfg.n_kv_heads * cfg.hd * 2
        return ssm + attn
    if cfg.family == "encdec":
        S_enc = max(64, S // 4)
        self_kv = 2.0 * cfg.n_dec_layers * B * S * cfg.n_kv_heads * cfg.hd * 2
        cross = 2.0 * cfg.n_dec_layers * B * S_enc * cfg.n_kv_heads * cfg.hd * 2
        return self_kv + cross
    raise ValueError(cfg.family)
