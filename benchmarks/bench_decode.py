"""Decode-path perf trajectory: fused scan vs per-token loop vs materialized.

Three decode paths over the SAME weights, measured on a CPU-sized serving
config (absolute numbers are hardware-relative; the *structure* — dispatch
count, host syncs, switch cost — is what transfers to TPU):

  fused_scan             engine.generate: one jitted lax.scan over steps,
                         precision schedule traced in-graph, sampling in the
                         scan body, ONE host transfer per generation.
  per_token              engine.generate_per_token: the legacy loop — one
                         jitted dispatch and one host token sync per step,
                         same packed-master numerics.
  per_token_materialized the pre-device-resident engine: live weights
                         rebuilt by core.packed.dequantize_tree at the
                         serving width (O(params) per switch), one jitted
                         dispatch + host sync per step.

Also measured: precision-switch cost — the materialized path's rebuild
latency vs the fused path's throughput under a worst-case mixed schedule
(alternating widths every token; the schedule is data of the same compiled
executable, so the expected overhead is ~0) — and, since schema v2, server
STARTUP cost: constructing the engine from fp32 params (the O(params)
quantize/pack pass the old lifecycle paid on every serve start) vs from a
saved repro.artifact (pre-packed bytes straight to device — the startup
analogue of the switch-cost fix).

Writes BENCH_decode.json at the repo root.  CI runs ``--smoke`` and then
``--check`` (schema assertion) and uploads the JSON as an artifact, so
every PR extends the decode perf trajectory.

    PYTHONPATH=src python benchmarks/bench_decode.py [--smoke] [--out PATH]
    PYTHONPATH=src python benchmarks/bench_decode.py --check PATH
"""

from __future__ import annotations

import argparse
import json
import sys
import time

SCHEMA_VERSION = 2
PATHS = ("fused_scan", "per_token", "per_token_materialized")


# ---------------------------------------------------------------------------
# schema (the --check contract; keep in sync with emit())
# ---------------------------------------------------------------------------

def check_schema(doc: dict) -> list:
    """Return a list of schema violations (empty == valid)."""
    errs = []

    def need(d, key, typ, where):
        if key not in d:
            errs.append(f"{where}: missing key {key!r}")
            return None
        if not isinstance(d[key], typ):
            errs.append(f"{where}.{key}: expected {typ}, got "
                        f"{type(d[key]).__name__}")
        return d[key]

    if need(doc, "schema_version", int, "$") != SCHEMA_VERSION:
        errs.append(f"$.schema_version != {SCHEMA_VERSION}")
    need(doc, "bench", str, "$")
    need(doc, "mode", str, "$")
    cfg = need(doc, "config", dict, "$") or {}
    for k in ("name", "family", "n_layers", "d_model", "vocab_size",
              "batch", "prompt_len", "max_new"):
        need(cfg, k, (int, str), "$.config")
    paths = need(doc, "paths", dict, "$") or {}
    for p in PATHS:
        entry = need(paths, p, dict, "$.paths") or {}
        need(entry, "tokens_per_sec", (int, float), f"$.paths.{p}")
        need(entry, "decode_seconds", (int, float), f"$.paths.{p}")
        need(entry, "host_transfers_per_generation", int, f"$.paths.{p}")
    need(doc, "speedup_fused_vs_per_token", (int, float), "$")
    sw = need(doc, "precision_switch", dict, "$") or {}
    for k in ("materialized_rebuild_seconds", "fused_constant_tokens_per_sec",
              "fused_mixed_tokens_per_sec",
              "fused_switch_extra_seconds_per_token"):
        need(sw, k, (int, float), "$.precision_switch")
    st = need(doc, "startup", dict, "$") or {}
    for k in ("pack_from_fp32_seconds", "artifact_load_seconds",
              "speedup_artifact_vs_pack"):
        need(st, k, (int, float), "$.startup")
    need(st, "artifact_bytes", int, "$.startup")
    return errs


# ---------------------------------------------------------------------------
# the materialized baseline (the engine this PR deleted, kept here as the
# measured point of comparison)
# ---------------------------------------------------------------------------

class MaterializedBaseline:
    """Pre-device-resident serving: pack once, but materialize a full live
    weight tree per precision switch and dispatch per token."""

    def __init__(self, cfg, params, max_len):
        import jax
        from repro.core import packed as packed_lib
        from repro.models import model_zoo as Z

        self.cfg = cfg
        self.max_len = max_len
        self.master = packed_lib.pack_tree(params)
        self._serve = jax.jit(Z.make_serve_step(cfg))
        self._prefill = jax.jit(Z.make_prefill(cfg),
                                static_argnames=("max_len",))
        self._m = None
        self._live = None

    def set_precision(self, m: int):
        import jax
        import jax.numpy as jnp
        from repro.core import packed as packed_lib

        if m == self._m:
            return
        self._live = packed_lib.dequantize_tree(
            self.master, jnp.int32(m), dtype=jnp.bfloat16)
        jax.block_until_ready(self._live)
        self._m = m

    def generate_greedy(self, prompts, max_new: int):
        import jax.numpy as jnp
        import numpy as np

        toks = jnp.asarray(prompts, jnp.int32)
        logits, cache = self._prefill(self._live, toks, max_len=self.max_len)
        out = []
        t0 = time.perf_counter()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(max_new):
            out.append(np.asarray(tok))  # per-step host sync
            logits, cache = self._serve(self._live, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        dt = time.perf_counter() - t0
        return np.stack(out, axis=1), dt, len(out)


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _best(fn, repeats: int):
    """(tokens, seconds, host_transfers) of the fastest of ``repeats``."""
    best = None
    for _ in range(repeats):
        r = fn()
        if best is None or r[1] < best[1]:
            best = r
    return best


def run(smoke: bool = False) -> dict:
    import jax
    import numpy as np
    from repro.models import model_zoo as Z
    from repro.models.config import ModelConfig
    from repro.serve import SwitchableServer

    max_new = 8 if smoke else 64
    batch, prompt_len = 4, 16
    repeats = 2 if smoke else 5
    cfg = ModelConfig(
        name="bench-decode", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        q_block=16, kv_block=16, loss_chunk=32, remat="none",
        dtype="bfloat16")
    max_len = prompt_len + max_new + 1

    params = Z.init_params(cfg, jax.random.PRNGKey(0))
    server = SwitchableServer(cfg, params, max_len=max_len)
    server.set_precision(7)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    prompts = prompts.astype(np.int32)

    def fused():
        r = server.generate(prompts, max_new=max_new)
        return r.tokens, r.decode_seconds, r.host_transfers

    def per_token():
        r = server.generate_per_token(prompts, max_new=max_new)
        return r.tokens, r.decode_seconds, r.host_transfers

    baseline = MaterializedBaseline(cfg, params, max_len)
    baseline.set_precision(7)

    def materialized():
        return baseline.generate_greedy(prompts, max_new)

    paths = {}
    results = {}
    for name, fn in (("fused_scan", fused), ("per_token", per_token),
                     ("per_token_materialized", materialized)):
        fn()  # warmup / compile
        toks, dt, host = _best(fn, repeats)
        results[name] = toks
        paths[name] = {
            "tokens_per_sec": batch * max_new / max(dt, 1e-9),
            "decode_seconds": dt,
            "host_transfers_per_generation": int(host),
        }

    # the fused scan is an optimization, not a semantics change
    np.testing.assert_array_equal(results["fused_scan"],
                                  results["per_token"])

    # -- precision-switch cost ------------------------------------------------
    # materialized: an O(params) live-tree rebuild per switch
    baseline.set_precision(7)
    t0 = time.perf_counter()
    baseline.set_precision(3)
    rebuild_s = time.perf_counter() - t0
    # fused: worst-case mixed schedule (switch EVERY token) vs constant —
    # both are data through one executable; overhead should be noise
    const_sched = [7] * max_new
    mixed_sched = [7 if i % 2 == 0 else 3 for i in range(max_new)]
    server.generate(prompts, max_new=max_new,
                    precision_schedule=mixed_sched)  # warmup
    _, t_const, _ = _best(
        lambda: (None, server.generate(
            prompts, max_new=max_new,
            precision_schedule=const_sched).decode_seconds, None), repeats)
    _, t_mixed, _ = _best(
        lambda: (None, server.generate(
            prompts, max_new=max_new,
            precision_schedule=mixed_sched).decode_seconds, None), repeats)

    # -- server startup cost --------------------------------------------------
    # fp32 path: every construction pays the O(params) quantize/pack pass;
    # artifact path: load pre-packed bytes, no fp32 pass (repro/artifact.py)
    import tempfile

    from repro import api

    def _construct_from_fp32():
        t0 = time.perf_counter()
        srv = SwitchableServer(cfg, params, max_len=max_len)
        jax.block_until_ready(srv.master)
        return time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as tmp:
        art_path = f"{tmp}/artifact"
        artifact = api.Artifact.from_params(cfg, params)
        artifact.save(art_path)
        art_bytes = int(artifact.memory_report()["total_bytes"])

        def _construct_from_artifact():
            t0 = time.perf_counter()
            srv = api.Artifact.load(art_path).server(max_len=max_len)
            jax.block_until_ready(srv.master)
            return time.perf_counter() - t0

        t_pack = min(_construct_from_fp32() for _ in range(repeats))
        t_load = min(_construct_from_artifact() for _ in range(repeats))

    doc = {
        "schema_version": SCHEMA_VERSION,
        "bench": "decode",
        "mode": "smoke" if smoke else "full",
        "config": {"name": cfg.name, "family": cfg.family,
                   "n_layers": cfg.n_layers, "d_model": cfg.d_model,
                   "vocab_size": cfg.vocab_size, "batch": batch,
                   "prompt_len": prompt_len, "max_new": max_new},
        "paths": paths,
        "speedup_fused_vs_per_token": (
            paths["fused_scan"]["tokens_per_sec"]
            / max(paths["per_token"]["tokens_per_sec"], 1e-9)),
        "precision_switch": {
            "materialized_rebuild_seconds": rebuild_s,
            "fused_constant_tokens_per_sec":
                batch * max_new / max(t_const, 1e-9),
            "fused_mixed_tokens_per_sec":
                batch * max_new / max(t_mixed, 1e-9),
            "fused_switch_extra_seconds_per_token":
                (t_mixed - t_const) / max_new,
        },
        "startup": {
            "pack_from_fp32_seconds": t_pack,
            "artifact_load_seconds": t_load,
            "speedup_artifact_vs_pack": t_pack / max(t_load, 1e-9),
            "artifact_bytes": art_bytes,
        },
    }
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run (CI leg): few tokens, one repeat")
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="validate an existing JSON against the schema "
                    "and exit (no benchmark run)")
    args = ap.parse_args()

    if args.check:
        with open(args.check) as f:
            doc = json.load(f)
        errs = check_schema(doc)
        if errs:
            print("\n".join(errs))
            sys.exit(1)
        print(f"{args.check}: schema v{doc['schema_version']} OK "
              f"(mode={doc['mode']}, fused/per-token speedup "
              f"{doc['speedup_fused_vs_per_token']:.2f}x)")
        return

    doc = run(smoke=args.smoke)
    errs = check_schema(doc)
    assert not errs, errs
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    p = doc["paths"]
    print(f"wrote {args.out} (mode={doc['mode']})")
    for name in PATHS:
        print(f"  {name:24s} {p[name]['tokens_per_sec']:9.1f} tok/s   "
              f"{p[name]['host_transfers_per_generation']:3d} host syncs")
    print(f"  fused vs per-token: "
          f"{doc['speedup_fused_vs_per_token']:.2f}x; materialized switch "
          f"{doc['precision_switch']['materialized_rebuild_seconds']*1e3:.1f}"
          f" ms vs fused extra "
          f"{doc['precision_switch']['fused_switch_extra_seconds_per_token']*1e6:+.1f}"
          f" us/token")
    st = doc["startup"]
    print(f"  startup: pack-from-fp32 {st['pack_from_fp32_seconds']*1e3:.1f}"
          f" ms vs artifact load {st['artifact_load_seconds']*1e3:.1f} ms "
          f"({st['speedup_artifact_vs_pack']:.2f}x)")


if __name__ == "__main__":
    main()
