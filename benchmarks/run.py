"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints a ``name,us_per_call,derived`` CSV summary at the end (per-benchmark
detail tables are printed inline).  The roofline/dry-run artifacts are
consumed by ``python -m benchmarks.roofline`` (see EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer fine-tuning steps (smoke mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    steps = 80 if args.quick else 300
    rows = []

    def bench(name, fn, derived_fn):
        if args.only and args.only != name:
            return
        t0 = time.perf_counter()
        out = fn()
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((name, dt, derived_fn(out)))

    from benchmarks import (bench_ablation, bench_bps_path, bench_gradients,
                            bench_memory_speed, bench_task_ppl,
                            bench_zeroshot)

    bench("task_ppl_table8", lambda: bench_task_ppl.run(steps=steps),
          lambda o: "otaro_avg_ppl=%.3f" % float(
              np.mean(list(o["otaro"].values()))))
    bench("zeroshot_table1", lambda: bench_zeroshot.run(steps=steps),
          lambda o: "otaro_avg_acc=%.4f" % float(
              np.mean(list(o["otaro"].values()))))
    bench("bps_path_fig3", lambda: bench_bps_path.run(steps=steps),
          lambda o: "bps_counts=" + str(o["bps_counts"]).replace(",", ";"))
    bench("gradients_fig456", bench_gradients.run,
          lambda o: "EY_ratio_m3=%.4f" % o["lsm"][3]["ratio"])
    bench("ablation_fig8", lambda: bench_ablation.run(steps=steps),
          lambda o: "otaro=%.3f;bps_only=%.3f" % (
              o["strategies"]["otaro"], o["strategies"]["bps_only"]))
    bench("memory_speed_table2", bench_memory_speed.run,
          lambda o: "reduction=%.3f;speedup_bound=%.2f" % (
              o["reduction"], o["speedup_bound"]))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
