"""Paper Table 1 (and Tables 3-7) — zero-shot accuracy across bit-widths.

The paper fine-tunes on Alpaca and evaluates 8 QA benchmarks.  CPU analog:
fine-tune on the task corpus, evaluate next-token top-1 accuracy on FOUR
held-out "task suites" (synthetic corpora with shifted statistics — the
multi-benchmark analog) and report the average per width for each method.
Expected: OTARo's average accuracy >= fixed-precision at every width, with
the largest wins at E5M4/E5M3.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common as CM
from repro.train import data as data_lib

# four "benchmarks" = four shifted distributions over the SAME language
# (same successor structure, different branching/copy statistics) — the
# multi-benchmark zero-shot analog; all solvable by a model that learned
# the base chain and adapted to the task shift.
TASK_SUITES = [
    dict(seed=CM.TASK_SEED, p_copy=0.05, branching=8, zipf_a=1.6),  # task
    dict(seed=CM.TASK_SEED, p_copy=0.10, branching=8, zipf_a=1.6),
    dict(seed=CM.TASK_SEED, p_copy=0.05, branching=6, zipf_a=1.6),
    dict(seed=CM.TASK_SEED, p_copy=0.02, branching=12, zipf_a=1.4),
]


def _suites():
    return [data_lib.SyntheticCorpus(vocab_size=CM.BENCH_LM.vocab_size, **kw)
            for kw in TASK_SUITES]


def _avg_acc(params, m):
    return float(np.mean([
        CM.eval_accuracy(params, m, corpus=c, n_batches=2)
        for c in _suites()]))


def run(steps: int = 300, log=print) -> dict:
    params0 = CM.pretrain()
    results = {}

    results["before"] = {m: _avg_acc(params0, m) for m in CM.WIDTHS}

    st, _ = CM.finetune(params0, "fp16", steps=steps)
    results["fp16"] = {m: _avg_acc(st.params, m) for m in CM.WIDTHS}

    results["fixed"] = {}
    for m in CM.WIDTHS:
        st, _ = CM.finetune(params0, "fixed", fixed_m=m, steps=steps)
        results["fixed"][m] = _avg_acc(st.params, m)

    st, _ = CM.finetune(params0, "otaro", steps=steps)
    results["otaro"] = {m: _avg_acc(st.params, m) for m in CM.WIDTHS}

    log("\n== bench_zeroshot (paper Table 1 analog; avg top-1 acc %) ==")
    log(f"{'method':8s} " + " ".join(f"E5M{m:<5d}" for m in CM.WIDTHS))
    for name in ("before", "fp16", "fixed", "otaro"):
        vals = [100 * results[name][m] for m in CM.WIDTHS]
        log(f"{name:8s} " + " ".join(f"{v:7.2f}%" for v in vals))
    return results


if __name__ == "__main__":
    run()
