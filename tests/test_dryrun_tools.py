"""Tests for the dry-run tooling: HLO collective parsing (incl. loop-body
attribution) and the analytic cost model's consistency with real configs."""

import math

import jax
import pytest

from repro import configs as C
from repro.models import model_zoo as Z
from repro.models.config import SHAPES

from benchmarks import costmodel
from repro.launch.dryrun import parse_collective_bytes

FAKE_HLO = """
HloModule test

%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar.1 = f32[128,256] all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
  %cp.1 = f32[64]{0} collective-permute(%y), source_target_pairs={{0,1}}
}

%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %lt = pred[] compare(%a, %b), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1
  %ag.1 = bf16[512,512] all-gather(%z), replica_groups=[2,2]<=[4], dimensions={0}
  %rs.1 = (f32[16,16], f32[16,16]) reduce-scatter(%u, %v), dimensions={0}
}
"""


class TestCollectiveParse:
    def test_counts_and_bytes(self):
        out = parse_collective_bytes(FAKE_HLO)
        assert out["all-reduce"]["count"] == 1
        assert out["all-reduce"]["bytes"] == 128 * 256 * 4
        assert out["all-gather"]["count"] == 1
        assert out["all-gather"]["bytes"] == 512 * 512 * 2
        # tuple output: both elements counted
        assert out["reduce-scatter"]["bytes"] == 2 * 16 * 16 * 4
        assert out["collective-permute"]["bytes"] == 64 * 4

    def test_loop_attribution(self):
        out = parse_collective_bytes(FAKE_HLO)
        # ops inside %body.1 are loop bytes; entry ops are top-level
        assert out["all-reduce"]["loop_bytes"] == 128 * 256 * 4
        assert out["collective-permute"]["loop_bytes"] == 64 * 4
        assert out["all-gather"]["loop_bytes"] == 0
        assert out["loop_bytes"] == 128 * 256 * 4 + 64 * 4
        assert out["top_level_bytes"] == (512 * 512 * 2 + 2 * 16 * 16 * 4)


class TestCostModel:
    @pytest.mark.parametrize("arch", C.ASSIGNED)
    def test_param_count_matches_eval_shape(self, arch):
        cfg = C.get_config(arch)
        analytic, _ = costmodel.param_counts(cfg)
        shapes = jax.eval_shape(
            lambda: Z.init_params(cfg, jax.random.PRNGKey(0)))
        real = sum(math.prod(x.shape)
                   for x in jax.tree_util.tree_leaves(shapes))
        assert abs(analytic - real) / real < 0.05, (arch, analytic, real)

    def test_moe_active_less_than_total(self):
        cfg = C.get_config("grok_1_314b")
        total, active = costmodel.param_counts(cfg)
        assert active < 0.5 * total  # top-2 of 8 experts

    @pytest.mark.parametrize("arch", C.ASSIGNED)
    @pytest.mark.parametrize("shape", list(SHAPES))
    def test_cell_cost_finite_positive(self, arch, shape):
        from repro.models.config import shape_applicable
        cfg = C.get_config(arch)
        sh = SHAPES[shape]
        if not shape_applicable(cfg, sh)[0]:
            return
        cost = costmodel.cell_cost(cfg, sh)
        assert cost.flops > 0 and cost.hbm_bytes > 0
        assert cost.model_flops > 0
        # train compute must dominate decode compute by orders of magnitude
        if sh.kind == "train":
            dec = costmodel.cell_cost(cfg, SHAPES["decode_32k"])
            assert cost.flops > 100 * dec.flops

    def test_train_flops_close_to_6nd(self):
        # dense archs: analytic total ~ 6*N*D within ~2.5x (attention+logits
        # overhead on top of the 6ND matmul floor)
        for arch in ("minitron_8b", "yi_9b", "qwen2_1_5b"):
            cfg = C.get_config(arch)
            cost = costmodel.cell_cost(cfg, SHAPES["train_4k"])
            ratio = cost.flops / cost.model_flops
            assert 0.8 < ratio < 2.5, (arch, ratio)
