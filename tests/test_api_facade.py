"""Facade grep-invariants (PR-2 style): the drivers — repro/launch/* and
examples/* — speak ONLY repro.api.

Rationale: before the unified API, precision and packing were wired three
incompatible ways across the drivers (OTAROConfig fields in training, CLI
ints in serving, ad-hoc schedule lists in the examples), and every serve
start re-packed fp32.  The facade makes that wiring internal; these
source-level invariants keep it from leaking back.
"""

import os

import repro.api

SRC_ROOT = os.path.dirname(os.path.abspath(repro.api.__file__))
REPO_ROOT = os.path.dirname(os.path.dirname(SRC_ROOT))
LAUNCH_DIR = os.path.join(SRC_ROOT, "launch")
EXAMPLES_DIR = os.path.join(REPO_ROOT, "examples")

# the internal wiring no driver may touch directly (the three ad-hoc
# precision surfaces this API replaced, plus the packing primitives whose
# presence in a driver would mean an O(params) pack pass on the serve path)
BANNED = (
    "repro.core.packed",
    "repro.serve.packed_step",
    "repro.core.otaro",
    "core import packed",
    "serve import packed_step",
    "core import otaro",
    "otaro_lib",
    "from repro.core import",
    "from repro.serve import",
    "pack_master_params",
    "SwitchableServer(",
    "make_otaro_step",
    "dequantize_tree",
)

# drivers (entry points); launch/mesh.py is shared infrastructure, not a
# driver, but it must respect the ban list too
DRIVERS = [
    os.path.join(LAUNCH_DIR, "train.py"),
    os.path.join(LAUNCH_DIR, "serve.py"),
    os.path.join(LAUNCH_DIR, "dryrun.py"),
    os.path.join(EXAMPLES_DIR, "quickstart.py"),
    os.path.join(EXAMPLES_DIR, "train_otaro.py"),
    os.path.join(EXAMPLES_DIR, "serve_switchable.py"),
]


def _py_files(d):
    return sorted(os.path.join(d, n) for n in os.listdir(d)
                  if n.endswith(".py"))


def test_driver_files_exist():
    for path in DRIVERS:
        assert os.path.exists(path), path


def test_no_internal_wiring_in_launch_or_examples():
    for path in _py_files(LAUNCH_DIR) + _py_files(EXAMPLES_DIR):
        src = open(path).read()
        for banned in BANNED:
            assert banned not in src, (
                f"{os.path.relpath(path, REPO_ROOT)} reaches around the "
                f"repro.api facade: {banned!r}")


def test_every_driver_imports_the_facade():
    for path in DRIVERS:
        src = open(path).read()
        assert ("from repro import api" in src
                or "from repro.api import" in src
                or "import repro.api" in src), (
            f"{os.path.relpath(path, REPO_ROOT)} does not import repro.api")


def test_serve_launcher_has_no_pack_or_quantize_call():
    """The serve startup path must stay O(1) in params: constructing from
    an artifact moves packed bytes only.  The launcher may mention neither
    the pack entry points nor the fp32 quantizer."""
    src = open(os.path.join(LAUNCH_DIR, "serve.py")).read()
    for banned in ("pack_tree", "quantize_tree", "pack_stacked",
                   "init_state"):
        assert banned not in src, banned
