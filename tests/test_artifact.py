"""repro.artifact tests: the train -> export -> load -> serve lifecycle.

The acceptance bar: a server constructed from a saved-then-loaded artifact
produces BITWISE-identical generations to one packed from the original fp32
params at every supported width, and artifact startup performs no O(params)
fp32 quantize/pack pass."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.artifact import ARTIFACT_FORMAT, ARTIFACT_VERSION
from repro.serve import packed_step as packed_step_mod

CFG = api.ModelConfig(name="artifact-tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16, q_block=16, kv_block=16,
                      loss_chunk=16, remat="none", dtype="float32")


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    """A few reduced training steps -> (FinetuneResult, artifact dir)."""
    out = str(tmp_path_factory.mktemp("run"))
    res = api.finetune(CFG, out_dir=out, steps=3, global_batch=2, seq=32,
                       lr=1e-3, ckpt_every=2, log_every=1)
    return res


def prompts(b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, (b, s)).astype(np.int32)


class TestExport:
    def test_finetune_exports_done_marked_artifact(self, trained):
        assert trained.artifact is not None
        assert os.path.exists(os.path.join(trained.artifact_path, "DONE"))
        assert os.path.exists(
            os.path.join(trained.artifact_path, "master.npz"))

    def test_meta_contents(self, trained):
        with open(os.path.join(trained.artifact_path, "meta.json")) as f:
            meta = json.load(f)
        assert meta["format"] == ARTIFACT_FORMAT
        assert meta["version"] == ARTIFACT_VERSION
        assert meta["model"]["name"] == CFG.name
        assert meta["policy"]["widths"] == [8, 7, 6, 5, 4, 3]
        assert meta["pack"]["master_m"] == 8
        assert meta["pack"]["group_size"] == 64
        # BPS visit/loss statistics from the trained state
        assert meta["bps"]["t"] == 3
        assert sum(meta["bps"]["t_b"]) == 3
        assert meta["provenance"]["train_step"] == 3
        assert "jax_version" in meta["provenance"]

    def test_atomic_save_leaves_no_tmp(self, trained, tmp_path):
        art = trained.artifact
        art.save(str(tmp_path / "a"))
        art.save(str(tmp_path / "a"))  # overwrite keeps a valid artifact
        names = os.listdir(tmp_path)
        assert not [n for n in names if n.startswith(".tmp_")]
        assert not [n for n in names if ".old-" in n]
        api.Artifact.load(str(tmp_path / "a"))  # still loadable

    def test_hash_prefixed_dict_key_roundtrips(self, tmp_path):
        """A dict key starting with '#' must survive save->load: its escaped
        token ('\\#x') stays distinguishable from a positional '#0'."""
        tree = {"#odd": {"w": np.ones((4,), np.float32)},
                "plain": np.full((2,), 2.0, np.float32)}
        art = api.Artifact.from_params(CFG, tree)
        art.save(str(tmp_path / "hash"))
        loaded = api.Artifact.load(str(tmp_path / "hash"))
        np.testing.assert_array_equal(
            np.asarray(loaded.master["#odd"]["w"], np.float32),
            np.ones((4,), np.float32))
        np.testing.assert_array_equal(
            np.asarray(loaded.master["plain"], np.float32),
            np.full((2,), 2.0, np.float32))


@pytest.fixture(scope="module")
def srv_pair(trained):
    """(server from saved-then-loaded artifact, server packed from the
    in-memory fp32 params) — one jit cache for all width cases."""
    srv_art = api.Artifact.load(trained.artifact_path).server(max_len=48)
    srv_fp32 = api.SwitchableServer(CFG, trained.state.params, max_len=48)
    return srv_art, srv_fp32


class TestRoundtrip:
    """ISSUE acceptance: bitwise-equal serving at every m in {8, 6, 4, 3}."""

    def test_loaded_master_bit_identical(self, trained):
        art = api.Artifact.load(trained.artifact_path)
        fresh = api.Artifact.from_params(CFG, trained.state.params)
        flat_a = jax.tree_util.tree_leaves(art.master)
        flat_f = jax.tree_util.tree_leaves(fresh.master)
        assert len(flat_a) == len(flat_f)
        for a, f in zip(flat_a, flat_f):
            assert a.dtype == f.dtype
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8), np.asarray(f).view(np.uint8))

    @pytest.mark.parametrize("m", [8, 6, 4, 3])
    def test_server_bitwise_equal_per_width(self, srv_pair, m):
        srv_art, srv_fp32 = srv_pair
        srv_art.set_precision(m)
        srv_fp32.set_precision(m)
        r_art = srv_art.generate(prompts(), max_new=8)
        r_fp32 = srv_fp32.generate(prompts(), max_new=8)
        np.testing.assert_array_equal(r_art.tokens, r_fp32.tokens)
        assert r_art.precision_trace == [m] * 8

    def test_evaluate_matches_between_loaded_and_fresh(self, trained):
        art = api.Artifact.load(trained.artifact_path)
        fresh = api.Artifact.from_params(CFG, trained.state.params)
        from repro.train.data import SyntheticCorpus
        b = {k: jnp.asarray(v) for k, v in SyntheticCorpus(
            vocab_size=CFG.vocab_size, seed=5).batch(0, 2, 32).items()}
        assert art.evaluate(b, widths=(8, 3)) == \
            fresh.evaluate(b, widths=(8, 3))


class TestPackFreeStartup:
    """The startup analogue of the O(1) precision switch: loading an
    artifact and building its server must never run the fp32 quantize/pack
    pass (grep-invariant on the serve path + a runtime trap)."""

    def test_load_and_serve_never_pack(self, trained, monkeypatch):
        def boom(*a, **k):
            raise AssertionError("fp32 pack pass ran on the artifact "
                                 "startup path")
        monkeypatch.setattr(packed_step_mod, "pack_master_params", boom)
        monkeypatch.setattr(api.Artifact, "from_params",
                            classmethod(lambda *a, **k: boom()))
        srv = api.Artifact.load(trained.artifact_path).server(max_len=48)
        toks = srv.generate(prompts(), max_new=4).tokens
        assert toks.shape == (2, 4)

    def test_policy_travels_with_artifact(self, trained):
        art = api.Artifact.load(trained.artifact_path)
        assert art.trained_widths == (8, 7, 6, 5, 4, 3)
        srv = art.server(max_len=48)
        assert srv.policy is not None
        assert srv.precision == 8

    def test_request_class_routing_from_policy(self, trained):
        art = api.Artifact.load(trained.artifact_path)
        policy = (api.PrecisionPolicy.all_widths()
                  .with_class("fast", 3)
                  .with_class("long", [(8, 2), (4, None)]))
        srv = art.server(policy, max_len=48)
        r = srv.generate(prompts(), max_new=4, request_class="fast")
        assert r.precision_trace == [3, 3, 3, 3]
        r = srv.generate(prompts(), max_new=4, request_class="long")
        assert r.precision_trace == [8, 8, 4, 4]
        with pytest.raises(KeyError, match="unknown request class"):
            srv.generate(prompts(), max_new=4, request_class="nope")
        with pytest.raises(ValueError, match="mutually exclusive"):
            srv.generate(prompts(), max_new=4, precision_schedule=[8] * 4,
                         request_class="fast")

    def test_max_new_zero_is_prefill_only(self, trained):
        # must hold on every scheduling path: plain default, a policy with
        # a mid-stream plan, a request class, and the per-token baseline
        art = api.Artifact.load(trained.artifact_path)
        plan_policy = (api.PrecisionPolicy.all_widths()
                       .with_schedule([(8, 2), (4, None)])
                       .with_class("fast", 3))
        for srv, kw in ((art.server(max_len=48), {}),
                        (art.server(plan_policy, max_len=48), {}),
                        (art.server(plan_policy, max_len=48),
                         {"request_class": "fast"})):
            r = srv.generate(prompts(), max_new=0, **kw)
            assert r.tokens.shape == (2, 0)
            assert r.precision_trace == []
        r = art.server(max_len=48).generate_per_token(prompts(), max_new=0)
        assert r.tokens.shape == (2, 0)


class TestErrors:
    def test_load_missing_dir(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="no artifact"):
            api.Artifact.load(str(tmp_path / "nope"))

    def test_load_torn_write(self, tmp_path, trained):
        torn = tmp_path / "torn"
        torn.mkdir()
        (torn / "master.npz").write_bytes(b"garbage")
        with pytest.raises(FileNotFoundError, match="DONE"):
            api.Artifact.load(str(torn))

    def test_load_layout_skew_rejected(self, trained, tmp_path):
        """An artifact packed under different layout constants must refuse
        to load (it would decode to silently wrong weights)."""
        p = str(tmp_path / "skew")
        trained.artifact.save(p)
        meta_path = os.path.join(p, "meta.json")
        with open(meta_path) as f:
            meta = json.load(f)
        meta["pack"]["group_size"] = 32
        with open(meta_path, "w") as f:
            json.dump(meta, f)
        with pytest.raises(ValueError, match="layout constants"):
            api.Artifact.load(p)

    def test_load_wrong_format(self, tmp_path):
        d = tmp_path / "notart"
        d.mkdir()
        (d / "meta.json").write_text(json.dumps({"format": "other"}))
        (d / "DONE").write_text("")
        with pytest.raises(ValueError, match="format"):
            api.Artifact.load(str(d))

    def test_from_checkpoint_no_done_step_lists_contents(self, tmp_path):
        d = tmp_path / "ckpts"
        d.mkdir()
        (d / "step_0000000001").mkdir()  # no DONE: torn write
        (d / "junk.txt").write_text("")
        with pytest.raises(FileNotFoundError) as ei:
            api.Artifact.from_checkpoint(str(d), CFG)
        msg = str(ei.value)
        assert "no DONE-marked checkpoint step" in msg
        assert "junk.txt" in msg and "step_0000000001" in msg

    def test_from_checkpoint_missing_dir(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="does not exist"):
            api.Artifact.from_checkpoint(str(tmp_path / "nope"), CFG)

    def test_from_checkpoint_bad_step(self, trained):
        ckpt_dir = os.path.join(os.path.dirname(trained.artifact_path),
                                "checkpoints")
        with pytest.raises(FileNotFoundError, match="available steps"):
            api.Artifact.from_checkpoint(ckpt_dir, CFG, step=999)


class TestFromCheckpoint:
    def test_import_matches_direct_export(self, trained):
        ckpt_dir = os.path.join(os.path.dirname(trained.artifact_path),
                                "checkpoints")
        art = api.Artifact.from_checkpoint(ckpt_dir, CFG)
        fresh = api.Artifact.from_params(CFG, trained.state.params)
        for a, f in zip(jax.tree_util.tree_leaves(art.master),
                        jax.tree_util.tree_leaves(fresh.master)):
            np.testing.assert_array_equal(np.asarray(a).view(np.uint8),
                                          np.asarray(f).view(np.uint8))
        assert art.provenance["train_step"] == 3

    def test_import_fixed_width_checkpoint(self, tmp_path):
        """A checkpoint trained under a non-default width set (fixed-m:
        one BPS arm) imports with the matching policy — the arm count is
        read from the stored arrays — and is refused (with instructions)
        under a policy whose arm count contradicts them, so the artifact
        never records falsified trained widths."""
        out = str(tmp_path / "fixed_run")
        api.finetune(CFG, out_dir=out, policy=api.PrecisionPolicy.fixed(4),
                     steps=2, global_batch=2, seq=32, lr=1e-3,
                     ckpt_every=2, log_every=1, export=False)
        ckpt_dir = os.path.join(out, "checkpoints")
        art = api.Artifact.from_checkpoint(
            ckpt_dir, CFG, policy=api.PrecisionPolicy.fixed(4))
        assert art.provenance["train_step"] == 2
        assert art.trained_widths == (4,)
        assert art.bps_stats["t"] == 2 and len(art.bps_stats["t_b"]) == 1
        with pytest.raises(ValueError, match="trained over 1 bit-width"):
            api.Artifact.from_checkpoint(ckpt_dir, CFG)  # default policy


class TestOverwriteSafety:
    def test_failed_overwrite_restores_old_artifact(self, trained,
                                                    tmp_path, monkeypatch):
        """If installing the new artifact fails mid-overwrite, the previous
        DONE-marked artifact must come back (rename-aside rollback)."""
        from repro.train import checkpoint as ckpt_mod
        target = str(tmp_path / "keep")
        trained.artifact.save(target)
        real_replace = os.replace

        def fail_final_install(src, dst):
            if (os.path.abspath(dst) == os.path.abspath(target)
                    and ".tmp_artifact" in os.path.basename(src)):
                raise OSError("injected failure installing new artifact")
            return real_replace(src, dst)

        monkeypatch.setattr(ckpt_mod.os, "replace", fail_final_install)
        with pytest.raises(OSError, match="injected"):
            trained.artifact.save(target)
        monkeypatch.undo()
        api.Artifact.load(target)  # the old artifact survived
        assert not [n for n in os.listdir(tmp_path) if ".old-" in n]
