"""Unit + property tests for the SEFP numerics (repro.core.sefp / packed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import packed as packed_lib
from repro.core import sefp

jax.config.update("jax_enable_x64", False)


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), jnp.float32)


# ---------------------------------------------------------------------------
# basic fake-quant behaviour
# ---------------------------------------------------------------------------

class TestSefpQuantize:
    def test_identity_on_representable(self):
        # Values that are exact multiples of the group quantum must round-trip.
        e_star = 3  # group max exponent
        m = 5
        quantum = 2.0 ** (e_star - (m - 1))
        codes = np.arange(-31, 33, 1, dtype=np.float32)  # 64 values
        codes[-1] = 31  # keep |code| <= 2^m - 1
        w = jnp.asarray(codes * quantum)
        w = w.at[0].set(2.0 ** e_star * 1.5)  # pin the max exponent
        q = sefp.sefp_quantize(w, m)
        # the pinned value is also representable: 1.5*2^3 = 12 = 96*0.125
        np.testing.assert_allclose(np.asarray(q), np.asarray(w), rtol=0, atol=0)

    def test_error_bound(self):
        # |w - Q(w)| <= quantum/2 for values that do not underflow/overflow.
        w = rand((4, 64), seed=1)
        for m in sefp.MANTISSA_WIDTHS:
            q = sefp.sefp_quantize(w, m)
            g = np.asarray(w).reshape(4, 64)
            e = np.floor(np.log2(np.abs(g))).max(axis=-1)
            quantum = 2.0 ** (np.clip(e, -14, 15) - (m - 1))
            err = np.abs(np.asarray(q).reshape(4, 64) - g)
            assert (err <= quantum[:, None] / 2 + 1e-7).all(), m

    def test_monotone_in_m(self):
        # Higher mantissa width must not increase total quantization error.
        w = rand((16, 64), seed=2)
        errs = []
        for m in (8, 6, 4, 3):
            q = sefp.sefp_quantize(w, m)
            errs.append(float(jnp.abs(q - w).sum()))
        assert errs == sorted(errs), errs

    def test_dynamic_m_traced(self):
        # m as a traced scalar must give identical results to static m,
        # under a single jitted callable (no per-width recompilation).
        w = rand((8, 128), seed=3)
        f = jax.jit(lambda w, m: sefp.sefp_quantize(w, m))
        for m in sefp.MANTISSA_WIDTHS:
            dyn = f(w, jnp.int32(m))
            stat = sefp.sefp_quantize(w, m)
            np.testing.assert_array_equal(np.asarray(dyn), np.asarray(stat))

    def test_zero_group(self):
        w = jnp.zeros((2, 64))
        q = sefp.sefp_quantize(w, 4)
        assert not jnp.isnan(q).any()
        np.testing.assert_array_equal(np.asarray(q), 0.0)

    def test_group_axis0(self):
        w = rand((128, 10), seed=4)
        q0 = sefp.sefp_quantize(w, 5, group_axis=0)
        qt = sefp.sefp_quantize(w.T, 5, group_axis=-1).T
        np.testing.assert_allclose(np.asarray(q0), np.asarray(qt), atol=0)

    def test_exponent_clamp_overflow(self):
        # Huge values: shared exponent clamps at 15, codes clamp at 2^m-1.
        w = jnp.full((64,), 1e6, jnp.float32)
        q = sefp.sefp_quantize(w, 4)
        assert jnp.isfinite(q).all()
        expected = 15.0 * 2.0 ** (15 - 3)  # (2^4-1) * 2^(15-(4-1))
        np.testing.assert_allclose(np.asarray(q), expected)

    def test_underflow_to_zero(self):
        w = jnp.asarray([1.0] + [1e-9] * 63, jnp.float32)
        q = sefp.sefp_quantize(w, 3)
        assert float(q[0]) == 1.0
        np.testing.assert_array_equal(np.asarray(q[1:]), 0.0)

    def test_bf16_dtype_preserved(self):
        w = rand((2, 64)).astype(jnp.bfloat16)
        q = sefp.sefp_quantize(w, 6)
        assert q.dtype == jnp.bfloat16


class TestSTE:
    def test_gradient_is_identity(self):
        w = rand((2, 64), seed=5)

        def f(w):
            return jnp.sum(sefp.sefp_quantize_ste(w, 4) ** 2)

        g = jax.grad(f)(w)
        # STE: d/dw sum(Q(w)^2) = 2*Q(w) (dQ/dw := 1)
        q = sefp.sefp_quantize(w, 4)
        np.testing.assert_allclose(np.asarray(g), np.asarray(2 * q), rtol=1e-6)

    def test_quantize_tree_excludes(self):
        params = {
            "layer": {"w": rand((128, 64)), "bias": rand((64,)),
                      "norm_scale": rand((64,))},
            "A_log": rand((128, 64)),
        }
        q = sefp.quantize_tree(params, 4, min_size=1)
        assert not np.allclose(np.asarray(q["layer"]["w"]),
                               np.asarray(params["layer"]["w"]))
        np.testing.assert_array_equal(np.asarray(q["layer"]["bias"]),
                                      np.asarray(params["layer"]["bias"]))
        np.testing.assert_array_equal(np.asarray(q["A_log"]),
                                      np.asarray(params["A_log"]))


# ---------------------------------------------------------------------------
# packed master + truncation semantics (the paper's switching mechanism)
# ---------------------------------------------------------------------------

class TestPacked:
    def test_pack_dequant_roundtrip_m8(self):
        w = rand((128, 256), seed=6)
        p = packed_lib.pack(w, group_axis=0)
        deq = packed_lib.dequantize(p, 8)
        ref = sefp.sefp_quantize(w, 8, group_axis=0)
        np.testing.assert_allclose(np.asarray(deq), np.asarray(ref),
                                   rtol=0, atol=1e-7)

    def test_truncation_matches_trunc_requant(self):
        # mag >> k must equal re-quantizing the M8 *dequant* with trunc
        # rounding — the paper's Fig. 2 equivalence.
        w = rand((64, 128), seed=7)
        p = packed_lib.pack(w, group_axis=0)
        for m in (7, 6, 5, 4, 3):
            deq_trunc = packed_lib.dequantize(p, m)
            master = packed_lib.dequantize(p, 8)
            ref = sefp.sefp_quantize(master, m, group_axis=0,
                                     rounding="trunc")
            np.testing.assert_allclose(np.asarray(deq_trunc),
                                       np.asarray(ref), rtol=0, atol=1e-7,
                                       err_msg=f"m={m}")

    def test_truncation_error_monotone(self):
        w = rand((256, 64), seed=8)
        p = packed_lib.pack(w, group_axis=0)
        errs = [float(jnp.abs(packed_lib.dequantize(p, m) - w).mean())
                for m in (8, 7, 6, 5, 4, 3)]
        assert errs == sorted(errs)

    def test_int8_codes_view(self):
        w = rand((64, 64), seed=9)
        p = packed_lib.pack(w, group_axis=0)
        for m in (7, 5, 3):
            codes, exp = packed_lib.to_int8_codes(p, m)
            quantum = np.exp2(np.asarray(exp, np.int32) - (m - 1))
            deq = (np.asarray(codes, np.float32)
                   * np.repeat(quantum, 64, axis=0))
            ref = np.asarray(packed_lib.dequantize(p, m))  # logical [K, N]
            np.testing.assert_allclose(deq, ref, rtol=0, atol=1e-7)

    def test_bits_accounting(self):
        w = rand((512, 512), seed=10)
        p = packed_lib.pack(w, group_axis=0)
        bits = p.nbytes_packed * 8 / w.size
        assert abs(bits - 9.125) < 1e-6
        # E5M4 streaming: ~5.125 bits => ~32% of fp16 (paper Table 2: 31%)
        assert abs(p.bits_per_param(4) - 5.125) < 1e-6

    def test_dynamic_m_dequant(self):
        w = rand((64, 64), seed=11)
        p = packed_lib.pack(w, group_axis=0)
        f = jax.jit(packed_lib.dequantize)
        for m in (8, 5, 3):
            np.testing.assert_array_equal(
                np.asarray(f(p, jnp.int32(m))),
                np.asarray(packed_lib.dequantize(p, m)))


# ---------------------------------------------------------------------------
# hypothesis property tests — system invariants
# ---------------------------------------------------------------------------

@st.composite
def weight_arrays(draw):
    rows = draw(st.sampled_from([1, 2, 3]))
    scale = draw(st.floats(min_value=1e-3, max_value=1e3))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(rows, 64)).astype(np.float32) * scale
    return jnp.asarray(w)


@settings(max_examples=25, deadline=None)
@given(w=weight_arrays(), m=st.sampled_from(sefp.MANTISSA_WIDTHS))
def test_prop_idempotent(w, m):
    """Q(Q(w)) == Q(w): quantization is a projection."""
    q1 = sefp.sefp_quantize(w, m)
    q2 = sefp.sefp_quantize(q1, m)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(w=weight_arrays(), m=st.sampled_from(sefp.MANTISSA_WIDTHS))
def test_prop_sign_preserved(w, m):
    q = np.asarray(sefp.sefp_quantize(w, m))
    wn = np.asarray(w)
    nz = q != 0
    assert (np.sign(q[nz]) == np.sign(wn[nz])).all()


@settings(max_examples=25, deadline=None)
@given(w=weight_arrays(), m=st.sampled_from(sefp.MANTISSA_WIDTHS))
def test_prop_scale_equivariance(w, m):
    """Q(2^k * w) == 2^k * Q(w): SEFP commutes with power-of-two scaling."""
    q1 = sefp.sefp_quantize(w * 4.0, m)
    q2 = sefp.sefp_quantize(w, m) * 4.0
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(w=weight_arrays())
def test_prop_truncation_chain(w):
    """Truncating M8->M5 in one step equals M8->M7->M6->M5 chained —
    the on-device downshift path is self-consistent."""
    p = packed_lib.pack(w, group_axis=-1)
    direct = np.asarray(packed_lib.dequantize(p, 5))
    # chain through re-packing at intermediate widths using trunc rounding
    x = packed_lib.dequantize(p, 8)
    for m in (7, 6, 5):
        x = sefp.sefp_quantize(x, m, group_axis=-1, rounding="trunc")
    np.testing.assert_allclose(direct, np.asarray(x), rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(w=weight_arrays(), m=st.sampled_from((8, 6, 4, 3)))
def test_prop_error_within_quantum(w, m):
    q = np.asarray(sefp.sefp_quantize(w, m), np.float64)
    g = np.asarray(w, np.float64)
    e = np.clip(np.floor(np.log2(np.abs(g).max(axis=-1))), -14, 15)
    quantum = 2.0 ** (e - (m - 1))
    # values above the representable max clamp; ignore those
    maxrep = (2.0 ** m - 1) * quantum
    mask = np.abs(g) <= maxrep[:, None]
    err = np.abs(q - g)
    assert (err[mask] <= (quantum[:, None] / 2 + 1e-12 * np.abs(g))[mask]).all()


# ---------------------------------------------------------------------------
# conventional-quantization contrast (paper Fig. 1)
# ---------------------------------------------------------------------------

def test_conventional_switch_breaks_sefp_switch_does_not():
    from repro.quant import int_quant

    w = rand((8, 64), seed=12)
    # SEFP: truncation from the master == native low-width quantization error
    p = packed_lib.pack(w, group_axis=-1)
    sefp_err = float(jnp.abs(packed_lib.dequantize(p, 4) - w).mean())
    native4 = sefp.sefp_quantize(w, 4, rounding="trunc")
    native_err = float(jnp.abs(native4 - w).mean())
    assert sefp_err <= native_err * 1.05  # switching costs (almost) nothing

    # INT: reusing 8-bit scales at 4 bits is much worse than native 4-bit
    _, codes8, scale8 = int_quant.int_quantize(w, 8)
    switched = int_quant.naive_bitwidth_switch(codes8, scale8, 8, 4)
    switched = switched.reshape(w.shape)
    int_native, _, _ = int_quant.int_quantize(w, 4)
    err_switched = float(jnp.abs(switched - w).mean())
    err_native = float(jnp.abs(int_native - w).mean())
    assert err_switched > err_native * 1.5
