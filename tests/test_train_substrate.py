"""Training-substrate tests: data pipeline determinism, checkpoint
atomicity + restart, elastic restore, gradient compression, distributed
step integration.  Runs on 8 fake CPU devices (set before jax init)."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import otaro as otaro_lib  # noqa: E402
from repro.kernels import compat  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.sharding import partition as SH  # noqa: E402
from repro.train import checkpoint as CKPT  # noqa: E402
from repro.train import compression as CM  # noqa: E402
from repro.train import data as data_lib  # noqa: E402
from repro.train import optimizer as opt_lib  # noqa: E402
from repro.train import runner as runner_lib  # noqa: E402
from repro.train import steps as steps_lib  # noqa: E402

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                   head_dim=16, q_block=16, kv_block=16, loss_chunk=16,
                   remat="none", dtype="float32")


class TestData:
    def test_deterministic(self):
        c = data_lib.SyntheticCorpus(vocab_size=256, seed=7)
        b1 = c.batch(3, 4, 32)
        b2 = c.batch(3, 4, 32)
        np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
        b3 = c.batch(4, 4, 32)
        assert not np.array_equal(b1["inputs"], b3["inputs"])

    def test_learnable_structure(self):
        # bigram statistics must be far from uniform (a model can learn it)
        c = data_lib.SyntheticCorpus(vocab_size=64, seed=1)
        toks = np.concatenate(
            [c.batch(i, 1, 512)["inputs"][0] for i in range(8)])
        # empirical successor entropy per token << log2(V)
        from collections import Counter, defaultdict
        succ = defaultdict(Counter)
        for a, b in zip(toks[:-1], toks[1:]):
            succ[a][b] += 1
        ents = []
        for a, cnt in succ.items():
            p = np.array(list(cnt.values()), float)
            p /= p.sum()
            ents.append(-(p * np.log2(p)).sum())
        assert np.mean(ents) < 0.7 * np.log2(64)

    def test_host_slice(self):
        c = data_lib.SyntheticCorpus(vocab_size=64, seed=2)
        b = c.batch(0, 8, 16)
        s0 = data_lib.host_batch_slice(b, 0, 2)
        s1 = data_lib.host_batch_slice(b, 1, 2)
        np.testing.assert_array_equal(
            np.concatenate([s0["inputs"], s1["inputs"]]), b["inputs"])


class TestCheckpoint:
    def _mk_state(self, seed=0):
        from repro.models import model_zoo as Z
        params = Z.init_params(TINY, jax.random.PRNGKey(seed))
        opt = opt_lib.sgd(1e-3)
        ocfg = otaro_lib.OTAROConfig(mode="otaro")
        return otaro_lib.init_state(params, opt, ocfg)

    def test_roundtrip(self, tmp_path):
        state = self._mk_state()
        CKPT.save_checkpoint(str(tmp_path), 7, state, extra={"data_step": 7})
        like = jax.eval_shape(lambda: self._mk_state())
        restored, meta = CKPT.restore_checkpoint(str(tmp_path), like)
        assert meta["step"] == 7
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_k(self, tmp_path):
        state = self._mk_state()
        for s in (1, 2, 3, 4, 5):
            CKPT.save_checkpoint(str(tmp_path), s, state, keep=2)
        assert CKPT.list_steps(str(tmp_path)) == [4, 5]

    def test_torn_write_ignored(self, tmp_path):
        state = self._mk_state()
        CKPT.save_checkpoint(str(tmp_path), 1, state)
        # fake a torn write: dir without DONE marker
        torn = tmp_path / "step_0000000099"
        torn.mkdir()
        (torn / "arrays.npz").write_bytes(b"garbage")
        assert CKPT.latest_step(str(tmp_path)) == 1

    def test_elastic_restore_new_mesh(self, tmp_path):
        """Save unsharded, restore onto a 4x2 mesh, then onto 2x4."""
        state = self._mk_state()
        CKPT.save_checkpoint(str(tmp_path), 3, state)
        like = jax.eval_shape(lambda: self._mk_state())
        for shape in [(4, 2), (2, 4)]:
            mesh = compat.make_mesh(shape, ("data", "model"))
            specs = SH.state_pspecs(like, mesh)
            shardings = SH.to_named_sharding(specs, mesh)
            restored, _ = CKPT.restore_checkpoint(str(tmp_path), like,
                                                  shardings=shardings)
            leaf = restored.params["layers"]["attn"]["wq"]
            assert leaf.sharding.mesh.shape == dict(
                zip(("data", "model"), shape))
            np.testing.assert_array_equal(
                np.asarray(leaf), np.asarray(state.params["layers"]["attn"]["wq"]))


class TestCheckpointKeyEncoding:
    """Regression tests for the path->key encoding: a naive "/".join of
    str(component) collides for dict keys containing "/" and for int-like
    string keys vs positional children; path_key escapes / type-tags each
    component so every distinct path round-trips distinctly."""

    def _roundtrip(self, tree, tmp_path):
        CKPT.save_checkpoint(str(tmp_path), 1, tree)
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x),
                                           np.asarray(x).dtype), tree)
        restored, _ = CKPT.restore_checkpoint(str(tmp_path), like)
        flat_in = jax.tree_util.tree_leaves(tree)
        flat_out = jax.tree_util.tree_leaves(restored)
        assert len(flat_in) == len(flat_out)
        for a, b in zip(flat_in, flat_out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        return restored

    def test_slash_key_does_not_collide_with_nesting(self, tmp_path):
        tree = {"a/b": np.float32(1.0), "a": {"b": np.float32(2.0)}}
        restored = self._roundtrip(tree, tmp_path)
        assert float(restored["a/b"]) == 1.0
        assert float(restored["a"]["b"]) == 2.0

    def test_int_like_dict_key_vs_positional_child(self, tmp_path):
        # dict key "0" and a list index 0 under sibling nodes must encode
        # differently ("0" vs "#0"); both round-trip
        tree = {"d": {"0": np.float32(3.0)}, "l": [np.float32(4.0)]}
        restored = self._roundtrip(tree, tmp_path)
        assert float(restored["d"]["0"]) == 3.0
        assert float(restored["l"][0]) == 4.0

    def test_escape_chars_roundtrip(self, tmp_path):
        tree = {"w\\q": np.float32(5.0), "#0": np.float32(6.0),
                "a\\/b": np.float32(7.0)}
        restored = self._roundtrip(tree, tmp_path)
        assert float(restored["#0"]) == 6.0
        assert float(restored["w\\q"]) == 5.0

    def test_split_key_inverts_escaping(self):
        assert CKPT.split_key("a\\/b/#3/c\\\\d") == ["a/b", "#3", "c\\d"]

    def test_packed_master_tree_roundtrip(self, tmp_path):
        """A packed {mag, sign, exp} stacked-master tree (uint8/int8 leaves,
        the repro.artifact payload) survives save/restore bit-exactly."""
        from repro.core import packed as packed_lib
        w = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 16))
        tree = {"layers": {"wq": packed_lib.pack_stacked(w)}}
        restored = self._roundtrip(tree, tmp_path)
        leaf = restored["layers"]["wq"]
        assert set(leaf) == {"mag", "sign", "exp"}
        assert np.asarray(leaf["mag"]).dtype == np.uint8
        assert np.asarray(leaf["sign"]).dtype == np.uint8
        assert np.asarray(leaf["exp"]).dtype == np.int8

    def test_distinct_paths_distinct_keys(self):
        # the collision the escaping exists to prevent: these four paths
        # used to flatten to two keys
        arrays = CKPT.flatten_arrays(
            {"a/b": np.float32(1), "a": {"b": np.float32(2)},
             "d": {"0": np.float32(3)}, "l": [np.float32(4)]})
        assert len(arrays) == 4

    def test_split_key_raw_keeps_escape_tags(self):
        # unescape=False keeps "\#x" (escaped dict key) distinguishable
        # from "#0" (positional) — the artifact tree rebuild relies on it
        raw = CKPT.split_key("\\#x/#0", unescape=False)
        assert raw == ["\\#x", "#0"]
        assert CKPT.unescape_component(raw[0]) == "#x"

    def test_legacy_format_checkpoint_gets_clear_error(self):
        # a checkpoint written with the pre-escaping naive keys must fail
        # with a message naming the format change, not a bare missing-key
        like = {"l": [jax.ShapeDtypeStruct((1,), np.float32)]}
        legacy_arrays = {"l/0": np.zeros(1, np.float32)}  # old-style key
        with pytest.raises(KeyError, match="pre-escaping"):
            CKPT.unflatten_arrays(like, legacy_arrays)


class TestRunnerFaultTolerance:
    def _setup(self, tmp_path):
        corpus = data_lib.SyntheticCorpus(vocab_size=TINY.vocab_size, seed=3)
        opt = opt_lib.sgd(1e-2)
        ocfg = otaro_lib.OTAROConfig(mode="otaro", laa_n=2)
        step_builder, init_fn = steps_lib.make_train_step(
            TINY, ocfg, opt, mesh=None)

        def batch_fn(step):
            b = corpus.batch(step, 4, 32)
            return {k: jnp.asarray(v) for k, v in b.items()}

        return step_builder, (lambda: init_fn(jax.random.PRNGKey(0))), batch_fn

    def test_failure_then_resume_reaches_target(self, tmp_path):
        step_fn, init_fn, batch_fn = self._setup(tmp_path)
        job = runner_lib.JobConfig(total_steps=12, out_dir=str(tmp_path),
                                   ckpt_every=4, log_every=4,
                                   simulate_failure_at=9)
        with pytest.raises(RuntimeError, match="simulated node failure"):
            runner_lib.run_training(step_fn, init_fn, batch_fn, job)
        # relaunch (same command) -> resumes from step 8 and completes
        job2 = runner_lib.JobConfig(total_steps=12, out_dir=str(tmp_path),
                                    ckpt_every=4, log_every=4)
        state, history = runner_lib.run_training(step_fn, init_fn, batch_fn,
                                                 job2)
        resumed = [h for h in history if h.get("event") == "resumed"]
        assert resumed and resumed[0]["step"] == 8
        assert CKPT.latest_step(str(tmp_path / "checkpoints")) == 12

    def test_resume_is_deterministic(self, tmp_path):
        """crash+resume must produce the same final BPS counts as an
        uninterrupted run (pure-function-of-step data pipeline)."""
        step_fn, init_fn, batch_fn = self._setup(tmp_path)
        # uninterrupted
        job = runner_lib.JobConfig(total_steps=8,
                                   out_dir=str(tmp_path / "a"),
                                   ckpt_every=4, log_every=8)
        state_a, _ = runner_lib.run_training(step_fn, init_fn, batch_fn, job)
        # interrupted at 6, resumed
        job_b = runner_lib.JobConfig(total_steps=8,
                                     out_dir=str(tmp_path / "b"),
                                     ckpt_every=4, log_every=8,
                                     simulate_failure_at=6)
        with pytest.raises(RuntimeError):
            runner_lib.run_training(step_fn, init_fn, batch_fn, job_b)
        job_b2 = runner_lib.JobConfig(total_steps=8,
                                      out_dir=str(tmp_path / "b"),
                                      ckpt_every=4, log_every=8)
        state_b, _ = runner_lib.run_training(step_fn, init_fn, batch_fn,
                                             job_b2)
        np.testing.assert_array_equal(np.asarray(state_a.bps.t_b),
                                      np.asarray(state_b.bps.t_b))
        for a, b in zip(jax.tree_util.tree_leaves(state_a.params),
                        jax.tree_util.tree_leaves(state_b.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


class TestCompression:
    def test_compressed_psum_close_to_exact(self):
        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(128, 64)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(130,)), jnp.float32)}
        f = jax.jit(lambda g: CM.compressed_psum_pods(g, mesh, m=8))
        with compat.set_mesh(mesh):
            out = f(g)
        for k in g:
            ref = 2 * g[k]  # replicated input, 2 pods -> sum = 2x
            err = float(jnp.abs(out[k] - ref).max() / jnp.abs(ref).max())
            assert err < 5e-3, (k, err)

    def test_lower_m_lower_fidelity(self):
        mesh = compat.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(1)
        g = {"w": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)}
        errs = []
        for m in (8, 4, 3):
            f = jax.jit(lambda g, m=m: CM.compressed_psum_pods(g, mesh, m=m))
            with compat.set_mesh(mesh):
                out = f(g)
            errs.append(float(jnp.abs(out["w"] - 2 * g["w"]).mean()))
        assert errs[0] < errs[1] < errs[2]

    def test_ratio(self):
        assert abs(CM.compression_ratio(8) - 9.125 / 16) < 1e-9
        assert abs(CM.compression_ratio(4) - 5.125 / 16) < 1e-9


class TestDistributedStep:
    def test_sharded_step_runs_and_matches_unsharded(self):
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        opt = opt_lib.sgd(1e-2)
        ocfg = otaro_lib.OTAROConfig(mode="fixed", fixed_m=8)
        corpus = data_lib.SyntheticCorpus(vocab_size=TINY.vocab_size, seed=4)
        batch = {k: jnp.asarray(v)
                 for k, v in corpus.batch(0, 8, 32).items()}
        batch_shapes = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)

        jit_step, init_fn = steps_lib.make_train_step(TINY, ocfg, opt,
                                                      mesh=mesh, donate=False)
        with compat.set_mesh(mesh):
            state = init_fn(jax.random.PRNGKey(0))
            step = jit_step(batch_shapes)
            state2, metrics = step(state, batch)
        loss_sharded = float(metrics["loss"])

        step_u, init_u = steps_lib.make_train_step(TINY, ocfg, opt, mesh=None,
                                                   donate=False)
        state_u = init_u(jax.random.PRNGKey(0))
        _, metrics_u = step_u(state_u, batch)
        assert abs(loss_sharded - float(metrics_u["loss"])) < 1e-3

    def test_pod_compressed_step_runs(self):
        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        opt = opt_lib.sgd(1e-2)
        ocfg = otaro_lib.OTAROConfig(mode="otaro", laa_n=2)
        corpus = data_lib.SyntheticCorpus(vocab_size=TINY.vocab_size, seed=5)
        batch = {k: jnp.asarray(v)
                 for k, v in corpus.batch(0, 8, 32).items()}
        batch_shapes = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
        jit_step, init_fn = steps_lib.make_train_step(
            TINY, ocfg, opt, mesh=mesh, compress_pods_m=8, donate=False)
        with compat.set_mesh(mesh):
            state = init_fn(jax.random.PRNGKey(0))
            step = jit_step(batch_shapes)
            state, metrics = step(state, batch)
            state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))


class TestMicrobatching:
    def test_grad_accum_equals_full_batch(self):
        from repro.models import model_zoo as Z
        loss_fn = Z.make_loss_fn(TINY)
        params = Z.init_params(TINY, jax.random.PRNGKey(1))
        corpus = data_lib.SyntheticCorpus(vocab_size=TINY.vocab_size, seed=6)
        batch = {k: jnp.asarray(v)
                 for k, v in corpus.batch(0, 8, 32).items()}
        g_full = jax.grad(loss_fn)(params, batch)
        loss_mb = steps_lib.microbatched(loss_fn, 4)
        g_mb = jax.grad(loss_mb)(params, batch)
        for a, b in zip(jax.tree_util.tree_leaves(g_full),
                        jax.tree_util.tree_leaves(g_mb)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-3, atol=5e-5)
