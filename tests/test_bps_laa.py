"""Tests for the BPS bandit, the LAA accumulator, and the combined OTARo step
(paper Algorithm 1) on a toy regression problem."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bps as bps_lib
from repro.core import laa as laa_lib
from repro.core import otaro as otaro_lib
from repro.core import sefp
from repro.train import optimizer as opt_lib


class TestBPS:
    def test_must_explore_all_arms_first(self):
        state = bps_lib.init(6)
        picked = []
        for step in range(6):
            arm, m = bps_lib.select(state, lam=5.0)
            picked.append(int(arm))
            state = bps_lib.update(state, arm, jnp.float32(1.0))
        assert sorted(picked) == list(range(6))

    def test_converges_to_lower_loss_arm(self):
        # Arm losses: higher widths (low arm index) have lower loss, as in
        # the paper.  After warmup, high widths must dominate selections.
        losses = np.array([0.5, 0.55, 0.6, 0.7, 0.9, 1.4], np.float32)
        state = bps_lib.init(6)
        counts = np.zeros(6, int)
        key = 0
        for t in range(400):
            arm, m = bps_lib.select(state, lam=0.5)
            a = int(arm)
            counts[a] += 1
            noisy = losses[a] + 0.01 * np.sin(t * 0.7 + a)
            state = bps_lib.update(state, arm, jnp.float32(noisy))
        # the best (highest-width) arm is selected most often
        assert counts[0] == counts.max()
        # but every arm keeps being explored (diversity)
        assert (counts > 0).all()

    def test_score_formula(self):
        state = bps_lib.BPSState(
            t=jnp.int32(100),
            t_b=jnp.asarray([50, 25, 25, 0, 0, 0], jnp.int32),
            loss_b=jnp.asarray([1.0, 2.0, 0.5, 0, 0, 0], jnp.float32))
        s = np.asarray(bps_lib.scores(state, lam=5.0))
        expect0 = 5.0 * np.sqrt(np.log(100) / 50) - 1.0
        assert abs(s[0] - expect0) < 1e-5
        assert np.isinf(s[3]) and s[3] > 0  # unvisited arm forced

    def test_uniform_cycles(self):
        ms = [int(bps_lib.uniform_select(jnp.int32(i))[1]) for i in range(12)]
        assert ms == [8, 7, 6, 5, 4, 3] * 2


class TestLAA:
    def test_high_precision_passthrough(self):
        g = {"w": jnp.ones((4,))}
        st = laa_lib.init(g)
        eff, do, st2 = laa_lib.step(st, g, jnp.asarray(False), n_delay=3)
        assert bool(do)
        np.testing.assert_array_equal(np.asarray(eff["w"]), 1.0)
        np.testing.assert_array_equal(np.asarray(st2.buf["w"]), 0.0)
        assert int(st2.count) == 0

    def test_accumulate_and_release(self):
        g1 = {"w": jnp.full((2,), 1.0)}
        g2 = {"w": jnp.full((2,), 2.0)}
        g3 = {"w": jnp.full((2,), 4.0)}
        st = laa_lib.init(g1)
        eff, do, st = laa_lib.step(st, g1, jnp.asarray(True), n_delay=3)
        assert not bool(do)
        np.testing.assert_array_equal(np.asarray(eff["w"]), 0.0)
        eff, do, st = laa_lib.step(st, g2, jnp.asarray(True), n_delay=3)
        assert not bool(do)
        eff, do, st = laa_lib.step(st, g3, jnp.asarray(True), n_delay=3)
        assert bool(do)
        # released gradient is the SUM over the 3 low-bit batches (Eq. 18)
        np.testing.assert_array_equal(np.asarray(eff["w"]), 7.0)
        assert int(st.count) == 0
        np.testing.assert_array_equal(np.asarray(st.buf["w"]), 0.0)

    def test_asynchronous_across_high_batches(self):
        # Buffer must survive interleaved high-precision batches.
        glow = {"w": jnp.full((1,), 1.0)}
        ghigh = {"w": jnp.full((1,), 100.0)}
        st = laa_lib.init(glow)
        _, do, st = laa_lib.step(st, glow, jnp.asarray(True), n_delay=2)
        assert not bool(do)
        eff, do, st = laa_lib.step(st, ghigh, jnp.asarray(False), n_delay=2)
        assert bool(do) and float(eff["w"][0]) == 100.0
        assert float(st.buf["w"][0]) == 1.0  # untouched
        eff, do, st = laa_lib.step(st, glow, jnp.asarray(True), n_delay=2)
        assert bool(do)
        np.testing.assert_array_equal(np.asarray(eff["w"]), 2.0)

    def test_noise_averaging_property(self):
        # Eq. 17: relative perturbation of the released update shrinks ~
        # 1/sqrt(N).  Simulate grad = mean + zero-mean noise.
        rng = np.random.default_rng(0)
        mean = 1.0
        for n in (4, 16, 64):
            st = laa_lib.init({"w": jnp.zeros((512,))})
            rels = []
            for trial in range(8):
                for i in range(n):
                    g = {"w": jnp.asarray(
                        mean + rng.normal(size=512).astype(np.float32))}
                    eff, do, st = laa_lib.step(st, g, jnp.asarray(True), n)
                rel = np.linalg.norm(np.asarray(eff["w"]) / n - mean) \
                    / np.sqrt(512)
                rels.append(rel)
            # noise of the averaged update ~ sigma/sqrt(n)
            assert np.mean(rels) < 2.0 / np.sqrt(n)


def _toy_setup(mode, seed=0, **cfg_kw):
    """Tiny quadratic-ish regression: y = x @ W_true, model y = x @ W."""
    rng = np.random.default_rng(seed)
    w_true = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(64, 8)) * 0.5, jnp.float32)
    params = {"w": w0}

    def loss_fn(p, batch):
        x, y = batch
        pred = x @ p["w"]
        return jnp.mean((pred - y) ** 2)

    cfg = otaro_lib.OTAROConfig(mode=mode, min_size=1, laa_n=3, **cfg_kw)
    opt = opt_lib.sgd(5e-2)
    step = jax.jit(otaro_lib.make_otaro_step(loss_fn, opt, cfg))
    state = otaro_lib.init_state(params, opt, cfg)

    def batch(i):
        r = np.random.default_rng(1000 + i)
        x = jnp.asarray(r.normal(size=(32, 64)), jnp.float32)
        return x, x @ w_true

    return state, step, batch, loss_fn, cfg


class TestOTAROStep:
    def test_loss_decreases(self):
        # Evaluate at the highest width (m=8) before/after training; the
        # per-step metrics loss mixes bit-widths (low widths have a high
        # quantization floor) so it is not a clean convergence signal.
        state, step, batch, loss_fn, cfg = _toy_setup("otaro")
        evalf = jax.jit(otaro_lib.make_eval_fn(loss_fn, cfg))
        eb = batch(9_999)
        before = float(evalf(state.params, eb, jnp.int32(8)))
        for i in range(200):
            state, _ = step(state, batch(i))
        after = float(evalf(state.params, eb, jnp.int32(8)))
        assert after < before * 0.3, (before, after)

    def test_single_compilation_across_widths(self):
        state, step, batch, loss_fn, cfg = _toy_setup("otaro")
        with jax.log_compiles(False):
            lowered = step.lower(state, batch(0))
        compiled = lowered.compile()
        # run many steps through ONE executable; widths must vary
        widths = set()
        for i in range(30):
            state, metrics = compiled(state, batch(i))
            widths.add(int(metrics["mantissa_width"]))
        assert len(widths) >= 3, widths

    def test_fixed_mode_uses_fixed_width(self):
        state, step, batch, *_ = _toy_setup("fixed", fixed_m=4)
        for i in range(5):
            state, metrics = step(state, batch(i))
            assert int(metrics["mantissa_width"]) == 4

    def test_fp16_mode_never_updates_laa(self):
        state, step, batch, *_ = _toy_setup("fp16")
        for i in range(5):
            state, metrics = step(state, batch(i))
            assert int(metrics["did_update"]) == 1

    def test_otaro_beats_fixed_low_on_mixed_eval(self):
        # The paper's headline: after fine-tuning, OTARo's AVERAGE loss over
        # all widths is <= fixed-high-precision fine-tuning's.
        results = {}
        for mode, kw in [("otaro", {}), ("fixed", {"fixed_m": 8})]:
            state, step, batch, loss_fn, cfg = _toy_setup(mode, seed=3, **kw)
            for i in range(250):
                state, _ = step(state, batch(i))
            evalf = jax.jit(otaro_lib.make_eval_fn(loss_fn, cfg))
            eb = batch(10_000)
            losses = [float(evalf(state.params, eb, jnp.int32(m)))
                      for m in sefp.MANTISSA_WIDTHS]
            results[mode] = np.mean(losses)
        assert results["otaro"] <= results["fixed"] * 1.05, results

    def test_laa_state_masking(self):
        # On LAA-held batches params must be bit-identical.
        state, step, batch, *_ = _toy_setup("otaro")
        prev = np.asarray(state.params["w"])
        for i in range(40):
            state, metrics = step(state, batch(i))
            cur = np.asarray(state.params["w"])
            if int(metrics["did_update"]) == 0:
                np.testing.assert_array_equal(cur, prev)
            prev = cur
