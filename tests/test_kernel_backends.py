"""Dispatch + compat subsystem tests: registry contents, platform
auto-selection, env-var / per-call override precedence, interpret-mode
regression for each Pallas kernel on CPU, bitwise backend agreement, and the
"compat owns every version-gated symbol" repo invariant."""

import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packed as packed_lib
from repro.kernels import compat, dispatch
from repro.kernels.sefp_matmul import sefp_matmul, sefp_matmul_gemv
from repro.kernels.sefp_matmul.ref import (sefp_matmul_gemv_ref,
                                           sefp_matmul_ref)
from repro.kernels.sefp_pack import sefp_pack_pallas
from repro.kernels.sefp_pack.ref import sefp_pack_ref
from repro.kernels.sefp_quant import sefp_quantize_pallas
from repro.kernels.sefp_quant.ref import sefp_quantize_ref

OPS = ("sefp_matmul", "sefp_matmul_gemv", "sefp_matmul_gemv_hetero",
       "sefp_pack", "sefp_quant")


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


class TestRegistry:
    def test_all_ops_fully_registered(self):
        assert dispatch.registered_ops() == sorted(OPS)
        for op in OPS:
            assert dispatch.backends_for(op) == sorted(dispatch.BACKENDS)

    def test_unknown_op_rejected(self):
        with pytest.raises(KeyError, match="sefp_matmul"):
            dispatch.dispatch("no_such_op")

    def test_malformed_backend_name_rejected(self):
        with pytest.raises(ValueError, match="non-empty string"):
            dispatch.register("sefp_quant", "")

    def test_unknown_backend_at_call_rejected(self):
        with pytest.raises(ValueError, match="warp-drive"):
            dispatch.dispatch("sefp_quant", rand((64, 64)), 5,
                              backend="warp-drive")

    def test_open_registration_of_new_backends(self):
        # The extension contract: a new backend registers under a new name
        # and is immediately resolvable per-call, no other edits.
        @dispatch.register("_test_op", "unit-test-backend")
        def _impl(x):
            return x + 1
        try:
            assert dispatch.dispatch("_test_op", 41,
                                     backend="unit-test-backend") == 42
        finally:
            dispatch._REGISTRY.pop("_test_op", None)

    def test_jax_ref_rejects_bad_group_dim_with_clear_error(self):
        # the K%64 check must fire before dispatch, on every backend
        with pytest.raises(ValueError, match="64"):
            sefp_quantize_pallas(rand((130, 64)), 5,
                                 backend=dispatch.JAX_REF)
        with pytest.raises(ValueError, match="64"):
            sefp_pack_pallas(rand((130, 64)), backend=dispatch.JAX_REF)


class TestResolution:
    def test_platform_auto_selection(self):
        assert dispatch.auto_backend("tpu") == dispatch.PALLAS_TPU
        assert dispatch.auto_backend("cpu") == dispatch.PALLAS_INTERPRET
        assert dispatch.auto_backend("gpu") == dispatch.PALLAS_INTERPRET

    def test_default_resolution_matches_platform(self, monkeypatch):
        monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
        expected = (dispatch.PALLAS_TPU if jax.default_backend() == "tpu"
                    else dispatch.PALLAS_INTERPRET)
        assert dispatch.resolve_backend() == expected

    def test_env_var_overrides_auto(self, monkeypatch):
        monkeypatch.setenv(dispatch.ENV_VAR, dispatch.JAX_REF)
        assert dispatch.resolve_backend() == dispatch.JAX_REF

    def test_per_call_overrides_env_var(self, monkeypatch):
        monkeypatch.setenv(dispatch.ENV_VAR, dispatch.JAX_REF)
        assert dispatch.resolve_backend(dispatch.PALLAS_INTERPRET) \
            == dispatch.PALLAS_INTERPRET

    def test_bad_env_var_is_a_clear_error(self, monkeypatch):
        monkeypatch.setenv(dispatch.ENV_VAR, "mystery")
        with pytest.raises(ValueError, match=dispatch.ENV_VAR):
            dispatch.resolve_backend()

    def test_env_var_reaches_the_ops(self, monkeypatch):
        # REPRO_KERNEL_BACKEND=jax-ref must actually steer execution
        monkeypatch.setenv(dispatch.ENV_VAR, dispatch.JAX_REF)
        w = rand((128, 128), seed=1)
        out = sefp_quantize_pallas(w, 5)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(sefp_quantize_ref(w, 5)))


class TestInterpretRegression:
    """Each Pallas kernel must import and run in interpret mode on CPU
    (the pltpu.CompilerParams-rename regression)."""

    def test_quant_runs_interpreted(self):
        w = rand((128, 256), seed=2)
        out = sefp_quantize_pallas(w, 6, backend=dispatch.PALLAS_INTERPRET)
        assert out.shape == w.shape and bool(jnp.isfinite(out).all())

    def test_pack_runs_interpreted(self):
        w = rand((128, 256), seed=3)
        p = sefp_pack_pallas(w, backend=dispatch.PALLAS_INTERPRET)
        assert p.mag.shape == (128, 256)
        assert p.sign_bits.shape == (16, 256)
        assert p.exp.shape == (2, 256)

    def test_matmul_runs_interpreted(self):
        x = rand((16, 128), seed=4)
        p = packed_lib.pack(rand((128, 64), seed=5), group_axis=0)
        out = sefp_matmul(x, p, 5, backend=dispatch.PALLAS_INTERPRET)
        assert out.shape == (16, 64) and bool(jnp.isfinite(out).all())

    def test_legacy_interpret_kwarg_maps_to_backend(self):
        w = rand((64, 128), seed=6)
        out = sefp_quantize_pallas(w, 4, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out),
            np.asarray(sefp_quantize_pallas(
                w, 4, backend=dispatch.PALLAS_INTERPRET)))


class TestBackendAgreement:
    """pallas-interpret and jax-ref must agree BITWISE: they implement the
    same normative numerics (DESIGN.md §4), differing only in tiling, and
    the shapes here keep the matmul to a single k-tile so even fp32
    accumulation order is identical."""

    @pytest.mark.parametrize("m", [8, 6, 4, 3])
    def test_quant_bitwise(self, m):
        w = rand((256, 384), seed=10 + m)
        a = sefp_quantize_pallas(w, m, backend=dispatch.PALLAS_INTERPRET)
        b = sefp_quantize_pallas(w, m, backend=dispatch.JAX_REF)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("m", [8, 6, 4, 3])
    def test_pack_bitwise(self, m):
        # packing is m-independent (the master is always M8); sweep m via
        # scale to vary the exponent field instead.
        w = rand((256, 384), seed=20, scale=10.0 ** (m - 5))
        a = sefp_pack_pallas(w, backend=dispatch.PALLAS_INTERPRET)
        b = sefp_pack_pallas(w, backend=dispatch.JAX_REF)
        np.testing.assert_array_equal(np.asarray(a.mag), np.asarray(b.mag))
        np.testing.assert_array_equal(np.asarray(a.sign_bits),
                                      np.asarray(b.sign_bits))
        np.testing.assert_array_equal(np.asarray(a.exp), np.asarray(b.exp))

    @pytest.mark.parametrize("m", [8, 6, 4, 3])
    def test_matmul_bitwise(self, m):
        x = rand((16, 128), seed=30 + m)
        p = packed_lib.pack(rand((128, 128), seed=40 + m), group_axis=0)
        a = sefp_matmul(x, p, m, backend=dispatch.PALLAS_INTERPRET)
        b = sefp_matmul(x, p, m, backend=dispatch.JAX_REF)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("m", [8, 6, 4, 3])
    def test_gemv_bitwise_multi_tile(self, m):
        # the gemv oracle mirrors the kernel's (n, k) tiling exactly, so
        # unlike the square kernel above, bitwise agreement holds even
        # with MULTIPLE k tiles (fp32 accumulation order is contractual).
        x = rand((4, 256), seed=60 + m)
        p = packed_lib.pack(rand((256, 256), seed=70 + m), group_axis=0)
        a = sefp_matmul_gemv(x, p, m, block_n=128, block_k=128,
                             backend=dispatch.PALLAS_INTERPRET)
        b = sefp_matmul_gemv(x, p, m, block_n=128, block_k=128,
                             backend=dispatch.JAX_REF)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ref_backends_match_standalone_oracles(self):
        w = rand((128, 128), seed=50)
        x = rand((8, 128), seed=51)
        np.testing.assert_array_equal(
            np.asarray(sefp_quantize_pallas(w, 5, backend=dispatch.JAX_REF)),
            np.asarray(sefp_quantize_ref(w, 5)))
        mag, sgn, e = sefp_pack_ref(w)
        p = sefp_pack_pallas(w, backend=dispatch.JAX_REF)
        np.testing.assert_array_equal(np.asarray(p.mag), np.asarray(mag))
        np.testing.assert_array_equal(
            np.asarray(sefp_matmul(x, p, 6, backend=dispatch.JAX_REF)),
            np.asarray(sefp_matmul_ref(x, mag, sgn, e, 6)))
        np.testing.assert_array_equal(
            np.asarray(sefp_matmul_gemv(x, p, 6, backend=dispatch.JAX_REF)),
            np.asarray(sefp_matmul_gemv_ref(x, mag, sgn, e, 6)))


class TestCompat:
    def test_make_mesh_shapes(self):
        n = len(jax.devices())
        mesh = compat.make_mesh((n, 1), ("data", "model"))
        assert dict(mesh.shape) == {"data": n, "model": 1}

    def test_set_mesh_makes_mesh_ambient(self):
        n = len(jax.devices())
        mesh = compat.make_mesh((n,), ("data",))
        assert compat.ambient_mesh() is None
        with compat.set_mesh(mesh):
            ambient = compat.ambient_mesh()
            assert ambient is not None and "data" in ambient.axis_names
        assert compat.ambient_mesh() is None

    def test_manual_axis_names_empty_outside_shard_map(self):
        n = len(jax.devices())
        mesh = compat.make_mesh((n,), ("data",))
        assert compat.manual_axis_names(mesh) == frozenset()

    def test_compat_is_sole_owner(self):
        # No file under src/ other than compat.py may reference the
        # version-gated symbols directly.
        src = pathlib.Path(__file__).resolve().parent.parent / "src"
        pat = re.compile(r"pallas.tpu|AxisType|get_abstract_mesh")
        offenders = [
            str(f) for f in src.rglob("*.py")
            if f.name != "compat.py" and pat.search(f.read_text())
        ]
        assert offenders == []
