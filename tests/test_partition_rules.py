"""Unit tests for the sharding rules (repro/sharding/partition.py):
path-based dispatch, divisibility fallback, stacked/expert leading dims,
batch layouts, cache layouts."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.kernels import compat  # noqa: E402
from repro.sharding import partition as SH  # noqa: E402


def mesh2(data=4, model=2):
    return compat.make_mesh((data, model), ("data", "model"))


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


class TestParamRules:
    def test_column_and_row_parallel(self):
        mesh = mesh2()
        tree = {"layers": {"attn": {"wq": sds((8, 16)), "wo": sds((16, 8))},
                           "mlp": {"w_gate": sds((8, 32)),
                                   "w_down": sds((32, 8))}}}
        specs = SH.param_pspecs(tree, mesh)
        assert specs["layers"]["attn"]["wq"] == P("data", "model")
        assert specs["layers"]["attn"]["wo"] == P("model", "data")
        assert specs["layers"]["mlp"]["w_gate"] == P("data", "model")
        assert specs["layers"]["mlp"]["w_down"] == P("model", "data")

    def test_stacked_and_expert_leading_dims_replicated(self):
        mesh = mesh2()
        tree = {"w_gate": sds((6, 8, 16)),          # [L, in, out]
                "w_down": sds((6, 3, 16, 8))}       # [L, E, in, out]
        specs = SH.param_pspecs(tree, mesh)
        assert specs["w_gate"] == P(None, "data", "model")
        assert specs["w_down"] == P(None, None, "model", "data")

    def test_divisibility_fallback(self):
        mesh = mesh2(data=4, model=2)
        tree = {"wq": sds((7, 16)),       # 7 % 4 != 0 -> in dim replicated
                "w_unembed": sds((8, 9))}  # 9 % 2 != 0 -> vocab replicated
        specs = SH.param_pspecs(tree, mesh)
        assert specs["wq"] == P(None, "model")
        assert specs["w_unembed"] == P("data", None)

    def test_small_params_replicated(self):
        mesh = mesh2()
        tree = {"norm_scale": sds((8,)), "q_bias": sds((16,))}
        specs = SH.param_pspecs(tree, mesh)
        assert specs["norm_scale"] == P()
        assert specs["q_bias"] == P()

    def test_packed_leaves_inherit_rule(self):
        # stacked-master children ({w}/mag, {w}/sign, {w}/exp) inherit the
        # rule of the weight they pack (core/packed.py stacked layout)
        mesh = mesh2()
        tree = {"wq": {"mag": sds((8, 16), jnp.uint8),
                       "sign": sds((1, 16), jnp.uint8),
                       "exp": sds((2, 16), jnp.int8)}}
        specs = SH.param_pspecs(tree, mesh)
        assert specs["wq"]["mag"] == P("data", "model")
        # sign/exp dim0 (K/8, K/64) is not divisible by data=4 -> fallback
        assert specs["wq"]["sign"] == P(None, "model")
        assert specs["wq"]["exp"] == P(None, "model")
        big = {"wo": {"mag": sds((64, 16), jnp.uint8),
                      "exp": sds((1, 16), jnp.int8)}}
        specs = SH.param_pspecs(big, mesh)
        assert specs["wo"]["mag"] == P("model", "data")

    def test_embedding_model_sharded_on_dmodel(self):
        mesh = mesh2()
        specs = SH.param_pspecs({"embedding": sds((100, 16))}, mesh)
        assert specs["embedding"] == P(None, "model")


class TestBatchLayouts:
    def test_tp_layout(self):
        mesh = mesh2()
        specs = SH.batch_pspecs({"inputs": sds((8, 32), jnp.int32)}, mesh)
        assert specs["inputs"] == P(("data",), None)

    def test_dp_layout_uses_model_axis(self):
        mesh = mesh2()
        specs = SH.batch_pspecs({"inputs": sds((8, 32), jnp.int32)}, mesh,
                                layout="dp")
        assert specs["inputs"] == P(("data", "model"), None)

    def test_pod_layout(self):
        mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        specs = SH.batch_pspecs({"inputs": sds((8, 32), jnp.int32)}, mesh,
                                layout="pod")
        assert specs["inputs"] == P(("pod",), None)

    def test_indivisible_batch_falls_back(self):
        mesh = mesh2(data=4, model=2)
        specs = SH.batch_pspecs({"inputs": sds((2, 32), jnp.int32)}, mesh)
        assert specs["inputs"] == P()


class TestCacheLayouts:
    KV = {"layers": {"k": sds((4, 8, 64, 2, 16)),
                     "v": sds((4, 8, 64, 2, 16))}}

    def test_seq_layout(self):
        mesh = mesh2(data=4, model=2)
        specs = SH.cache_pspecs(self.KV, mesh)
        assert specs["layers"]["k"] == P(None, ("data",), "model", None,
                                         None)

    def test_heads_layout_when_divisible(self):
        mesh = mesh2(data=4, model=2)
        specs = SH.cache_pspecs(self.KV, mesh, kv_layout="heads")
        assert specs["layers"]["k"] == P(None, ("data",), None, "model",
                                         None)

    def test_heads_layout_falls_back_to_seq(self):
        mesh = mesh2(data=2, model=4)  # KV=2 not divisible by 4
        specs = SH.cache_pspecs(self.KV, mesh, kv_layout="heads")
        assert specs["layers"]["k"] == P(None, ("data",), "model", None,
                                         None)

    def test_ssm_state_heads_sharded(self):
        mesh = mesh2(data=4, model=2)
        tree = {"layers": {"ssm_state": sds((4, 8, 6, 16, 16))}}
        specs = SH.cache_pspecs(tree, mesh)
        assert specs["layers"]["ssm_state"] == P(None, ("data",), "model",
                                                 None, None)
