"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp oracles (interpret=True executes kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packed as packed_lib
from repro.core import sefp as sefp_core
from repro.kernels.sefp_quant import sefp_quantize_pallas
from repro.kernels.sefp_quant.ref import sefp_quantize_ref
from repro.kernels.sefp_matmul import sefp_matmul, sefp_matmul_gemv
from repro.kernels.sefp_matmul.ref import sefp_matmul_ref


def rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


QUANT_SHAPES = [(64, 128), (128, 128), (256, 512), (192, 384), (640, 256)]
MM_SHAPES = [  # (M, K, N)
    (8, 64, 128),
    (16, 128, 128),
    (128, 256, 512),
    (1, 512, 256),
    (64, 384, 192),
]


class TestSefpQuantKernel:
    @pytest.mark.parametrize("shape", QUANT_SHAPES)
    @pytest.mark.parametrize("m", [8, 5, 3])
    def test_matches_ref(self, shape, m):
        w = rand(shape, seed=shape[0] + m)
        out = sefp_quantize_pallas(w, m)
        ref = sefp_quantize_ref(w, m)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0, atol=0)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        w = rand((128, 256), seed=1, dtype=dtype)
        out = sefp_quantize_pallas(w, 5)
        ref = sefp_quantize_ref(w, 5)
        assert out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=0, atol=0)

    def test_matches_core_semantics(self):
        # kernel == the framework-wide fake-quant (core.sefp) semantics
        w = rand((256, 128), seed=2)
        for m in sefp_core.MANTISSA_WIDTHS:
            out = sefp_quantize_pallas(w, m)
            core = sefp_core.sefp_quantize(w, m, group_axis=0)
            np.testing.assert_allclose(np.asarray(out), np.asarray(core),
                                       rtol=0, atol=0)

    def test_dynamic_m_one_executable(self):
        w = rand((128, 128), seed=3)
        outs = {m: np.asarray(sefp_quantize_pallas(w, jnp.int32(m)))
                for m in (8, 6, 3)}
        for m, o in outs.items():
            np.testing.assert_allclose(
                o, np.asarray(sefp_quantize_ref(w, m)), rtol=0, atol=0)

    def test_extreme_scales(self):
        for scale in (1e-6, 1.0, 1e4):
            w = rand((64, 128), seed=4, scale=scale)
            out = sefp_quantize_pallas(w, 4)
            ref = sefp_quantize_ref(w, 4)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=0, atol=0)
            assert jnp.isfinite(out).all()


class TestSefpMatmulKernel:
    @pytest.mark.parametrize("mkn", MM_SHAPES)
    @pytest.mark.parametrize("m_bits", [8, 6, 4, 3])
    def test_matches_ref(self, mkn, m_bits):
        M, K, N = mkn
        x = rand((M, K), seed=M + K)
        w = rand((K, N), seed=K + N)
        p = packed_lib.pack(w, group_axis=0)
        out = sefp_matmul(x, p, m_bits)
        ref = sefp_matmul_ref(x, p.mag, p.sign_bits, p.exp, m_bits)
        # fp32 accumulation order differs between tiled and single dot
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_matches_dequant_matmul(self):
        # end-to-end: kernel == x @ core.packed.dequantize(p, m) in bf16
        x = rand((32, 256), seed=7)
        w = rand((256, 128), seed=8)
        p = packed_lib.pack(w, group_axis=0)
        for m_bits in (8, 5, 3):
            out = sefp_matmul(x, p, m_bits)
            wd = packed_lib.dequantize(p, m_bits).astype(jnp.bfloat16)
            ref = jnp.dot(x.astype(jnp.bfloat16), wd,
                          preferred_element_type=jnp.float32)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-3, atol=2e-3)

    def test_batched_leading_dims(self):
        x = rand((2, 4, 128), seed=9)
        w = rand((128, 64), seed=10)
        p = packed_lib.pack(w, group_axis=0)
        out = sefp_matmul(x, p, 6)
        assert out.shape == (2, 4, 64)
        flat = sefp_matmul(x.reshape(8, 128), p, 6)
        np.testing.assert_array_equal(np.asarray(out).reshape(8, 64),
                                      np.asarray(flat))

    def test_runtime_precision_switch_is_cheap(self):
        # same jitted executable must serve all widths (no recompile):
        # results at each width equal the per-width oracle.
        x = rand((16, 128), seed=11)
        w = rand((128, 128), seed=12)
        p = packed_lib.pack(w, group_axis=0)
        for m_bits in (8, 7, 6, 5, 4, 3):
            out = sefp_matmul(x, p, jnp.int32(m_bits))
            ref = sefp_matmul_ref(x, p.mag, p.sign_bits, p.exp, m_bits)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-3, atol=2e-3)

    def test_truncation_improves_with_width(self):
        x = rand((8, 512), seed=13)
        w = rand((512, 64), seed=14)
        p = packed_lib.pack(w, group_axis=0)
        exact = np.asarray(x @ w)
        errs = [float(np.abs(np.asarray(sefp_matmul(x, p, m)) - exact).mean())
                for m in (8, 6, 4, 3)]
        assert errs[0] <= errs[1] <= errs[2] <= errs[3]


class TestSefpGemvKernel:
    """Decode-shaped path: tall-skinny x, 2-D (n, k) grid, whole row block
    resident.  The oracle mirrors the tiling, so agreement is BITWISE (the
    serving acceptance bar — argmax over logits must not depend on which
    backend computed them)."""

    @pytest.mark.parametrize("rows", [1, 2, 4, 8])
    @pytest.mark.parametrize("m_bits", [8, 6, 4, 3])
    def test_bitwise_vs_oracle(self, rows, m_bits):
        x = rand((rows, 256), seed=20 + rows)
        w = rand((256, 256), seed=21 + m_bits)
        p = packed_lib.pack(w, group_axis=0)
        a = sefp_matmul_gemv(x, p, m_bits, block_n=128, block_k=128,
                             backend="pallas-interpret")
        b = sefp_matmul_gemv(x, p, m_bits, block_n=128, block_k=128,
                             backend="jax-ref")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_row_padding_is_invisible(self):
        # M=3 pads to the sublane multiple internally; results must equal
        # the unpadded rows of an M=8 call on the same data.
        x8 = rand((8, 128), seed=30)
        w = rand((128, 128), seed=31)
        p = packed_lib.pack(w, group_axis=0)
        full = sefp_matmul_gemv(x8, p, 5, backend="jax-ref")
        part = sefp_matmul_gemv(x8[:3], p, 5, backend="jax-ref")
        np.testing.assert_array_equal(np.asarray(full)[:3], np.asarray(part))

    def test_matches_square_kernel_to_tolerance(self):
        # same contract as sefp_matmul; only the fp32 accumulation tiling
        # differs between the two paths.
        x = rand((4, 512), seed=32)
        w = rand((512, 256), seed=33)
        p = packed_lib.pack(w, group_axis=0)
        for m_bits in (8, 5, 3):
            a = sefp_matmul_gemv(x, p, m_bits, backend="jax-ref")
            b = sefp_matmul_ref(x, p.mag, p.sign_bits, p.exp, m_bits)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)

    def test_traced_m_and_leading_dims(self):
        x = rand((2, 1, 128), seed=34)
        w = rand((128, 64), seed=35)
        p = packed_lib.pack(w, group_axis=0)

        @jax.jit
        def f(x, m):
            return sefp_matmul_gemv(x, p, m, backend="jax-ref")

        out = f(x, jnp.int32(4))
        assert out.shape == (2, 1, 64)
        ref = sefp_matmul_gemv(x.reshape(2, 128), p, 4, backend="jax-ref")
        np.testing.assert_array_equal(np.asarray(out).reshape(2, 64),
                                      np.asarray(ref))
