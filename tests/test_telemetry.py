"""Serving telemetry tests (DESIGN.md §16): the metrics registry and its
Prometheus text exposition (naming, label escaping, histogram bucket
monotonicity), the bounded Chrome-trace Tracer (per-track ts ordering,
matched B/E spans), and the scheduler integration — the registry is the
ONE source of truth behind ``scheduler.stats`` (``json.dumps`` must always
succeed on it), ``FinishedRequest.wall`` carries wall-clock TTFT/ITL under
``Telemetry``, and a mixed speculative/plain + slo-degrade workload's
per-request width timeline in the trace reconciles EXACTLY with
``width_counts()`` / ``tokens_by_width``."""

import collections
import json
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.models import model_zoo as Z
from repro.models.config import ModelConfig
from repro.policy import PrecisionPolicy
from repro.serve import SwitchableServer
from repro.serve.scheduler import SLODegradePolicy
from repro.serve.telemetry import (
    LATENCY_BUCKETS,
    MetricsRegistry,
    NullTelemetry,
    Telemetry,
    Tracer,
    json_sanitize,
    parse_prometheus,
    render_report,
    serve_metrics,
    validate_trace,
)

CFG = ModelConfig(name="sched-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, q_block=16, kv_block=16, loss_chunk=16,
                  remat="none", dtype="bfloat16")


@pytest.fixture(scope="module")
def server():
    params = Z.init_params(CFG, jax.random.PRNGKey(0))
    srv = SwitchableServer(CFG, params, max_len=96)
    srv.set_policy(PrecisionPolicy.all_widths()
                   .with_class("generation", 8)
                   .with_class("understanding", 4))
    return srv


def prompt(n=12, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, (n,)).astype(np.int32)


# ---------------------------------------------------------------------------
# metrics registry + Prometheus exposition
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_basics(self):
        r = MetricsRegistry()
        c = r.counter("t_requests_total", "reqs", labels=("event",))
        c.labels(event="admitted").inc()
        c.labels(event="admitted").inc(3)
        c.labels(event="rejected").inc()
        g = r.gauge("t_depth", "queue depth")
        g.child().set(7)
        assert r.value("t_requests_total", event="admitted") == 4
        assert r.value("t_requests_total", event="rejected") == 1
        assert r.value("t_depth") == 7
        assert r.series("t_requests_total") == {("admitted",): 4,
                                                ("rejected",): 1}
        # absent family / absent labeled series
        assert r.value("t_nope") is None
        assert r.value("t_requests_total", event="nope") is None

    def test_gauge_set_function_reads_live(self):
        r = MetricsRegistry()
        state = {"v": 1}
        r.gauge("t_live", "").child().set_function(lambda: state["v"])
        assert r.value("t_live") == 1
        state["v"] = 42
        assert r.value("t_live") == 42

    def test_collect_callback_family(self):
        r = MetricsRegistry()
        src = {"hits": 3, "misses": 1}
        fam = r.counter("t_cache_total", "", labels=("event",))
        fam.set_collect(lambda: {(k,): v for k, v in src.items()})
        assert r.value("t_cache_total", event="hits") == 3
        src["hits"] = 5
        assert r.value("t_cache_total", event="hits") == 5

    def test_invalid_names_rejected(self):
        r = MetricsRegistry()
        with pytest.raises(ValueError):
            r.counter("2bad", "")
        with pytest.raises(ValueError):
            r.counter("bad-dash", "")
        with pytest.raises(ValueError):
            r.counter("t_ok", "", labels=("bad-label",))
        with pytest.raises(ValueError):
            r.counter("t_ok2", "", labels=("__reserved",))

    def test_reregistration(self):
        r = MetricsRegistry()
        a = r.counter("t_x_total", "", labels=("w",))
        assert r.counter("t_x_total", "", labels=("w",)) is a
        with pytest.raises(ValueError):
            r.gauge("t_x_total", "")          # kind conflict
        with pytest.raises(ValueError):
            r.counter("t_x_total", "", labels=("other",))  # label conflict

    def test_labels_must_match_schema(self):
        r = MetricsRegistry()
        fam = r.counter("t_y_total", "", labels=("w",))
        with pytest.raises(ValueError):
            fam.labels(other="1")
        with pytest.raises(ValueError):
            fam.child()                       # labeled family has no child()

    def test_histogram_validation(self):
        r = MetricsRegistry()
        with pytest.raises(ValueError):
            r.histogram("t_h", "", buckets=())
        with pytest.raises(ValueError):
            r.histogram("t_h", "", buckets=(1.0, 1.0, 2.0))  # not strict
        with pytest.raises(ValueError):
            r.histogram("t_h", "", buckets=(2.0, 1.0))       # decreasing
        with pytest.raises(ValueError):
            r.histogram("t_h", "", labels=("le",))           # reserved

    def test_histogram_observe_and_exposition(self):
        r = MetricsRegistry()
        h = r.histogram("t_lat_seconds", "latency", buckets=(0.1, 1.0, 10.0))
        ch = h.child()
        for x in (0.05, 0.5, 0.5, 5.0, 50.0):
            ch.observe(x)
        text = r.render_prometheus()
        assert "# TYPE t_lat_seconds histogram" in text
        parsed = parse_prometheus(text)
        samples = {(n, labels.get("le")): v
                   for n, labels, v in parsed["t_lat_seconds"]["samples"]}
        # cumulative buckets: 1, 3, 4, +Inf == 5
        assert samples[("t_lat_seconds_bucket", "0.1")] == 1
        assert samples[("t_lat_seconds_bucket", "1.0")] == 3
        assert samples[("t_lat_seconds_bucket", "10.0")] == 4
        assert samples[("t_lat_seconds_bucket", "+Inf")] == 5
        assert samples[("t_lat_seconds_count", None)] == 5
        assert samples[("t_lat_seconds_sum", None)] == pytest.approx(56.05)

    def test_label_escaping_round_trips(self):
        r = MetricsRegistry()
        nasty = 'a\\b"c\nd'
        r.counter("t_esc_total", 'help with \\ and\nnewline',
                  labels=("cls",)).labels(cls=nasty).inc()
        text = r.render_prometheus()
        assert '\\\\' in text and '\\"' in text and "\\n" in text
        parsed = parse_prometheus(text)
        (_, labels, v), = parsed["t_esc_total"]["samples"]
        assert labels == {"cls": nasty}
        assert v == 1

    def test_exposition_has_help_and_type(self):
        r = MetricsRegistry()
        r.counter("t_a_total", "the a").child().inc()
        text = r.render_prometheus()
        assert "# HELP t_a_total the a" in text
        assert "# TYPE t_a_total counter" in text

    def test_snapshot_json_serializable(self):
        r = MetricsRegistry()
        r.counter("t_c_total", "", labels=("w",)).labels(w="8").inc(2)
        r.histogram("t_h_seconds", "").child().observe(0.01)
        snap = json.loads(json.dumps(r.snapshot()))
        assert snap["t_c_total"]["samples"][0]["value"] == 2
        assert snap["t_h_seconds"]["samples"][0]["count"] == 1

    def test_default_buckets_are_strictly_increasing(self):
        assert list(LATENCY_BUCKETS) == sorted(set(LATENCY_BUCKETS))


class TestParsePrometheus:
    def test_rejects_malformed_sample(self):
        with pytest.raises(ValueError):
            parse_prometheus("this is not exposition format\n")

    def test_rejects_bad_metric_name_in_type(self):
        with pytest.raises(ValueError):
            parse_prometheus("# TYPE 2bad counter\n")

    def test_rejects_non_monotonic_histogram(self):
        text = "\n".join([
            "# TYPE t_h histogram",
            't_h_bucket{le="0.1"} 5',
            't_h_bucket{le="1.0"} 3',      # decreases: invalid
            't_h_bucket{le="+Inf"} 5',
            "t_h_sum 1.0",
            "t_h_count 5",
        ])
        with pytest.raises(ValueError, match="non-monotonic"):
            parse_prometheus(text)

    def test_accepts_monotonic_histogram(self):
        text = "\n".join([
            "# TYPE t_h histogram",
            't_h_bucket{le="0.1"} 1',
            't_h_bucket{le="+Inf"} 5',
            "t_h_sum 1.0",
            "t_h_count 5",
        ])
        assert "t_h" in parse_prometheus(text)


# ---------------------------------------------------------------------------
# tracer + trace validity
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_pairs_and_order(self):
        tr = Tracer()
        tr.name_track(1, "req 0")
        tr.begin("request", 1, rid=0)
        tr.instant("token", 1, width=8)
        tr.end("request", 1, status="ok")
        evs = tr.events()
        assert evs[0]["ph"] == "M"            # metadata first
        assert [e["ph"] for e in evs[1:]] == ["B", "i", "E"]
        assert validate_trace(evs) == []

    def test_ring_drops_oldest(self):
        tr = Tracer(max_events=4)
        for i in range(10):
            tr.instant(f"e{i}", 0)
        body = [e for e in tr.events() if e["ph"] != "M"]
        assert len(body) == 4
        assert [e["name"] for e in body] == ["e6", "e7", "e8", "e9"]
        assert tr.dropped == 6
        assert tr.chrome_trace()["otherData"]["dropped_events"] == 6

    def test_max_events_validated(self):
        with pytest.raises(ValueError):
            Tracer(max_events=0)

    def test_validate_catches_unmatched_spans(self):
        tr = Tracer()
        tr.end("request", 1)                  # E with no B
        errs = validate_trace(tr.events())
        assert any("without a matching B" in e for e in errs)
        tr2 = Tracer()
        tr2.begin("request", 1)               # B never ended
        errs2 = validate_trace(tr2.events())
        assert any("never ended" in e for e in errs2)

    def test_validate_catches_ts_regression(self):
        evs = [{"name": "a", "ph": "i", "pid": 0, "tid": 3, "ts": 10.0},
               {"name": "b", "ph": "i", "pid": 0, "tid": 3, "ts": 5.0}]
        errs = validate_trace(evs)
        assert any("ts" in e and "tid 3" in e for e in errs)

    def test_validate_catches_missing_keys(self):
        errs = validate_trace([{"ph": "i", "tid": 0, "ts": 0.0}])
        assert any("missing" in e for e in errs)

    def test_complete_event_duration(self):
        tr = Tracer()
        t0 = tr.now()
        tr.complete("chunk", 2, t0, tokens=16)
        (ev,) = [e for e in tr.events() if e["ph"] == "X"]
        assert ev["dur"] >= 0 and ev["args"]["tokens"] == 16

    def test_write_chrome_trace_and_jsonl(self, tmp_path):
        tr = Tracer()
        tr.name_track(1, "req 0")
        tr.begin("request", 1)
        tr.end("request", 1)
        p_json = tmp_path / "trace.json"
        p_jsonl = tmp_path / "trace.jsonl"
        tr.write_chrome_trace(str(p_json))
        tr.write_jsonl(str(p_jsonl))
        doc = json.loads(p_json.read_text())
        assert doc["displayTimeUnit"] == "ms"
        assert validate_trace(doc["traceEvents"]) == []
        lines = [json.loads(ln)
                 for ln in p_jsonl.read_text().splitlines()]
        assert validate_trace(lines) == []
        assert len(lines) == len(doc["traceEvents"])


class TestMetricsServer:
    def test_scrape_round_trip(self):
        r = MetricsRegistry()
        r.counter("t_up_total", "is it up").child().inc(3)
        srv = serve_metrics(r, port=0)
        try:
            assert srv.port != 0
            text = srv.scrape()
            parsed = parse_prometheus(text)
            (_, _, v), = parsed["t_up_total"]["samples"]
            assert v == 3
            # non-/metrics paths 404
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    srv.url.replace("/metrics", "/other"), timeout=10)
        finally:
            srv.close()


# ---------------------------------------------------------------------------
# json_sanitize
# ---------------------------------------------------------------------------

class TestJsonSanitize:
    def test_numpy_scalars_arrays_and_keys(self):
        obj = {
            np.int32(8): np.int64(3),
            "arr": np.arange(3, dtype=np.int32),
            "ctr": collections.Counter({np.int32(4): 2}),
            "t": (np.float32(0.5), 1),
            "plain": {"s": "x", "n": None, "b": True},
        }
        out = json_sanitize(obj)
        assert out[8] == 3
        assert out["arr"] == [0, 1, 2]
        assert out["ctr"] == {4: 2}
        assert out["t"] == [0.5, 1]
        json.dumps(out)  # must not raise


# ---------------------------------------------------------------------------
# SLODegradePolicy bounded trace ring
# ---------------------------------------------------------------------------

class TestSLOTraceRing:
    def _pressure(self, qd):
        return {"queue_depth": qd, "active": 0, "slots": 4,
                "widths": (8, 6, 4, 3)}

    def test_trace_len_validated(self):
        with pytest.raises(ValueError):
            SLODegradePolicy(trace_len=0)

    def test_ring_bounds_trace_and_max_shift_stays_exact(self):
        sd = SLODegradePolicy(queue_high=1, queue_low=0, hold_steps=1,
                              trace_len=4)
        clock = 0
        for _ in range(10):                   # 10 escalate-to-3 / relieve
            for _ in range(3):
                clock += 1
                sd.observe(dict(self._pressure(5), clock=clock))
            for _ in range(3):
                clock += 1
                sd.observe(dict(self._pressure(0), clock=clock))
        deg = sd.degradation
        assert deg["escalations"] == 30
        assert len(deg["trace"]) == 4         # ring kept the newest window
        # max_shift_seen is a running max, exact despite 56 dropped
        # transitions (the ladder cap is len(ladder) - 1 == 3)
        assert deg["max_shift_seen"] == 3
        assert deg["shift"] == 0
        # shape pinned: list of (clock, shift) pairs, newest last
        assert all(len(t) == 2 for t in deg["trace"])
        assert deg["trace"][-1] == (clock, 0)

    def test_shift_causes_recorded(self):
        sd = SLODegradePolicy(queue_high=2, queue_low=0, hold_steps=1)
        sd.observe(dict(self._pressure(5), clock=1))
        assert sd.last_shift_cause == "queue_depth"
        sd.observe({"queue_depth": 1, "active": 4, "slots": 4, "clock": 2,
                    "widths": (8, 6, 4, 3)})
        assert sd.last_shift_cause == "slots_full_backlog"
        sd.observe(dict(self._pressure(0), clock=3))
        assert sd.last_shift_cause == "relief"
        lat = SLODegradePolicy(slo_step_seconds=0.01, queue_high=10_000,
                               hold_steps=1)
        lat.observe(dict(self._pressure(0), step_seconds=5.0, clock=1))
        assert lat.last_shift_cause == "latency_ewma"


# ---------------------------------------------------------------------------
# scheduler integration
# ---------------------------------------------------------------------------

class TestSchedulerIntegration:
    def test_null_telemetry_default(self, server):
        sched = server.continuous(slots=2)
        assert isinstance(sched.telemetry, NullTelemetry)
        assert not sched.telemetry.enabled
        rid = sched.submit(prompt(seed=1), 4, request_class="generation",
                           seed=0)
        done = sched.drain(max_steps=500)
        assert done[rid].wall is None         # wall clock gated off
        # the registry is live even without telemetry: one source of truth
        stats = sched.stats
        assert sched.metrics.value("otaro_serve_steps_total") \
            == stats["steps"]
        assert sched.metrics.value("otaro_serve_requests_total",
                                   event="finished") == 1
        parse_prometheus(sched.metrics.render_prometheus())

    def test_wall_clock_on_finished_request(self, server):
        sched = server.continuous(slots=2, telemetry=Telemetry())
        rid = sched.submit(prompt(seed=2), 5, request_class="generation",
                           seed=0)
        done = sched.drain(max_steps=500)
        w = done[rid].wall
        assert w is not None
        assert w["ttft_s"] >= 0
        assert w["finish_s"] >= w["first_token_s"] >= w["submit_s"]
        assert w["itl_mean_s"] >= 0           # 5 tokens -> ITL defined
        # TTFT/ITL histograms per precision class on the registry
        ttft = sched.metrics.value("otaro_serve_ttft_seconds",
                                   request_class="generation")
        itl = sched.metrics.value("otaro_serve_itl_seconds",
                                  request_class="generation")
        assert ttft.count == 1
        assert itl.count == len(done[rid].tokens) - 1

    def test_telemetry_true_shorthand(self, server):
        sched = server.continuous(slots=1, telemetry=True)
        assert isinstance(sched.telemetry, Telemetry)
        assert sched.telemetry.registry is sched.metrics

    def test_mixed_spec_slo_workload_trace_reconciles(self, server):
        """The acceptance workload: speculative decode + slo-degrade under
        queue pressure.  Healthy (shift 0) steps run the m=8 speculative
        macro-step; escalated steps downshift below the verify width and
        commit plain — the trace must show both, stay structurally valid,
        and its per-request width timeline must reconcile EXACTLY with
        width_counts() / tokens_by_width."""
        tel = Telemetry()
        sd = SLODegradePolicy(queue_high=3, queue_low=0, hold_steps=2)
        sched = server.continuous(
            slots=2, width_policy=sd, telemetry=tel,
            spec_decode={"k": 3, "draft_width": 6, "candidates": (4, 6)})
        # calm phase: no queue pressure, shift stays 0, the m=8 rows run
        # the speculative macro-step
        rids = [sched.submit(prompt(seed=10 + i), 8,
                             request_class="generation", seed=i)
                for i in range(2)]
        done = dict(sched.drain(max_steps=2_000))
        # burst phase: 6 requests into 2 slots crosses queue_high, the
        # policy escalates, realized width drops below the verify width
        # and commits go through the plain path
        rids += [sched.submit(prompt(seed=20 + i), 8,
                              request_class="generation", seed=10 + i)
                 for i in range(6)]
        done.update(sched.drain(max_steps=2_000))
        stats = sched.stats
        evs = tel.tracer.events()

        # structurally valid Chrome trace: ts ordered per track, B/E paired
        assert validate_trace(evs) == []
        names = collections.Counter(e["name"] for e in evs)
        assert names["request"] == 2 * len(rids)      # B + E per request
        assert names["admitted"] == len(rids)
        assert names["first_token"] == len(rids)
        assert names["spec_macro"] > 0                # speculation engaged
        assert names["slo_escalation"] >= 1           # pressure escalated
        esc = next(e for e in evs if e["name"] == "slo_escalation")
        assert esc["args"]["cause"] == "queue_depth"
        assert esc["tid"] == 0                        # scheduler track

        # width-timeline reconciliation: trace "token" events vs the
        # request-level and registry-level accounting
        trace_widths = collections.Counter(
            e["args"]["width"] for e in evs if e["name"] == "token")
        agg = collections.Counter()
        for fr in done.values():
            agg.update(fr.width_counts())
        assert trace_widths == agg
        assert dict(trace_widths) == stats["tokens_by_width"]
        # both the spec verify width and a downshifted width committed
        assert 8 in trace_widths and any(w < 8 for w in trace_widths)

        # per-request trace timeline: submit < admitted < first_token <=
        # tokens <= retire, all on the request's own track (tid = rid + 1)
        for rid in rids:
            tid = rid + 1
            row = [e for e in evs if e.get("tid") == tid
                   and e["ph"] != "M"]
            assert row[0]["ph"] == "B" and row[-1]["ph"] == "E"
            assert [e["ts"] for e in row] == sorted(e["ts"] for e in row)

        # wall-clock histograms per class
        ttft = sched.metrics.value("otaro_serve_ttft_seconds",
                                   request_class="generation")
        assert ttft.count == len(rids)
        # speculative accounting exposed through the registry collect hooks
        sp = stats["speculative"]
        drafted = sum(sched.metrics.series("otaro_spec_drafted_total")
                      .values())
        assert drafted == sp["drafted"]
        assert sched.metrics.value("otaro_spec_macro_steps_total") \
            == sp["macro_steps"]
        # exposition of the whole registry stays valid under the mix
        parse_prometheus(sched.metrics.render_prometheus())
        json.dumps(stats)

    def test_quarantine_event_in_trace(self, server):
        from repro.serve.faults import NaNLogitsFault
        tel = Telemetry()
        sched = server.continuous(slots=2, telemetry=tel,
                                  faults=[NaNLogitsFault(slot=0, step=2)])
        rid = sched.submit(prompt(seed=30), 8, request_class="generation",
                           seed=0)
        done = sched.drain(max_steps=500)
        assert done[rid].status == "poisoned"
        qs = [e for e in tel.tracer.events() if e["name"] == "quarantine"]
        assert len(qs) == 1 and qs[0]["args"]["slot"] == 0
        assert sched.metrics.value("otaro_serve_requests_total",
                                   event="poisoned") == 1

    def test_paged_gauges_and_prefix_events(self, server):
        tel = Telemetry()
        sched = server.continuous(slots=2, page_size=16, n_pages=13,
                                  prefill_chunk=16, telemetry=tel)
        doc = prompt(32, seed=40)
        sched.submit(doc, 2, request_class="understanding", seed=0)
        sched.drain(max_steps=500)
        sched.submit(doc, 2, request_class="understanding", seed=1)
        sched.drain(max_steps=500)
        assert sched.metrics.value("otaro_serve_pages") == 13
        assert sched.metrics.value("otaro_serve_pages_high_water") > 0
        assert sched.metrics.value("otaro_serve_prefix_cache_events_total",
                                   event="hits") >= 1
        hits = [e for e in tel.tracer.events()
                if e["name"] == "prefix_hit"]
        assert hits and hits[0]["args"]["pages"] >= 1
        assert sched.metrics.value("otaro_serve_reused_pages_total") >= 1

    def test_render_report_lines(self, server):
        sched = server.continuous(slots=2, telemetry=Telemetry())
        sched.submit(prompt(seed=50), 4, request_class="generation", seed=0)
        sched.drain(max_steps=500)
        lines = render_report(sched)
        assert any(ln.startswith("width steps:") for ln in lines)
        assert any(ln.startswith("tokens by width:") for ln in lines)
        assert any(ln.startswith("latency[generation]:") for ln in lines)


# ---------------------------------------------------------------------------
# stats JSON round-trip regression (every policy, spec, faults)
# ---------------------------------------------------------------------------

class TestStatsJsonRoundTrip:
    def _assert_round_trips(self, sched):
        stats = sched.stats
        text = json.dumps(stats)              # must not raise
        back = json.loads(text)
        assert back["steps"] == stats["steps"]
        assert back["committed_tokens"] == stats["committed_tokens"]

    @pytest.mark.parametrize("policy", ["max-width", "width-rr",
                                        "heterogeneous", "slo-degrade"])
    def test_all_width_policies(self, server, policy):
        sched = server.continuous(slots=2, width_policy=policy)
        for i in range(3):
            sched.submit(prompt(seed=60 + i), 4,
                         request_class=("generation" if i % 2 == 0
                                        else "understanding"), seed=i)
        sched.drain(max_steps=1_000)
        self._assert_round_trips(sched)

    def test_speculative_stats(self, server):
        sched = server.continuous(
            slots=2, spec_decode={"k": 3, "draft_width": 6,
                                  "candidates": (4, 6)})
        sched.submit(prompt(seed=70), 8, request_class="generation", seed=0)
        sched.drain(max_steps=1_000)
        self._assert_round_trips(sched)

    def test_faulted_stats(self, server):
        from repro.serve.faults import NaNLogitsFault
        sched = server.continuous(slots=2,
                                  faults=[NaNLogitsFault(slot=0, step=2)])
        sched.submit(prompt(seed=80), 6, request_class="generation", seed=0)
        sched.drain(max_steps=500)
        self._assert_round_trips(sched)

    def test_paged_stats(self, server):
        sched = server.continuous(slots=2, page_size=16, n_pages=13,
                                  prefill_chunk=16)
        sched.submit(prompt(32, seed=90), 2, request_class="understanding",
                     seed=0)
        sched.drain(max_steps=500)
        self._assert_round_trips(sched)
