"""Hypothesis property tests for the system's control-flow invariants
(BPS bandit accounting, LAA gradient conservation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bps as bps_lib
from repro.core import laa as laa_lib


@settings(max_examples=30, deadline=None)
@given(losses=st.lists(st.floats(0.1, 10.0), min_size=8, max_size=40),
       lam=st.floats(0.1, 10.0))
def test_bps_counter_conservation(losses, lam):
    """t == sum(t_b) after any update sequence, and every arm is visited
    once before any arm is visited twice (forced exploration)."""
    state = bps_lib.init(6)
    first_six = []
    for i, loss in enumerate(losses):
        arm, m = bps_lib.select(state, lam=lam)
        if i < 6:
            first_six.append(int(arm))
        state = bps_lib.update(state, arm, jnp.float32(loss))
    assert int(state.t) == len(losses)
    assert int(state.t_b.sum()) == len(losses)
    assert sorted(first_six) == list(range(6))


@settings(max_examples=30, deadline=None)
@given(seq=st.lists(st.tuples(st.floats(-3, 3), st.booleans()),
                    min_size=1, max_size=40),
       n_delay=st.integers(1, 7))
def test_laa_gradient_conservation(seq, n_delay):
    """Exact conservation: sum(applied effective grads) + final buffer ==
    sum(all grads).  Holds for ANY interleaving of low/high batches — the
    asynchronous buffer neither loses nor double-counts gradient mass."""
    state = laa_lib.init({"w": jnp.zeros((3,))})
    applied = np.zeros(3)
    total = np.zeros(3)
    for val, is_low in seq:
        g = {"w": jnp.full((3,), val, jnp.float32)}
        total += np.asarray(g["w"])
        eff, do, state = laa_lib.step(state, g, jnp.asarray(is_low), n_delay)
        if bool(do):
            applied += np.asarray(eff["w"])
    remainder = np.asarray(state.buf["w"])
    np.testing.assert_allclose(applied + remainder, total,
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n_low=st.integers(1, 30), n_delay=st.integers(1, 7))
def test_laa_release_cadence(n_low, n_delay):
    """Updates are released exactly every n_delay low batches."""
    state = laa_lib.init({"w": jnp.zeros(())})
    releases = 0
    for i in range(n_low):
        g = {"w": jnp.ones(())}
        eff, do, state = laa_lib.step(state, g, jnp.asarray(True), n_delay)
        if bool(do):
            releases += 1
            np.testing.assert_allclose(float(eff["w"]), n_delay)
    assert releases == n_low // n_delay
    assert int(state.count) == n_low % n_delay
