"""Serving-engine tests: device-resident fused decode, packed-master
fidelity, zero-cost runtime precision switching (incl. mid-generation),
fused-scan vs per-token agreement across kernel backends, batching
consistency, memory accounting."""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packed as packed_lib
from repro.models import model_zoo as Z
from repro.models.config import ModelConfig
from repro.serve import SwitchableServer
from repro.serve import engine as engine_mod
from repro.serve import packed_step as packed_step_mod

CFG = ModelConfig(name="serve-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, q_block=16, kv_block=16, loss_chunk=16,
                  remat="none", dtype="bfloat16")


@pytest.fixture(scope="module")
def params():
    return Z.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def server(params):
    return SwitchableServer(CFG, params, max_len=96)


def prompts(b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, (b, s)).astype(np.int32)


class TestSwitchableServer:
    def test_greedy_generation_deterministic(self, server):
        server.set_precision(8)
        r1 = server.generate(prompts(), max_new=8)
        r2 = server.generate(prompts(), max_new=8)
        np.testing.assert_array_equal(r1.tokens, r2.tokens)
        assert r1.tokens.shape == (2, 8)
        # the whole generation comes back as ONE device array
        assert r1.host_transfers == 1

    def test_precision_changes_behavior_gracefully(self, server):
        outs = {}
        for m in (8, 5, 3):
            server.set_precision(m)
            outs[m] = server.generate(prompts(seed=1), max_new=8).tokens
        # M8 vs M7 usually agree early; M3 should diverge somewhere
        assert not np.array_equal(outs[8], outs[3]) or True  # no crash is key
        for m, t in outs.items():
            assert t.min() >= 0 and t.max() < CFG.vocab_size

    def test_master_matches_direct_pack(self, server, params):
        """The stacked master == core.packed.pack of each layer slice, and
        its in-scan dequant == core.packed.dequantize — one set of numerics
        from the 2-D kernel format to the scanned serving format."""
        wq = params["layers"]["attn"]["wq"]          # [L, K, N]
        leaf = server.master["layers"]["attn"]["wq"]
        got = packed_lib.dequantize_stacked(leaf, 4, dtype=jnp.bfloat16)
        expect = packed_lib.dequantize(
            packed_lib.pack(wq[0], group_axis=0), 4, dtype=jnp.bfloat16)
        np.testing.assert_array_equal(np.asarray(got[0], np.float32),
                                      np.asarray(expect, np.float32))

    def test_mid_generation_switch(self, server):
        """prefill at M8, decode steps 0-3 at M8 then M3 after (the paper's
        prefill/decode asymmetry) — one fused scan, schedule traced."""
        server.set_precision(8)
        sched = lambda i: 8 if i < 4 else 3
        r = server.generate(prompts(seed=2), max_new=8,
                            precision_schedule=sched)
        assert r.precision_trace == [8, 8, 8, 8, 3, 3, 3, 3]
        assert r.tokens.shape == (2, 8)
        assert r.host_transfers == 1

    def test_schedule_sequence_and_validation(self, server):
        r = server.generate(prompts(seed=2), max_new=4,
                            precision_schedule=[8, 6, 4, 3])
        assert r.precision_trace == [8, 6, 4, 3]
        with pytest.raises(ValueError, match="length"):
            server.generate(prompts(), max_new=4, precision_schedule=[8, 7])
        with pytest.raises(ValueError, match="range"):
            server.generate(prompts(), max_new=2, precision_schedule=[8, 9])

    def test_batch_consistency(self, server):
        """row i of a batched generation == generating row i alone."""
        server.set_precision(6)
        p = prompts(b=4, s=16, seed=3)
        full = server.generate(p, max_new=6).tokens
        one = server.generate(p[1:2], max_new=6).tokens
        np.testing.assert_array_equal(full[1:2], one)

    def test_memory_report(self, server):
        server.set_precision(4)
        rep = server.memory_report()
        # vs fp16: 9.125/16 = 0.57 for packed leaves (+ raw bf16 leaves)
        assert rep["master_bytes"] < rep["fp16_bytes"]
        # E5M4 stream < master < fp16
        assert rep["stream_bytes_at_precision"] < rep["master_bytes"]
        # accounting derives from the format constants, not literals
        assert rep["master_bits_per_param"] == packed_lib.stream_bits_per_param(
            packed_lib.MASTER_M)

    def test_switch_is_free(self, server):
        """switching must neither touch the packed master nor materialize
        any weight tree: the master arrays are the SAME buffers across
        switches (zero bytes moved, not merely equal bytes)."""
        before = server.master["layers"]["attn"]["wq"]["mag"]
        server.set_precision(3)
        server.set_precision(7)
        after = server.master["layers"]["attn"]["wq"]["mag"]
        assert before is after
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))

    def test_no_materialization_in_serve_path(self):
        """grep invariant: the serve path must never rebuild a live weight
        tree — ``dequantize_tree`` (the O(params) materialize-on-switch
        rebuild) is banned from engine.py and packed_step.py sources."""
        for mod in (engine_mod, packed_step_mod):
            src = inspect.getsource(mod)
            assert "dequantize_tree(" not in src, mod.__name__


class TestFusedScanVsPerTokenLoop:
    """The fused scan is an optimization, not a semantics change: at
    temperature 0 it must reproduce the legacy per-step loop token for
    token, including under a mid-generation precision switch, on every
    serving backend."""

    SCHED = [8, 8, 4, 4, 4, 3, 3, 3]  # prefill m=8, decode m=4 -> 3

    def _check(self, srv):
        srv.set_precision(8)
        fused = srv.generate(prompts(seed=5), max_new=8,
                             precision_schedule=self.SCHED)
        loop = srv.generate_per_token(prompts(seed=5), max_new=8,
                                      precision_schedule=self.SCHED)
        np.testing.assert_array_equal(fused.tokens, loop.tokens)
        assert fused.precision_trace == loop.precision_trace == self.SCHED
        assert fused.host_transfers == 1
        assert loop.host_transfers == 8

    def test_xla_path(self, server):
        self._check(server)

    @pytest.mark.parametrize("backend", ["pallas-interpret", "jax-ref"])
    def test_kernel_backends(self, params, backend):
        srv = SwitchableServer(CFG, params, max_len=64,
                               kernel_backend=backend)
        self._check(srv)

    def test_sampled_path_agrees(self, server):
        """identical key stream: fused and per-token sampling match even at
        temperature > 0."""
        server.set_precision(6)
        fused = server.generate(prompts(seed=6), max_new=6, temperature=0.8,
                                top_k=8, seed=11)
        loop = server.generate_per_token(prompts(seed=6), max_new=6,
                                         temperature=0.8, top_k=8, seed=11)
        np.testing.assert_array_equal(fused.tokens, loop.tokens)


class TestSamplers:
    def test_temperature_topk(self):
        from repro.serve.sampler import sample_token
        logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                             jnp.float32)
        g = sample_token(logits, jax.random.PRNGKey(0), 0.0)
        np.testing.assert_array_equal(np.asarray(g),
                                      np.asarray(jnp.argmax(logits, -1)))
        t = sample_token(logits, jax.random.PRNGKey(0), 1.0, top_k=4)
        # top-k: every sample within the top-4 of its row
        top4 = np.argsort(np.asarray(logits), axis=-1)[:, -4:]
        for i, tok in enumerate(np.asarray(t)):
            assert tok in top4[i]

    def test_topk_larger_than_vocab(self):
        from repro.serve.sampler import sample_token
        logits = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8)),
                             jnp.float32)
        t = sample_token(logits, jax.random.PRNGKey(1), 1.0, top_k=100)
        assert int(t.min()) >= 0 and int(t.max()) < 8

    def test_scan_body_safe(self):
        """static temperature/top_k: the sampler must trace inside a jitted
        scan body without data-dependent branching."""
        from repro.serve.sampler import sample_token

        def body(key, _):
            logits = jnp.ones((2, 16), jnp.float32)
            key, sub = jax.random.split(key)
            return key, sample_token(logits, sub, 0.7, top_k=4)

        _, toks = jax.jit(
            lambda k: jax.lax.scan(body, k, jnp.arange(3)))(
            jax.random.PRNGKey(0))
        assert toks.shape == (3, 2)
