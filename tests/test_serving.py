"""Serving-engine tests: device-resident fused decode, packed-master
fidelity, zero-cost runtime precision switching (incl. mid-generation),
fused-scan vs per-token agreement across kernel backends, batching
consistency, memory accounting."""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packed as packed_lib
from repro.models import model_zoo as Z
from repro.models.config import ModelConfig
from repro.serve import SwitchableServer
from repro.serve import engine as engine_mod
from repro.serve import packed_step as packed_step_mod

CFG = ModelConfig(name="serve-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, q_block=16, kv_block=16, loss_chunk=16,
                  remat="none", dtype="bfloat16")


@pytest.fixture(scope="module")
def params():
    return Z.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def server(params):
    return SwitchableServer(CFG, params, max_len=96)


def prompts(b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, (b, s)).astype(np.int32)


class TestSwitchableServer:
    def test_greedy_generation_deterministic(self, server):
        server.set_precision(8)
        r1 = server.generate(prompts(), max_new=8)
        r2 = server.generate(prompts(), max_new=8)
        np.testing.assert_array_equal(r1.tokens, r2.tokens)
        assert r1.tokens.shape == (2, 8)
        # the whole generation comes back as ONE device array
        assert r1.host_transfers == 1

    def test_precision_changes_behavior_gracefully(self, server):
        outs = {}
        for m in (8, 5, 3):
            server.set_precision(m)
            outs[m] = server.generate(prompts(seed=1), max_new=8).tokens
        # M8 vs M7 usually agree early; M3 should diverge somewhere
        assert not np.array_equal(outs[8], outs[3]) or True  # no crash is key
        for m, t in outs.items():
            assert t.min() >= 0 and t.max() < CFG.vocab_size

    def test_master_matches_direct_pack(self, server, params):
        """The stacked master == core.packed.pack of each layer slice, and
        its in-scan dequant == core.packed.dequantize — one set of numerics
        from the 2-D kernel format to the scanned serving format."""
        wq = params["layers"]["attn"]["wq"]          # [L, K, N]
        leaf = server.master["layers"]["attn"]["wq"]
        got = packed_lib.dequantize_stacked(leaf, 4, dtype=jnp.bfloat16)
        expect = packed_lib.dequantize(
            packed_lib.pack(wq[0], group_axis=0), 4, dtype=jnp.bfloat16)
        np.testing.assert_array_equal(np.asarray(got[0], np.float32),
                                      np.asarray(expect, np.float32))

    def test_mid_generation_switch(self, server):
        """prefill at M8, decode steps 0-3 at M8 then M3 after (the paper's
        prefill/decode asymmetry) — one fused scan, schedule traced."""
        server.set_precision(8)
        sched = lambda i: 8 if i < 4 else 3
        r = server.generate(prompts(seed=2), max_new=8,
                            precision_schedule=sched)
        assert r.precision_trace == [8, 8, 8, 8, 3, 3, 3, 3]
        assert r.tokens.shape == (2, 8)
        assert r.host_transfers == 1

    def test_schedule_sequence_and_validation(self, server):
        r = server.generate(prompts(seed=2), max_new=4,
                            precision_schedule=[8, 6, 4, 3])
        assert r.precision_trace == [8, 6, 4, 3]
        with pytest.raises(ValueError, match="length"):
            server.generate(prompts(), max_new=4, precision_schedule=[8, 7])
        with pytest.raises(ValueError, match="range"):
            server.generate(prompts(), max_new=2, precision_schedule=[8, 9])

    def test_batch_consistency(self, server):
        """row i of a batched generation == generating row i alone."""
        server.set_precision(6)
        p = prompts(b=4, s=16, seed=3)
        full = server.generate(p, max_new=6).tokens
        one = server.generate(p[1:2], max_new=6).tokens
        np.testing.assert_array_equal(full[1:2], one)

    def test_memory_report(self, server):
        server.set_precision(4)
        rep = server.memory_report()
        # vs fp16: 9.125/16 = 0.57 for packed leaves (+ raw bf16 leaves)
        assert rep["master_bytes"] < rep["fp16_bytes"]
        # E5M4 stream < master < fp16
        assert rep["stream_bytes_at_precision"] < rep["master_bytes"]
        # accounting derives from the format constants, not literals
        assert rep["master_bits_per_param"] == packed_lib.stream_bits_per_param(
            packed_lib.MASTER_M)

    def test_switch_is_free(self, server):
        """switching must neither touch the packed master nor materialize
        any weight tree: the master arrays are the SAME buffers across
        switches (zero bytes moved, not merely equal bytes)."""
        before = server.master["layers"]["attn"]["wq"]["mag"]
        server.set_precision(3)
        server.set_precision(7)
        after = server.master["layers"]["attn"]["wq"]["mag"]
        assert before is after
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))

    def test_no_materialization_in_serve_path(self):
        """grep invariant: the serve path must never rebuild a live weight
        tree — ``dequantize_tree`` (the O(params) materialize-on-switch
        rebuild) is banned from engine.py and packed_step.py sources."""
        for mod in (engine_mod, packed_step_mod):
            src = inspect.getsource(mod)
            assert "dequantize_tree(" not in src, mod.__name__


class TestFusedScanVsPerTokenLoop:
    """The fused scan is an optimization, not a semantics change: at
    temperature 0 it must reproduce the legacy per-step loop token for
    token, including under a mid-generation precision switch, on every
    serving backend."""

    SCHED = [8, 8, 4, 4, 4, 3, 3, 3]  # prefill m=8, decode m=4 -> 3

    def _check(self, srv):
        srv.set_precision(8)
        fused = srv.generate(prompts(seed=5), max_new=8,
                             precision_schedule=self.SCHED)
        loop = srv.generate_per_token(prompts(seed=5), max_new=8,
                                      precision_schedule=self.SCHED)
        np.testing.assert_array_equal(fused.tokens, loop.tokens)
        assert fused.precision_trace == loop.precision_trace == self.SCHED
        assert fused.host_transfers == 1
        assert loop.host_transfers == 8

    def test_xla_path(self, server):
        self._check(server)

    @pytest.mark.parametrize("backend", ["pallas-interpret", "jax-ref"])
    def test_kernel_backends(self, params, backend):
        srv = SwitchableServer(CFG, params, max_len=64,
                               kernel_backend=backend)
        self._check(srv)

    def test_sampled_path_agrees(self, server):
        """identical key stream: fused and per-token sampling match even at
        temperature > 0."""
        server.set_precision(6)
        fused = server.generate(prompts(seed=6), max_new=6, temperature=0.8,
                                top_k=8, seed=11)
        loop = server.generate_per_token(prompts(seed=6), max_new=6,
                                         temperature=0.8, top_k=8, seed=11)
        np.testing.assert_array_equal(fused.tokens, loop.tokens)


class TestEosEarlyStop:
    """eos_id semantics on the lockstep paths: the fused scan masks
    emissions after the first eos (fixed-length executable, bitwise-same
    prefix) and reports per-row lengths; the per-token loop genuinely
    breaks once every row emitted eos (fewer steps, fewer host syncs)."""

    def _eos_of(self, server, p, at=3):
        return int(server.generate(p, max_new=8).tokens[0, at])

    def test_fused_masks_after_eos(self, server):
        server.set_precision(8)
        p = prompts(b=2, seed=21)
        eos = self._eos_of(server, p)
        base = server.generate(p, max_new=8)
        r = server.generate(p, max_new=8, eos_id=eos)
        assert r.lengths is not None and r.lengths.shape == (2,)
        for b in range(2):
            n = r.lengths[b]
            np.testing.assert_array_equal(r.tokens[b, :n], base.tokens[b, :n])
            assert (r.tokens[b, n:] == eos).all()
            if n < 8:
                assert r.tokens[b, n - 1] == eos
        assert r.host_transfers == 1  # still one device->host transfer

    def test_per_token_breaks_early(self, server):
        """The loop stops once EVERY row emitted eos — a b=1 batch breaks
        at the first emission, saving the remaining steps and syncs."""
        server.set_precision(8)
        p = prompts(b=1, seed=21)
        eos = self._eos_of(server, p)
        fused = server.generate(p, max_new=8, eos_id=eos)
        loop = server.generate_per_token(p, max_new=8, eos_id=eos)
        steps = loop.tokens.shape[1]
        assert steps == int(fused.lengths.max()) < 8
        assert loop.host_transfers == steps
        assert loop.precision_trace == fused.precision_trace[:steps]
        np.testing.assert_array_equal(loop.tokens,
                                      fused.tokens[:, :steps])
        np.testing.assert_array_equal(loop.lengths, fused.lengths)

    def test_per_token_waits_for_all_rows(self, server):
        """A row that never emits eos keeps the loop running to max_new;
        the finished row's tail is padded with eos_id."""
        server.set_precision(8)
        p = prompts(b=2, seed=21)
        eos = self._eos_of(server, p)
        loop = server.generate_per_token(p, max_new=8, eos_id=eos)
        fused = server.generate(p, max_new=8, eos_id=eos)
        np.testing.assert_array_equal(loop.lengths, fused.lengths)
        np.testing.assert_array_equal(loop.tokens, fused.tokens)
        assert loop.tokens.shape == (2, 8)

    def test_no_eos_behavior_unchanged(self, server):
        p = prompts(b=2, seed=22)
        r = server.generate(p, max_new=6)
        assert r.lengths is None
        rl = server.generate_per_token(p, max_new=6)
        assert rl.lengths is None and rl.tokens.shape == (2, 6)

    def test_prefill_precision_override(self, server):
        """prefill at a width independent of the decode schedule (the
        continuous scheduler's oracle hook): overriding with the schedule's
        own first width is a no-op; a different width changes the prompt
        encoding."""
        p = prompts(b=2, seed=23)
        base = server.generate(p, max_new=6, precision_schedule=[4] * 6)
        same = server.generate(p, max_new=6, precision_schedule=[4] * 6,
                               prefill_precision=4)
        np.testing.assert_array_equal(base.tokens, same.tokens)
        assert same.prefill_precision == 4
        other = server.generate(p, max_new=6, precision_schedule=[4] * 6,
                                prefill_precision=8)
        assert other.prefill_precision == 8
        with pytest.raises(ValueError, match="prefill_precision"):
            server.generate(p, max_new=2, prefill_precision=11)


class TestSamplers:
    def test_temperature_topk(self):
        from repro.serve.sampler import sample_token
        logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                             jnp.float32)
        g = sample_token(logits, jax.random.PRNGKey(0), 0.0)
        np.testing.assert_array_equal(np.asarray(g),
                                      np.asarray(jnp.argmax(logits, -1)))
        t = sample_token(logits, jax.random.PRNGKey(0), 1.0, top_k=4)
        # top-k: every sample within the top-4 of its row
        top4 = np.argsort(np.asarray(logits), axis=-1)[:, -4:]
        for i, tok in enumerate(np.asarray(t)):
            assert tok in top4[i]

    def test_topk_larger_than_vocab(self):
        from repro.serve.sampler import sample_token
        logits = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8)),
                             jnp.float32)
        t = sample_token(logits, jax.random.PRNGKey(1), 1.0, top_k=100)
        assert int(t.min()) >= 0 and int(t.max()) < 8

    def test_scan_body_safe(self):
        """static temperature/top_k: the sampler must trace inside a jitted
        scan body without data-dependent branching."""
        from repro.serve.sampler import sample_token

        def body(key, _):
            logits = jnp.ones((2, 16), jnp.float32)
            key, sub = jax.random.split(key)
            return key, sample_token(logits, sub, 0.7, top_k=4)

        _, toks = jax.jit(
            lambda k: jax.lax.scan(body, k, jnp.arange(3)))(
            jax.random.PRNGKey(0))
        assert toks.shape == (3, 2)


class TestVectorizedSampler:
    """sample_token_vec: per-slot temperature/top_k/keys, all traced.  The
    defining property is row isolation — row i equals the scalar sampler
    applied to row i alone with row i's key — which is exactly what makes
    a mixed continuous batch reproducible per request."""

    def _logits(self, b=6, v=33, seed=0):
        return jnp.asarray(np.random.default_rng(seed).normal(size=(b, v)),
                           jnp.float32)

    def test_rows_match_scalar_sampler(self):
        from repro.serve.sampler import sample_token, sample_token_vec
        logits = self._logits()
        keys = jax.random.split(jax.random.PRNGKey(3), 6)
        temps = jnp.asarray([0.0, 0.8, 1.3, 0.8, 0.0, 2.0], jnp.float32)
        topks = jnp.asarray([0, 4, 0, 100, 3, 1], jnp.int32)
        vec = sample_token_vec(logits, keys, temps, topks)
        for i in range(6):
            ref = sample_token(logits[i:i + 1], keys[i], float(temps[i]),
                               int(topks[i]))
            assert int(vec[i]) == int(ref[0]), i

    def test_greedy_rows_ignore_keys(self):
        from repro.serve.sampler import sample_token_vec
        logits = self._logits(seed=1)
        t0 = sample_token_vec(logits, jax.random.split(jax.random.PRNGKey(0), 6),
                              jnp.zeros((6,)), jnp.zeros((6,), jnp.int32))
        t1 = sample_token_vec(logits, jax.random.split(jax.random.PRNGKey(9), 6),
                              jnp.zeros((6,)), jnp.zeros((6,), jnp.int32))
        np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))
        np.testing.assert_array_equal(np.asarray(t0),
                                      np.asarray(jnp.argmax(logits, -1)))

    def test_fully_traced_one_executable(self):
        """temps/topks/keys are all traced: one jitted executable serves
        any request mix without retrace."""
        from repro.serve.sampler import sample_token_vec
        fn = jax.jit(sample_token_vec)
        logits = self._logits(b=4, seed=2)
        keys = jax.random.split(jax.random.PRNGKey(1), 4)
        fn(logits, keys, jnp.zeros((4,)), jnp.zeros((4,), jnp.int32))
        n0 = fn._cache_size()
        fn(logits, keys, jnp.asarray([0.0, 0.5, 1.0, 2.0]),
           jnp.asarray([0, 3, 5, 7], jnp.int32))
        assert fn._cache_size() == n0  # no retrace for a new mix


try:  # optional dep: richer randomized coverage of the same invariants;
    # guarded inline (not importorskip) so the rest of this module still
    # runs without hypothesis — decorators below need the real symbols.
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # Fallback: the property tests still RUN without hypothesis, as a
    # deterministic numpy-driven sweep — ``given`` draws max_examples
    # fixed-seed samples from the same strategy shapes and calls the test
    # once per sample.  No shrinking or adaptive search, but the invariant
    # gets exercised over the same parameter space either way (these two
    # tests used to be permanent skips in hypothesis-less environments).
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors the hypothesis strategies namespace
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def lists(elem, min_size, max_size):
            return _Strategy(lambda rng: [
                elem.draw(rng) for _ in range(
                    int(rng.integers(min_size, max_size + 1)))])

    def settings(max_examples=20, **kw):
        def deco(f):
            f._fallback_examples = max_examples
            return f
        return deco

    def given(**strategies):
        def deco(f):
            # NOT functools.wraps: copying __wrapped__/signature would make
            # pytest treat the strategy kwargs as fixtures
            def wrapper(self):
                n = getattr(wrapper, "_fallback_examples", 20)
                rng = np.random.default_rng(0xC0FFEE)
                for _ in range(n):
                    kw = {name: s.draw(rng)
                          for name, s in strategies.items()}
                    try:
                        f(self, **kw)
                    except AssertionError as e:
                        raise AssertionError(
                            f"fallback property sweep failed on {kw}"
                        ) from e
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco


class TestVectorizedSamplerProperties:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), b=st.integers(1, 5),
           temps=st.lists(st.floats(0.0, 3.0), min_size=5, max_size=5),
           topks=st.lists(st.integers(0, 40), min_size=5, max_size=5))
    def test_row_isolation_property(self, seed, b, temps, topks):
        """For any mix of per-row params, each row of sample_token_vec
        equals the scalar sampler on that row alone."""
        from repro.serve.sampler import sample_token, sample_token_vec
        logits = jnp.asarray(
            np.random.default_rng(seed).normal(size=(b, 17)), jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(seed), b)
        tv = jnp.asarray(temps[:b], jnp.float32)
        kv = jnp.asarray(topks[:b], jnp.int32)
        vec = np.asarray(sample_token_vec(logits, keys, tv, kv))
        for i in range(b):
            ref = sample_token(logits[i:i + 1], keys[i], float(tv[i]),
                               int(kv[i]))
            assert vec[i] == int(ref[0])

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), k=st.integers(1, 16),
           temp=st.floats(0.05, 3.0))
    def test_topk_support_property(self, seed, k, temp):
        """Sampled ids always lie within each row's top-k logits."""
        from repro.serve.sampler import sample_token_vec
        logits = jnp.asarray(
            np.random.default_rng(seed).normal(size=(3, 16)), jnp.float32)
        keys = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
        toks = np.asarray(sample_token_vec(
            logits, keys, jnp.full((3,), temp), jnp.full((3,), k,
                                                         jnp.int32)))
        order = np.argsort(np.asarray(logits), axis=-1)[:, ::-1]
        for i in range(3):
            assert toks[i] in order[i, :k]
