"""Serving-engine tests: packed-master fidelity, runtime precision
switching (incl. mid-generation), batching consistency, memory accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packed as packed_lib
from repro.core import sefp
from repro.models import model_zoo as Z
from repro.models.config import ModelConfig
from repro.serve import SwitchableServer

CFG = ModelConfig(name="serve-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, q_block=16, kv_block=16, loss_chunk=16,
                  remat="none", dtype="bfloat16")


@pytest.fixture(scope="module")
def server():
    params = Z.init_params(CFG, jax.random.PRNGKey(0))
    return SwitchableServer(CFG, params, max_len=96)


def prompts(b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, (b, s)).astype(np.int32)


class TestSwitchableServer:
    def test_greedy_generation_deterministic(self, server):
        server.set_precision(8)
        r1 = server.generate(prompts(), max_new=8)
        r2 = server.generate(prompts(), max_new=8)
        np.testing.assert_array_equal(r1.tokens, r2.tokens)
        assert r1.tokens.shape == (2, 8)

    def test_precision_changes_behavior_gracefully(self, server):
        outs = {}
        for m in (8, 5, 3):
            server.set_precision(m)
            outs[m] = server.generate(prompts(seed=1), max_new=8).tokens
        # M8 vs M7 usually agree early; M3 should diverge somewhere
        assert not np.array_equal(outs[8], outs[3]) or True  # no crash is key
        for m, t in outs.items():
            assert t.min() >= 0 and t.max() < CFG.vocab_size

    def test_live_weights_match_direct_quantization(self, server):
        """materialize-on-switch == quantize-from-master directly."""
        server.set_precision(4)
        wq_live = server._live["layers"]["attn"]["wq"]
        master = server.master["layers"]["attn"]["wq"]
        expect = packed_lib.dequantize(master, 4, dtype=jnp.bfloat16)
        np.testing.assert_array_equal(np.asarray(wq_live, np.float32),
                                      np.asarray(expect, np.float32))

    def test_mid_generation_switch(self, server):
        """prefill at M8, decode steps 0-3 at M8 then M3 after (the paper's
        prefill/decode asymmetry) — engine must keep the same cache."""
        server.set_precision(8)
        sched = lambda i: 8 if i < 4 else 3
        r = server.generate(prompts(seed=2), max_new=8,
                            precision_schedule=sched)
        assert r.precision_trace == [8, 8, 8, 8, 3, 3, 3, 3]
        assert r.tokens.shape == (2, 8)

    def test_batch_consistency(self, server):
        """row i of a batched generation == generating row i alone."""
        server.set_precision(6)
        p = prompts(b=4, s=16, seed=3)
        full = server.generate(p, max_new=6).tokens
        one = server.generate(p[1:2], max_new=6).tokens
        np.testing.assert_array_equal(full[1:2], one)

    def test_memory_report(self, server):
        server.set_precision(4)
        rep = server.memory_report()
        # packed master must be ~9.14/32 of fp32, i.e. < 30% of fp16 x2...
        # vs fp16: 9.125/16 = 0.57 for packed leaves (+ raw fp32 leaves)
        assert rep["master_bytes"] < rep["fp16_bytes"]
        # E5M4 stream < master < fp16
        assert rep["stream_bytes_at_precision"] < rep["master_bytes"]

    def test_switch_cost_is_elementwise_only(self, server):
        """switching must not touch the packed master (no re-quantization):
        master arrays are bit-identical across switches."""
        before = np.asarray(server.master["layers"]["attn"]["wq"].mag)
        server.set_precision(3)
        server.set_precision(7)
        after = np.asarray(server.master["layers"]["attn"]["wq"].mag)
        np.testing.assert_array_equal(before, after)


class TestSamplers:
    def test_temperature_topk(self):
        from repro.serve.sampler import sample_token
        logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                             jnp.float32)
        g = sample_token(logits, jax.random.PRNGKey(0), 0.0)
        np.testing.assert_array_equal(np.asarray(g),
                                      np.asarray(jnp.argmax(logits, -1)))
        t = sample_token(logits, jax.random.PRNGKey(0), 1.0, top_k=4)
        # top-k: every sample within the top-4 of its row
        top4 = np.argsort(np.asarray(logits), axis=-1)[:, -4:]
        for i, tok in enumerate(np.asarray(t)):
            assert tok in top4[i]
