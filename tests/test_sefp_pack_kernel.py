"""sefp_pack kernel validation: shape sweep, bitwise agreement with both
its standalone oracle and the framework-wide core/packed.pack, and
end-to-end round-trip through the serving matmul kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packed as packed_lib
from repro.kernels.sefp_pack import sefp_pack_pallas
from repro.kernels.sefp_pack.ref import sefp_pack_ref


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


SHAPES = [(64, 128), (128, 128), (256, 384), (640, 256)]


@pytest.mark.parametrize("shape", SHAPES)
def test_matches_ref_bitwise(shape):
    w = rand(shape, seed=shape[0])
    p = sefp_pack_pallas(w)
    mag, sgn, exp = sefp_pack_ref(w)
    np.testing.assert_array_equal(np.asarray(p.mag), np.asarray(mag))
    np.testing.assert_array_equal(np.asarray(p.sign_bits), np.asarray(sgn))
    np.testing.assert_array_equal(np.asarray(p.exp), np.asarray(exp))


@pytest.mark.parametrize("scale", [1e-4, 1.0, 1e3])
def test_matches_core_pack_bitwise(scale):
    w = rand((128, 256), seed=3, scale=scale)
    p_kernel = sefp_pack_pallas(w)
    p_core = packed_lib.pack(w, group_axis=0)
    np.testing.assert_array_equal(np.asarray(p_kernel.mag),
                                  np.asarray(p_core.mag))
    np.testing.assert_array_equal(np.asarray(p_kernel.sign_bits),
                                  np.asarray(p_core.sign_bits))
    np.testing.assert_array_equal(np.asarray(p_kernel.exp),
                                  np.asarray(p_core.exp))


def test_roundtrip_through_serving_kernel():
    """pack (kernel) -> matmul (kernel) == pack (core) -> dequant matmul."""
    from repro.kernels.sefp_matmul import sefp_matmul

    w = rand((256, 128), seed=4)
    x = rand((8, 256), seed=5)
    p = sefp_pack_pallas(w)
    out = sefp_matmul(x, p, 5)
    wd = packed_lib.dequantize(packed_lib.pack(w, group_axis=0), 5,
                               dtype=jnp.bfloat16)
    ref = jnp.dot(x.astype(jnp.bfloat16), wd,
                  preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
