"""Width-heterogeneous fused decode (DESIGN.md §14): one step serves every
batch row at its OWN SEFP mantissa width.  The acceptance contract is
BITWISE: row i of the heterogeneous kernel / serve step / schedule equals
the lockstep (single-width) run of that row at width m_i — heterogeneity
must be free of numerics drift, not merely close."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packed as packed_lib
from repro.kernels import dispatch
from repro.kernels.sefp_matmul import (
    normalize_widths,
    sefp_matmul_gemv,
    sefp_matmul_gemv_hetero,
)
from repro.models import model_zoo as Z
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.policy import PrecisionPolicy
from repro.serve import SwitchableServer
from repro.serve import packed_step as PS
from repro.serve.scheduler import (
    HeterogeneousPolicy,
    SLODegradePolicy,
    WidthRoundRobinPolicy,
    make_width_policy,
)

WIDTHS = (8, 6, 4, 3)

DENSE_CFG = ModelConfig(name="het-dense", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                        vocab_size=256, head_dim=16, q_block=16, kv_block=16,
                        loss_chunk=16, remat="none", dtype="bfloat16")

MOE_CFG = ModelConfig(name="het-moe", family="moe", n_layers=2, d_model=128,
                      n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
                      vocab_size=256, n_experts=4, top_k=2, q_block=32,
                      kv_block=32, loss_chunk=32, remat="none",
                      dtype="bfloat16")

RWKV_CFG = ModelConfig(name="het-rwkv", family="rwkv", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                       d_ff=256, vocab_size=256, rwkv_head_dim=32,
                       q_block=32, kv_block=32, loss_chunk=32, remat="none",
                       dtype="bfloat16")

# NOTE: hybrid is pinned at layer_unroll=1 (the TPU default).  Under CPU
# auto-full-unroll XLA fuses across the unrolled Mamba2 scan iterations
# differently around the hetero ladder's lax.cond, which breaks
# cross-PROGRAM bitwise agreement for the recurrent state (DESIGN.md §14).
HYBRID_CFG = ModelConfig(name="het-hybrid", family="hybrid", n_layers=4,
                         d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab_size=256, head_dim=16, attn_every=2,
                         ssm_state=16, ssm_head_dim=16, q_block=16,
                         kv_block=16, loss_chunk=16, remat="none",
                         dtype="bfloat16")


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# kernel layer: per-row gemv vs the scalar gemv, row for row
# ---------------------------------------------------------------------------

KERNEL_BACKENDS = (dispatch.JAX_REF, dispatch.PALLAS_INTERPRET)


class TestHeteroGemvKernel:
    @pytest.mark.parametrize("backend", KERNEL_BACKENDS)
    def test_rows_bitwise_equal_scalar_gemv(self, backend):
        """A mixed {8,6,4,3} batch: output row i of the fused hetero gemv
        is bitwise row i of the scalar gemv at m_i."""
        K, N = 128, 128
        p = packed_lib.pack(rand((K, N), seed=1), group_axis=0)
        x = rand((8, K), seed=2)
        m = np.asarray([8, 6, 4, 3, 3, 4, 6, 8], np.int32)
        out = np.asarray(sefp_matmul_gemv_hetero(
            x, p, m, widths=WIDTHS, block_n=64, block_k=64, backend=backend))
        for w in WIDTHS:
            rows = np.flatnonzero(m == w)
            solo = np.asarray(sefp_matmul_gemv(
                x, p, w, block_n=64, block_k=64, backend=backend))
            np.testing.assert_array_equal(out[rows], solo[rows])

    @pytest.mark.parametrize("backend", KERNEL_BACKENDS)
    def test_all_same_width_degenerates_to_scalar(self, backend):
        """A uniform width vector reproduces the scalar gemv exactly — the
        lockstep path is a special case of the hetero path."""
        K, N = 128, 64
        p = packed_lib.pack(rand((K, N), seed=3), group_axis=0)
        x = rand((8, K), seed=4)
        m = np.full((8,), 6, np.int32)
        out = sefp_matmul_gemv_hetero(x, p, m, widths=WIDTHS, block_n=64,
                                      block_k=64, backend=backend)
        solo = sefp_matmul_gemv(x, p, 6, block_n=64, block_k=64,
                                backend=backend)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(solo))

    @pytest.mark.parametrize("backend", KERNEL_BACKENDS)
    def test_row_padding_edge(self, backend):
        """5 rows (not a sublane multiple): padded rows reuse m[0]'s width
        internally and are sliced away; the visible rows stay bitwise."""
        K, N = 128, 64
        p = packed_lib.pack(rand((K, N), seed=5), group_axis=0)
        x = rand((5, K), seed=6)
        m = np.asarray([4, 8, 3, 6, 4], np.int32)
        out = np.asarray(sefp_matmul_gemv_hetero(
            x, p, m, widths=WIDTHS, block_n=64, block_k=64, backend=backend))
        assert out.shape == (5, N)
        for i, w in enumerate(m):
            solo = np.asarray(sefp_matmul_gemv(
                x, p, int(w), block_n=64, block_k=64, backend=backend))
            np.testing.assert_array_equal(out[i], solo[i])

    def test_backends_agree_bitwise(self):
        """pallas-interpret and jax-ref walk the same tile sequence and
        ladder, so whole outputs agree bitwise."""
        K, N = 256, 128
        p = packed_lib.pack(rand((K, N), seed=7), group_axis=0)
        x = rand((8, K), seed=8)
        m = np.asarray([8, 3, 6, 4, 8, 3, 6, 4], np.int32)
        a = sefp_matmul_gemv_hetero(x, p, m, widths=WIDTHS, block_n=128,
                                    block_k=128, backend=dispatch.JAX_REF)
        b = sefp_matmul_gemv_hetero(x, p, m, widths=WIDTHS, block_n=128,
                                    block_k=128,
                                    backend=dispatch.PALLAS_INTERPRET)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_absent_ladder_width_zeroes_row(self):
        """A row whose width is not on the compiled ladder comes back zero
        (the documented kernel contract; serve callers validate on host)."""
        K, N = 128, 64
        p = packed_lib.pack(rand((K, N), seed=9), group_axis=0)
        x = rand((8, K), seed=10)
        m = np.asarray([8, 5, 8, 8, 8, 8, 8, 8], np.int32)  # 5 not in ladder
        out = np.asarray(sefp_matmul_gemv_hetero(
            x, p, m, widths=WIDTHS, backend=dispatch.JAX_REF))
        assert not out[1].any()
        assert out[0].any()

    def test_normalize_widths(self):
        assert normalize_widths(None) == (8, 7, 6, 5, 4, 3, 2, 1)
        assert normalize_widths([4, 8, 4, 3]) == (8, 4, 3)
        with pytest.raises(ValueError, match="non-empty"):
            normalize_widths([])
        with pytest.raises(ValueError, match="outside"):
            normalize_widths([9])
        with pytest.raises(ValueError, match="outside"):
            normalize_widths([0])

    def test_m_vector_shape_validated(self):
        K, N = 128, 64
        p = packed_lib.pack(rand((K, N), seed=11), group_axis=0)
        x = rand((4, K), seed=12)
        with pytest.raises(ValueError, match="one width per row"):
            sefp_matmul_gemv_hetero(x, p, np.asarray([8, 4], np.int32))

    def test_registered_on_all_backends(self):
        assert dispatch.backends_for("sefp_matmul_gemv_hetero") == sorted(
            dispatch.BACKENDS)


# ---------------------------------------------------------------------------
# serve-step layer: one fused hetero step vs per-width scalar steps
# ---------------------------------------------------------------------------


def _assert_step_rows_match_lockstep(cfg, unroll, paged):
    """Run the hetero step (mixed widths) and, per ladder width, the scalar
    step on the same batch; rows wanting that width must agree bitwise
    across several greedy decode steps.  Rows are independent in decode, so
    the scalar runs may feed different tokens at OTHER rows without
    perturbing the compared rows."""
    params = Z.init_params(cfg, jax.random.PRNGKey(0))
    master = PS.pack_master_params(params, min_size=1 << 10)
    B, PSZ, NPP = 4, 8, 2  # slots, page size, pages per slot
    m = np.asarray([8, 6, 4, 3], np.int32)
    m_dev = jnp.asarray(m)

    if paged:
        hetero = jax.jit(PS.make_master_serve_step_hetero_paged(
            cfg, WIDTHS, layer_unroll=unroll, page_size=PSZ))
        scalar = jax.jit(PS.make_master_serve_step_paged(
            cfg, layer_unroll=unroll, page_size=PSZ))
        n_pages = 1 + B * NPP  # page 0 is the null page
        bt = np.zeros((B, NPP), np.int32)
        for i in range(B):
            bt[i] = 1 + i * NPP + np.arange(NPP)
        bt = jnp.asarray(bt)

        def init():
            return T.lm_init_paged_cache(cfg, B, n_pages, PSZ)

        def step(fn, cache, tok, width):
            return fn(master, cache, tok, width, bt)
    else:
        hetero = jax.jit(PS.make_master_serve_step_hetero(
            cfg, WIDTHS, layer_unroll=unroll))
        scalar = jax.jit(PS.make_master_serve_step(cfg,
                                                   layer_unroll=unroll))

        def init():
            return Z.init_cache(cfg, params, B, 16)

        def step(fn, cache, tok, width):
            return fn(master, cache, tok, width)

    cache_h = init()
    tok_h = jnp.asarray([3, 7, 11, 2], jnp.int32)
    scalar_state = {w: (init(), tok_h) for w in WIDTHS}
    for _ in range(3):
        lh, cache_h = step(hetero, cache_h, tok_h, m_dev)
        for w in WIDTHS:
            cache_s, tok_s = scalar_state[w]
            ls, cache_s = step(scalar, cache_s, tok_s, jnp.int32(w))
            rows = np.flatnonzero(m == w)
            np.testing.assert_array_equal(np.asarray(lh)[rows],
                                          np.asarray(ls)[rows])
            scalar_state[w] = (cache_s,
                               jnp.argmax(ls, -1).astype(jnp.int32))
        tok_h = jnp.argmax(lh, -1).astype(jnp.int32)


class TestHeteroServeStep:
    @pytest.mark.parametrize("cfg,unroll", [
        (DENSE_CFG, None),
        (MOE_CFG, None),
        (RWKV_CFG, None),
        (HYBRID_CFG, 1),
    ], ids=["dense", "moe", "rwkv", "hybrid-unroll1"])
    def test_step_rows_bitwise_lockstep(self, cfg, unroll):
        _assert_step_rows_match_lockstep(cfg, unroll, paged=False)

    @pytest.mark.parametrize("cfg,unroll", [
        (DENSE_CFG, None),
        (HYBRID_CFG, 1),
    ], ids=["dense", "hybrid-unroll1"])
    def test_paged_step_rows_bitwise_lockstep(self, cfg, unroll):
        _assert_step_rows_match_lockstep(cfg, unroll, paged=True)


# ---------------------------------------------------------------------------
# policy layer: HeterogeneousPolicy units + SLO composition
# ---------------------------------------------------------------------------


class TestHeterogeneousPolicy:
    def test_commits_everyone_at_wanted_width(self):
        p = HeterogeneousPolicy()
        wanted = {0: 8, 2: 4, 5: 3}
        for _ in range(5):
            m, commit = p.select(dict(wanted))
            assert m == wanted          # per-slot dict, not one scalar
            assert commit == {0, 2, 5}  # commit rate 1.0 by construction
        assert p.starvation == {}       # nothing to rotate, nothing to wait

    def test_registry(self):
        assert isinstance(make_width_policy("heterogeneous"),
                          HeterogeneousPolicy)
        assert getattr(make_width_policy("heterogeneous"),
                       "heterogeneous", False)

    def test_slo_composition_clamps_per_slot(self):
        """Under pressure the embedded slo-degrade state machine CLAMPS the
        width vector per slot (honoring per-slot floors) instead of forcing
        one batch-wide width — everyone still commits every step."""
        p = HeterogeneousPolicy(degrade=SLODegradePolicy(queue_high=2))
        sig = {"clock": 0, "queue_depth": 0, "active": 1, "slots": 4,
               "step_seconds": None, "floors": {1: 8},
               "widths": (8, 6, 4, 3)}
        p.observe(dict(sig))
        m, commit = p.select({0: 8, 1: 8, 2: 4})
        assert m == {0: 8, 1: 8, 2: 4}  # healthy: exact fidelity
        p.observe({**sig, "clock": 1, "queue_depth": 5})  # breach
        m, commit = p.select({0: 8, 1: 8, 2: 4})
        assert commit == {0, 1, 2}      # still everyone, every step
        assert m == {0: 6, 1: 8, 2: 3}  # one rung down, slot 1 floored at 8
        assert p.degradation["shift"] == 1
        assert p.degradation["downshifted_slot_steps"] == 2


# ---------------------------------------------------------------------------
# width-rr starvation accounting (regression: audited semantics)
# ---------------------------------------------------------------------------


class TestWidthRRStarvationAccounting:
    def test_high_water_vs_current_streak(self):
        """``starvation`` is the lifetime HIGH-WATER wait per width group
        (never reset — the fairness audit bound); ``current_waits`` is the
        live streak, reset on serve and restarted at 0 when a drained
        group reappears."""
        p = WidthRoundRobinPolicy()
        wanted = {0: 8, 1: 4}
        for _ in range(4):
            p.select(dict(wanted))
        # steady two-group alternation: high-water pinned at 1, and the
        # just-served group's live streak is 0
        assert set(p.starvation.values()) == {1}
        assert sorted(p.current_waits.values()) == [0, 1]
        # group 4 drains: its live streak entry is dropped, its lifetime
        # high-water persists
        for _ in range(3):
            m, _ = p.select({0: 8})
            assert m == 8
        assert 4 not in p.current_waits
        assert p.starvation[4] == 1
        assert p.current_waits == {8: 0}
        # group 4 reappears: streak restarts at 0 (not carried across the
        # drain), high-water unchanged until it genuinely waits longer
        m, _ = p.select({0: 8, 1: 4})
        assert m == 4  # rotation serves the returning group first
        assert p.current_waits[4] == 0
        assert p.starvation[4] == 1


# ---------------------------------------------------------------------------
# scheduler e2e: heterogeneous policy, oracle replay, token accounting
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def server():
    params = Z.init_params(DENSE_CFG, jax.random.PRNGKey(0))
    srv = SwitchableServer(DENSE_CFG, params, max_len=96)
    srv.set_policy(PrecisionPolicy.all_widths()
                   .with_class("m8", 8).with_class("m6", 6)
                   .with_class("m4", 4).with_class("m3", 3))
    return srv


def prompts(b=4, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, DENSE_CFG.vocab_size, (b, s)).astype(np.int32)


def check_oracle(server, fr, prompt, **sample_kw):
    sched, pm = fr.oracle_schedule()
    solo = server.generate(prompt[None], max_new=len(fr.tokens),
                           precision_schedule=sched, prefill_precision=pm,
                           **sample_kw)
    np.testing.assert_array_equal(fr.tokens, solo.tokens[0])


class TestHeterogeneousScheduling:
    def test_mixed_classes_all_at_wanted_width(self, server):
        """Every request decodes EVERY step at its class width; commit rate
        is 1.0, starvation empty, and each request replays bitwise on the
        lockstep oracle."""
        p = prompts(b=4, seed=3)
        classes = ["m8", "m6", "m4", "m3"]
        sched = server.continuous(slots=4, width_policy="heterogeneous")
        rids = [sched.submit(p[i], 6, request_class=classes[i])
                for i in range(4)]
        done = sched.drain()
        assert len(done) == 4
        want = {"m8": 8, "m6": 6, "m4": 4, "m3": 3}
        for i, rid in enumerate(rids):
            fr = done[rid]
            assert fr.decode_widths == [want[classes[i]]] * len(
                fr.decode_widths)
            check_oracle(server, fr, p[i])
        stats = sched.stats
        assert stats["commit_rate"] == 1.0
        assert stats["starvation"] == {}
        assert sum(stats["tokens_by_width"].values()) == \
            stats["committed_tokens"]

    def test_sampled_rows_replay_bitwise(self, server):
        """temperature > 0 rows: per-slot PRNG streams survive the hetero
        step — a sampled request replays bitwise with its seed."""
        p = prompts(b=2, seed=21)
        sched = server.continuous(slots=2, width_policy="heterogeneous")
        r0 = sched.submit(p[0], 6, request_class="m6", temperature=0.8,
                          top_k=8, seed=13)
        r1 = sched.submit(p[1], 6, request_class="m3", temperature=1.1,
                          top_k=4, seed=5)
        done = sched.drain()
        check_oracle(server, done[r0], p[0], temperature=0.8, top_k=8,
                     seed=13)
        check_oracle(server, done[r1], p[1], temperature=1.1, top_k=4,
                     seed=5)

    def test_staggered_admission_oracle(self, server):
        """Slots join mid-flight at different widths; every finisher still
        replays bitwise and tokens_by_width matches the per-request
        width_counts aggregation."""
        p = prompts(b=6, seed=8)
        classes = ["m8", "m3", "m6", "m4", "m8", "m3"]
        sched = server.continuous(slots=2, width_policy="heterogeneous")
        rids = [sched.submit(p[i], 4, request_class=classes[i])
                for i in range(6)]
        done = sched.drain()
        assert len(done) == 6
        agg = {}
        for i, rid in enumerate(rids):
            fr = done[rid]
            check_oracle(server, fr, p[i])
            for w, c in fr.width_counts().items():
                agg[w] = agg.get(w, 0) + c
        stats = sched.stats
        assert agg == stats["tokens_by_width"]
        assert stats["commit_rate"] == 1.0
        # heterogeneous serves multiple widths in ONE step: fewer steps
        # than the per-width turn-taking would need
        assert set(stats["width_steps"]) == {8, 6, 4, 3}

    def test_tokens_by_width_all_policies(self, server):
        """tokens_by_width is policy-agnostic accounting: width-rr runs
        report it too, summing to committed_tokens."""
        p = prompts(b=2, seed=30)
        sched = server.continuous(slots=2, width_policy="width-rr")
        sched.submit(p[0], 4, request_class="m8")
        sched.submit(p[1], 4, request_class="m4")
        sched.drain()
        stats = sched.stats
        assert sum(stats["tokens_by_width"].values()) == \
            stats["committed_tokens"]
        assert set(stats["tokens_by_width"]) <= {8, 4}
