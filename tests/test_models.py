"""Model-substrate correctness: chunked algorithms vs naive oracles, and
prefill+decode vs full-sequence consistency for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import model_zoo as Z
from repro.models import rwkv6 as R6
from repro.models import transformer as T
from repro.models.config import ModelConfig


def rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=256, head_dim=16, q_block=16, kv_block=16,
            loss_chunk=16, remat="none", dtype="float32")


def cfg_for(family, **kw):
    d = dict(BASE)
    if family == "moe":
        d.update(n_experts=4, top_k=2)
    if family == "rwkv":
        d.update(rwkv_head_dim=16, rwkv_chunk=8)
    if family == "hybrid":
        d.update(n_kv_heads=4, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                 attn_every=2, n_layers=4)
    if family == "vlm":
        d.update(n_prefix_embeds=4)
    if family == "encdec":
        d.update(n_enc_layers=2, n_dec_layers=2)
    d.update(kw)
    return ModelConfig(name=family, family=family, **d)


# ---------------------------------------------------------------------------
# flash attention vs naive
# ---------------------------------------------------------------------------

class TestFlashAttention:
    def naive(self, q, k, v, causal):
        B, Sq, H, hd = q.shape
        KV = k.shape[2]
        G = H // KV
        qr = q.reshape(B, Sq, KV, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qr, k) / np.sqrt(hd)
        if causal:
            mask = jnp.arange(k.shape[1])[None, :] > jnp.arange(Sq)[:, None]
            s = jnp.where(mask[None, None, None], -1e30, s)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bkgqh", p, v)
        return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, Sq, H, hd)

    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("qb,kb", [(16, 16), (32, 64), (64, 32)])
    def test_matches_naive(self, causal, qb, kb):
        B, S, H, KV, hd = 2, 128, 4, 2, 16
        q = rand((B, S, H, hd), 1)
        k = rand((B, S, KV, hd), 2)
        v = rand((B, S, KV, hd), 3)
        out = L.flash_attention(q, k, v, causal=causal, q_block=qb,
                                kv_block=kb)
        ref = self.naive(q, k, v, causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_decode_matches_naive_last_row(self):
        B, S, H, KV, hd = 2, 64, 4, 2, 16
        q = rand((B, S, H, hd), 4)
        k = rand((B, S, KV, hd), 5)
        v = rand((B, S, KV, hd), 6)
        ref = self.naive(q, k, v, True)[:, -1:]
        out = L.decode_attention(q[:, -1:], k, v, kv_len=jnp.int32(S))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# mamba2 chunked vs sequential oracle
# ---------------------------------------------------------------------------

class TestMamba2:
    def test_chunked_matches_sequential(self):
        cfg = cfg_for("hybrid", ssm_chunk=8)
        key = jax.random.PRNGKey(0)
        p = M2.mamba2_init(key, cfg)
        x = rand((2, 32, cfg.d_model), 7, 0.5)
        y_chunk = M2.mamba2_apply(p, x, cfg)

        # sequential oracle via the decode path
        cache = M2.mamba2_init_cache(cfg, 2)
        ys = []
        for t in range(32):
            y, cache = M2.mamba2_decode(p, x[:, t:t + 1], cache, cfg)
            ys.append(y)
        y_seq = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4)

    def test_prefill_state_continues_decode(self):
        cfg = cfg_for("hybrid", ssm_chunk=8)
        p = M2.mamba2_init(jax.random.PRNGKey(1), cfg)
        x = rand((2, 24, cfg.d_model), 8, 0.5)
        y_full = M2.mamba2_apply(p, x, cfg)
        y_pre, st = M2.mamba2_apply_with_state(p, x[:, :16], cfg)
        cache = st
        outs = [y_pre]
        for t in range(16, 24):
            y, cache = M2.mamba2_decode(p, x[:, t:t + 1], cache, cfg)
            outs.append(y)
        y_cat = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_cat),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rwkv6 chunked vs sequential oracle
# ---------------------------------------------------------------------------

class TestRWKV6:
    def test_chunked_matches_sequential(self):
        cfg = cfg_for("rwkv", rwkv_chunk=8)
        p = R6.rwkv6_init(jax.random.PRNGKey(2), cfg)
        x = rand((2, 32, cfg.d_model), 9, 0.5)
        y_chunk = R6.rwkv6_apply(p, x, cfg)

        cache = R6.rwkv6_init_cache(cfg, 2)
        ys = []
        for t in range(32):
            y, cache = R6.rwkv6_decode(p, x[:, t:t + 1], cache, cfg)
            ys.append(y)
        y_seq = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4)

    def test_state_prefill_continuation(self):
        cfg = cfg_for("rwkv", rwkv_chunk=8)
        p = R6.rwkv6_init(jax.random.PRNGKey(3), cfg)
        x = rand((1, 16, cfg.d_model), 10, 0.5)
        y_full = R6.rwkv6_apply(p, x, cfg)
        y_pre, S_final = R6.rwkv6_apply_with_state(p, x[:, :8], cfg)
        cache = {"wkv_state": S_final, "shift_state": x[:, 7:8]}
        outs = [y_pre]
        for t in range(8, 16):
            y, cache = R6.rwkv6_decode(p, x[:, t:t + 1], cache, cfg)
            outs.append(y)
        y_cat = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_cat),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE dispatch equivalence
# ---------------------------------------------------------------------------

class TestMoE:
    def test_capacity_matches_dense_when_no_drop(self):
        # generous capacity => no token dropped => capacity == dense combine
        cfg = cfg_for("moe", moe_capacity_factor=8.0)
        p = MOE.moe_init(jax.random.PRNGKey(4), cfg)
        x = rand((2, 64, cfg.d_model), 11, 0.5)
        dense = MOE._moe_dense(p, x.reshape(-1, cfg.d_model), cfg)
        capd = MOE.moe_apply(p, x, cfg, dispatch_chunk=64)
        np.testing.assert_allclose(np.asarray(capd).reshape(-1, cfg.d_model),
                                   np.asarray(dense), rtol=2e-4, atol=2e-4)

    def test_tokens_dropped_under_tight_capacity(self):
        cfg = cfg_for("moe", moe_capacity_factor=0.25)
        p = MOE.moe_init(jax.random.PRNGKey(5), cfg)
        x = rand((1, 64, cfg.d_model), 12, 0.5)
        out = MOE.moe_apply(p, x, cfg, dispatch_chunk=64)
        assert jnp.isfinite(out).all()


# ---------------------------------------------------------------------------
# prefill + decode == full forward (per family)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "moe", "rwkv", "hybrid"])
def test_prefill_decode_consistency(family):
    cfg = cfg_for(family)
    params = Z.init_params(cfg, jax.random.PRNGKey(6))
    S = 24
    rng = np.random.default_rng(13)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    dt = Z.act_dtype(cfg)

    # full forward logits at the last position
    x = L.embed(params["embed"], toks, dt)
    h = T.lm_apply_hidden(params, x, cfg)
    full_logits = L.logits_for_last(h[:, -1:], params["unembed"])

    # prefill S-1 tokens, then decode token S-1
    prefill = Z.make_prefill(cfg)
    serve = Z.make_serve_step(cfg)
    _, cache = prefill(params, toks[:, :S - 1], S + 8)
    logits, _ = serve(params, cache, toks[:, S - 1])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=3e-3, atol=3e-3)


def test_encdec_decode_consistency():
    cfg = cfg_for("encdec")
    params = Z.init_params(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(14)
    enc_embeds = rand((2, 16, cfg.d_model), 15, 0.5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)

    enc_out = ED.encode(params, enc_embeds, cfg)
    h = ED.decode_train(params, enc_out, toks, cfg)
    full_logits = L.logits_for_last(h[:, -1:], params["unembed"])

    cache = ED.encdec_init_cache(params, enc_out, cfg, 16,
                                 dtype=jnp.float32)
    serve = Z.make_serve_step(cfg)
    logits = None
    for t in range(8):
        logits, cache = serve(params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=3e-3, atol=3e-3)


def test_vlm_prefix_changes_loss():
    cfg = cfg_for("vlm")
    params = Z.init_params(cfg, jax.random.PRNGKey(8))
    loss_fn = Z.make_loss_fn(cfg)
    rng = np.random.default_rng(16)
    batch = {
        "patch_embeds": rand((2, 4, cfg.d_model), 17, 1.0),
        "inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 28))),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 28))),
    }
    l1 = float(loss_fn(params, batch))
    batch2 = dict(batch, patch_embeds=batch["patch_embeds"] * 3.0)
    l2 = float(loss_fn(params, batch2))
    assert np.isfinite(l1) and np.isfinite(l2) and l1 != l2


def test_gradients_flow_all_families():
    for family in ["dense", "moe", "rwkv", "hybrid"]:
        cfg = cfg_for(family)
        params = Z.init_params(cfg, jax.random.PRNGKey(9))
        loss_fn = Z.make_loss_fn(cfg)
        rng = np.random.default_rng(18)
        batch = {
            "inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16))),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16))),
        }
        grads = jax.grad(loss_fn)(params, batch)
        gn = sum(float(jnp.abs(g).sum())
                 for g in jax.tree_util.tree_leaves(grads))
        assert np.isfinite(gn) and gn > 0, family
