"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family, run one forward/train step (and one decode step) on CPU, assert
output shapes and no NaNs.  The FULL configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.core import otaro as otaro_lib
from repro.models import model_zoo as Z
from repro.models.config import SHAPES, shape_applicable
from repro.train import optimizer as opt_lib

ARCHS = C.list_archs()


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = lambda s: jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)),
                                 jnp.int32)
    if cfg.is_encdec:
        return {
            "enc_embeds": jnp.asarray(
                rng.normal(size=(B, max(8, S // 4), cfg.d_model)),
                jnp.float32),
            "inputs": toks(S), "targets": toks(S),
        }
    if cfg.family == "vlm":
        npfx = cfg.n_prefix_embeds
        return {
            "patch_embeds": jnp.asarray(
                rng.normal(size=(B, npfx, cfg.d_model)), jnp.float32),
            "inputs": toks(S - npfx), "targets": toks(S - npfx),
        }
    return {"inputs": toks(S), "targets": toks(S)}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact(arch):
    """The full config matches the assigned spec (no silent edits)."""
    cfg = C.get_config(arch)
    spec = {
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen2_0_5b": (24, 896, 14, 2, 4864, 151936),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "yi_9b": (48, 4096, 32, 4, 11008, 64000),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
        "seamless_m4t_large_v2": (48, 1024, 16, 16, 8192, 256206),
    }.get(arch)
    if spec is None:
        return  # paper's own eval models, spec'd in their files
    L_, d, h, kv, ff, v = spec
    assert cfg.n_layers == L_
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = C.get_reduced(arch)
    params = Z.init_params(cfg, jax.random.PRNGKey(1))
    loss_fn = Z.make_loss_fn(cfg)
    batch = make_batch(cfg)

    # one OTARo train step (the framework's real step function)
    ocfg = otaro_lib.OTAROConfig(mode="otaro", laa_n=2)
    opt = opt_lib.sgd(1e-3)
    step = jax.jit(otaro_lib.make_otaro_step(loss_fn, opt, ocfg))
    state = otaro_lib.init_state(params, opt, ocfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert not jnp.isnan(leaf).any(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = C.get_reduced(arch)
    params = Z.init_params(cfg, jax.random.PRNGKey(2))
    B = 2
    serve = jax.jit(Z.make_serve_step(cfg))
    if cfg.is_encdec:
        from repro.models import encdec as ED
        rng = np.random.default_rng(3)
        enc = jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.float32)
        enc_out = ED.encode(params, enc.astype(Z.act_dtype(cfg)), cfg)
        cache = Z.init_cache(cfg, params, B, 64, enc_out=enc_out)
    else:
        cache = Z.init_cache(cfg, params, B, 64)
    tok = jnp.ones((B,), jnp.int32)
    logits, cache = serve(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), arch
    # a second step advances pos and stays finite
    logits2, cache = serve(params, cache, tok)
    assert jnp.isfinite(logits2).all(), arch
    assert int(cache["pos"]) == 2


def test_shape_applicability_matrix():
    """The 40-cell matrix resolves exactly as DESIGN.md §5 documents."""
    runnable = {}
    for arch in C.ASSIGNED:
        cfg = C.get_config(arch)
        for sname, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            runnable[(arch, sname)] = ok
    # long_500k only for the sub-quadratic archs
    for arch in C.ASSIGNED:
        expect = arch in ("zamba2_7b", "rwkv6_7b")
        assert runnable[(arch, "long_500k")] == expect, arch
    # everything else runs
    for (arch, sname), ok in runnable.items():
        if sname != "long_500k":
            assert ok, (arch, sname)


def test_param_counts_plausible():
    """Full-config parameter counts are in the right ballpark (catches
    transposed dims / missing stacks) without allocating: eval_shape."""
    import math

    expect = {
        "minitron_8b": 8.0e9, "qwen2_0_5b": 0.5e9, "qwen2_1_5b": 1.5e9,
        "yi_9b": 8.8e9, "zamba2_7b": 7.5e9, "grok_1_314b": 314e9,
        "granite_moe_1b_a400m": 1.3e9, "rwkv6_7b": 7.5e9,
        "pixtral_12b": 12e9, "llama3_8b": 8e9, "llama3_2_1b": 1.2e9,
        "seamless_m4t_large_v2": 1.4e9,
    }
    for arch, target in expect.items():
        cfg = C.get_config(arch)
        shapes = jax.eval_shape(
            lambda: Z.init_params(cfg, jax.random.PRNGKey(0)))
        n = sum(math.prod(x.shape)
                for x in jax.tree_util.tree_leaves(shapes))
        ratio = n / target
        assert 0.5 < ratio < 2.1, (arch, n, target)
