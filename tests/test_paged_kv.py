"""Paged KV cache tests (DESIGN.md §13): allocator/prefix-cache units, and
the load-bearing serving invariants — a request served through the paged
continuous batcher (block tables, chunked prefill, prefix reuse) replays
BITWISE on the dense lockstep oracle at every SEFP width; shared pages are
read-only; corruption of one slot's exclusive page never perturbs a
co-resident sharing its prefix."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model_zoo as Z
from repro.models.config import ModelConfig
from repro.policy import PrecisionPolicy
from repro.serve import SwitchableServer
from repro.serve import pages as pages_lib
from repro.serve.faults import CacheCorruptionFault
from repro.serve.pages import PageAllocator, PageBudgetExceeded, PrefixCache

CFG = ModelConfig(name="paged-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, q_block=16, kv_block=16, loss_chunk=16,
                  remat="none", dtype="bfloat16")

HYBRID_CFG = ModelConfig(name="paged-hybrid", family="hybrid", n_layers=4,
                         d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab_size=256, head_dim=16, attn_every=2,
                         ssm_state=16, ssm_head_dim=16, q_block=16,
                         kv_block=16, loss_chunk=16, remat="none",
                         dtype="bfloat16")

RWKV_CFG = ModelConfig(name="paged-rwkv", family="rwkv", n_layers=2,
                       d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                       d_ff=256, vocab_size=256, rwkv_head_dim=32,
                       q_block=32, kv_block=32, loss_chunk=32, remat="none",
                       dtype="bfloat16")

PS = 8  # page size for every scheduler in this file


@pytest.fixture(scope="module")
def server():
    params = Z.init_params(CFG, jax.random.PRNGKey(0))
    srv = SwitchableServer(CFG, params, max_len=96)
    srv.set_policy(PrecisionPolicy.all_widths()
                   .with_class("m8", 8).with_class("m6", 6)
                   .with_class("m4", 4).with_class("m3", 3))
    return srv


def prompt(n, seed=0, vocab=256):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, (n,)).astype(np.int32)


def check_oracle(server, fr, p):
    sched, pm = fr.oracle_schedule()
    solo = server.generate(p[None], max_new=len(fr.tokens),
                           precision_schedule=sched, prefill_precision=pm)
    np.testing.assert_array_equal(fr.tokens, solo.tokens[0])


# ---------------------------------------------------------------------------
# host-side units: allocator, prefix keys, prefix cache
# ---------------------------------------------------------------------------

class TestPageAllocator:
    def test_alloc_free_refcount(self):
        a = PageAllocator(6)
        assert a.pages_free == 5 and a.pages_in_use == 0
        pg = a.alloc(3)
        assert len(set(pg)) == 3 and 0 not in pg
        assert a.pages_in_use == 3 and a.high_water == 3
        a.incref(pg[0])
        assert not a.decref(pg[0])  # one ref left -> not freed
        assert a.decref(pg[0])      # now freed
        assert a.pages_in_use == 2
        assert a.high_water == 3    # high-water sticks

    def test_budget_exceeded(self):
        a = PageAllocator(3)
        a.alloc(2)
        assert not a.can_alloc(1)
        with pytest.raises(PageBudgetExceeded):
            a.alloc(1)

    def test_null_page_never_handed_out(self):
        a = PageAllocator(4)
        assert 0 not in a.alloc(3)
        with pytest.raises(ValueError):
            a.incref(0)

    def test_request_pages_math(self):
        # prefill writes plen positions; decode writes up to
        # plen + max_new - 2 (the last token is never fed back)
        assert pages_lib.request_pages(8, 1, 8) == 1
        assert pages_lib.request_pages(8, 2, 8) == 2
        assert pages_lib.request_pages(9, 8, 8) == 2
        assert pages_lib.request_pages(16, 10, 8) == 4


class TestPrefixCache:
    def test_chain_keys_depend_on_history_and_width(self):
        p = prompt(24, seed=1)
        k_a = pages_lib.prefix_keys(p, 8, 4)
        assert len(k_a) == 3
        # same page-2 tokens, different page-0 history -> different key
        q = p.copy()
        q[0] ^= 1
        assert pages_lib.prefix_keys(q, 8, 4)[2] != k_a[2]
        # K/V bytes differ per prefill width: keys must too
        assert pages_lib.prefix_keys(p, 8, 8)[0] != k_a[0]

    def test_lookup_longest_run_and_insert(self):
        a = PageAllocator(8)
        c = PrefixCache(a)
        pg = a.alloc(3)
        assert c.insert("k0", pg[0]) and c.insert("k1", pg[1])
        assert not c.insert("k0", pg[2])  # first producer wins
        assert c.lookup(["k0", "k1", "k2"]) == [pg[0], pg[1]]
        assert c.lookup(["kX", "k0"]) == []  # a chain: miss stops the run

    def test_evict_skips_referenced_pages(self):
        a = PageAllocator(4)
        c = PrefixCache(a)
        pg = a.alloc(3)
        for i, p in enumerate(pg):
            c.insert(f"k{i}", p)
            assert a.decref(p) is False  # cache ref keeps the page alive
        a.incref(pg[0])  # an "active reader" of page 0
        freed = c.evict_for(2)
        assert pg[0] not in freed and len(freed) == 2
        assert a.ref(pg[0]) == 2  # untouched

    def test_purge_pages(self):
        a = PageAllocator(4)
        c = PrefixCache(a)
        pg = a.alloc(2)
        c.insert("k0", pg[0])
        c.insert("k1", pg[1])
        a.decref(pg[0]), a.decref(pg[1])
        freed = c.purge_pages([pg[0]])
        assert freed == [pg[0]] and len(c) == 1


# ---------------------------------------------------------------------------
# the serving invariants
# ---------------------------------------------------------------------------

class TestPagedOracle:
    def test_bitwise_oracle_every_width(self, server):
        """Paged continuous serving replays bitwise on the dense lockstep
        engine at m in {8, 6, 4, 3} — the acceptance criterion."""
        sched = server.continuous(slots=4, page_size=PS)
        ps = {}
        for i, cls in enumerate(("m8", "m6", "m4", "m3")):
            p = prompt(11 + 7 * i, seed=i)
            ps[sched.submit(p, max_new=8, request_class=cls, seed=i)] = p
        fin = sched.drain()
        assert len(fin) == 4
        for rid, fr in fin.items():
            assert fr.status == "ok"
            check_oracle(server, fr, ps[rid])

    def test_mixed_sampling_oracle(self, server):
        """Stochastic sampling + width-rr stalls, still bitwise."""
        sched = server.continuous(slots=3, page_size=PS,
                                  width_policy="width-rr")
        ps, seeds = {}, {}
        for i, cls in enumerate(("m8", "m4", "m4")):
            p = prompt(9 + 5 * i, seed=20 + i)
            rid = sched.submit(p, max_new=6, request_class=cls,
                               temperature=0.8, top_k=7, seed=31 + i)
            ps[rid], seeds[rid] = p, 31 + i
        fin = sched.drain()
        for rid, fr in fin.items():
            sc, pm = fr.oracle_schedule()
            solo = server.generate(ps[rid][None], max_new=len(fr.tokens),
                                   precision_schedule=sc,
                                   prefill_precision=pm,
                                   temperature=0.8, top_k=7,
                                   seed=seeds[rid])
            np.testing.assert_array_equal(fr.tokens, solo.tokens[0])


class TestChunkedPrefill:
    def test_chunked_equals_whole_prefill(self, server):
        """Splitting a prefill into chunks is bitwise-neutral: the same
        workload with prefill_chunk=5 produces identical token streams to
        the whole-prompt prefill."""
        work = [(prompt(23, seed=40 + i), 7, i) for i in range(3)]
        streams = []
        for chunk in (None, 5):
            sched = server.continuous(slots=2, page_size=PS,
                                      prefill_chunk=chunk,
                                      prefix_cache=False)
            rids = [sched.submit(p, max_new=mn, request_class="m6", seed=s)
                    for p, mn, s in work]
            fin = sched.drain()
            streams.append([fin[r].tokens for r in rids])
            if chunk is not None:
                assert sched.stats["prefill_chunks"] >= 3 * 5  # 23/5 -> 5
        for a, b in zip(*streams):
            np.testing.assert_array_equal(a, b)

    def test_decode_never_stalls_behind_long_prefill(self, server):
        """A long document arriving mid-decode must not stall the decode
        clock: chunks interleave, decode_stall_steps stays 0 and the short
        request's stream is bitwise the oracle's."""
        sched = server.continuous(slots=2, page_size=PS, prefill_chunk=4,
                                  prefix_cache=False)
        p_short = prompt(6, seed=50)
        rid_s = sched.submit(p_short, max_new=12, request_class="m8",
                             seed=50)
        for _ in range(2):
            sched.step()
        p_long = prompt(48, seed=51)
        rid_l = sched.submit(p_long, max_new=4, request_class="m4", seed=51)
        fin = sched.drain()
        assert sched.stats["decode_stall_steps"] == 0
        check_oracle(server, fin[rid_s], p_short)
        check_oracle(server, fin[rid_l], p_long)


class TestPrefixReuse:
    def test_reuse_hits_and_stays_bitwise(self, server):
        """A second request sharing the first's prompt prefix adopts its
        pages (hit count > 0, prefill compute skipped) and still replays
        bitwise on the oracle."""
        sched = server.continuous(slots=2, page_size=PS)
        p = prompt(26, seed=60)
        r0 = sched.submit(p, max_new=6, request_class="m4", seed=60)
        fin0 = sched.drain()
        check_oracle(server, fin0[r0], p)
        r1 = sched.submit(p, max_new=9, request_class="m4", seed=61)
        fin1 = sched.drain()
        st = sched.stats["pages"]
        assert st["prefix_cache"]["hits"] >= 3  # 26 tokens -> 3 full pages
        assert st["reused_pages"] >= 3
        check_oracle(server, fin1[r1], p)

    def test_no_reuse_across_widths(self, server):
        """K/V bytes depend on the prefill width, so a prefix prefilled at
        m=8 must never serve an m=4 request."""
        sched = server.continuous(slots=2, page_size=PS)
        p = prompt(26, seed=62)
        sched.submit(p, max_new=4, request_class="m8", seed=62)
        sched.drain()
        hits0 = sched.stats["pages"]["prefix_cache"]["hits"]
        r1 = sched.submit(p, max_new=4, request_class="m4", seed=63)
        fin = sched.drain()
        assert sched.stats["pages"]["prefix_cache"]["hits"] == hits0
        check_oracle(server, fin[r1], p)

    def test_shared_pages_cow_divergent_suffixes(self, server):
        """Two concurrent requests sharing a prompt prefix but with
        divergent suffixes: shared pages are read-only (ref > 1 while both
        are active), the divergent tails live in exclusive pages, and both
        streams replay bitwise."""
        sched = server.continuous(slots=2, page_size=PS)
        head = prompt(16, seed=64)  # two full shared pages
        pa = np.concatenate([head, prompt(7, seed=65)])
        pb = np.concatenate([head, prompt(9, seed=66)])
        ra = sched.submit(pa, max_new=5, request_class="m6", seed=65)
        fina = sched.drain()
        rb = sched.submit(pb, max_new=5, request_class="m6", seed=66)
        ra2 = sched.submit(pa, max_new=5, request_class="m6", seed=67)
        sched.step()  # admit both sharers
        # the shared prefix pages are referenced by the cache AND both
        # active slots while decoding: read-only by refcount
        shared_refs = [sched._allocator.ref(pg)
                       for _, s in sched._table.active()
                       for pg in s.pages[:s.n_reused]]
        assert shared_refs and all(r >= 3 for r in shared_refs)
        finb = sched.drain()
        assert sched.stats["pages"]["prefix_cache"]["hits"] >= 4
        check_oracle(server, fina[ra], pa)
        check_oracle(server, finb[rb], pb)
        check_oracle(server, finb[ra2], pa)

    def test_whole_prompt_cached_still_computes_first_token(self, server):
        """Even a fully page-aligned, fully-cached prompt prefills its last
        token live (the reuse cap): first-token logits come from compute,
        never from the cache."""
        sched = server.continuous(slots=2, page_size=PS)
        p = prompt(24, seed=68)  # exactly 3 pages
        sched.submit(p, max_new=4, request_class="m6", seed=68)
        sched.drain()
        r1 = sched.submit(p, max_new=4, request_class="m6", seed=69)
        fin = sched.drain()
        # only 2 of the 3 full pages may be adopted
        assert sched.stats["pages"]["reused_pages"] == 2
        check_oracle(server, fin[r1], p)


class TestPageBudget:
    def test_admission_gates_on_pages(self, server):
        """With a page pool too small for two long requests, the second
        blocks at the queue head until the first retires — and everything
        still finishes, bitwise."""
        sched = server.continuous(slots=4, page_size=PS, n_pages=11,
                                  prefix_cache=False)
        ps = {}
        for i in range(3):
            p = prompt(40, seed=70 + i)  # 40+8-1 -> 6 pages each
            ps[sched.submit(p, max_new=8, request_class="m8",
                            seed=70 + i)] = p
        fin = sched.drain()
        assert len(fin) == 3
        assert sched.stats["pages"]["page_blocked_admissions"] > 0
        assert sched.stats["pages"]["high_water"] <= 10
        for rid, fr in fin.items():
            assert fr.status == "ok"
            check_oracle(server, fr, ps[rid])

    def test_infeasible_request_rejected_at_submit(self, server):
        sched = server.continuous(slots=2, page_size=PS, n_pages=4)
        with pytest.raises(ValueError, match="pages"):
            sched.submit(prompt(40, seed=75), max_new=8)

    def test_memory_report_kv_section(self, server):
        sched = server.continuous(slots=2, page_size=PS)
        rep = sched.memory_report()
        kv = rep["kv_cache"]
        assert kv["paged"] and kv["page_size"] == PS
        # [L, n_pages, ps, KV, hd] x {k,v} bf16
        expect = 2 * CFG.n_layers * PS * CFG.n_kv_heads * 16 * 2
        assert kv["bytes_per_page"] == expect
        assert kv["total_bytes"] == expect * kv["n_pages"]
        p = prompt(20, seed=76)
        sched.submit(p, max_new=4, seed=76)
        sched.drain()
        assert sched.memory_report()["kv_cache"]["high_water"] >= 3
        assert "master_bytes" in rep  # server report still included


class TestRecurrentFamilies:
    def test_rwkv_unaffected(self):
        """rwkv has no attention KV: the scheduler runs it dense (pages
        stats None) and the oracle property is untouched."""
        params = Z.init_params(RWKV_CFG, jax.random.PRNGKey(3))
        srv = SwitchableServer(RWKV_CFG, params, max_len=64)
        sched = srv.continuous(slots=2)
        p = prompt(12, seed=80)
        rid = sched.submit(p, max_new=6, seed=80)
        fin = sched.drain()
        assert sched.stats["pages"] is None
        assert sched.memory_report()["kv_cache"] == {
            "paged": False, "family": "rwkv"}
        check_oracle(srv, fin[rid], p)

    def test_hybrid_paged_attention_dense_ssm(self):
        """hybrid pages its attention KV (whole-prompt install, no
        chunking/reuse) while Mamba2 state stays dense — bitwise on the
        lockstep oracle."""
        params = Z.init_params(HYBRID_CFG, jax.random.PRNGKey(4))
        srv = SwitchableServer(HYBRID_CFG, params, max_len=64)
        sched = srv.continuous(slots=2, page_size=PS)
        ps = {}
        for i in range(3):
            p = prompt(9 + 6 * i, seed=90 + i)
            ps[sched.submit(p, max_new=6, seed=90 + i)] = p
        fin = sched.drain()
        assert sched.stats["pages"] is not None
        assert sched.stats["pages"]["prefix_cache"] is None
        for rid, fr in fin.items():
            check_oracle(srv, fr, ps[rid])


class TestSharedPageContainment:
    def test_corruption_contained_under_shared_pages(self, server):
        """CacheCorruptionFault under prefix sharing: the fault lands in
        the victim's first EXCLUSIVE page (never a shared one), the victim
        quarantines, and a co-resident actively sharing its prefix pages
        streams bitwise what the no-fault run streams."""
        head = prompt(16, seed=100)
        pa = np.concatenate([head, prompt(5, seed=101)])
        pb = np.concatenate([head, prompt(3, seed=102)])

        def run(with_fault):
            sched = server.continuous(slots=2, page_size=PS)
            # seed the prefix cache, then run both sharers concurrently
            sched.submit(head, max_new=2, request_class="m6", seed=99)
            sched.drain()
            fault = None
            if with_fault:
                # both sharers decode from the next step on; fire two
                # steps in (the clock is deterministic, so the clean and
                # faulted runs line up exactly)
                fault = CacheCorruptionFault(slot=0, step=sched.clock + 2)
                sched.inject(fault)
            ra = sched.submit(pa, max_new=10, request_class="m6", seed=101)
            rb = sched.submit(pb, max_new=10, request_class="m6", seed=102)
            fin = sched.drain(max_steps=100)
            return fin[ra], fin[rb], fault

        clean_a, clean_b, _ = run(False)
        fa, fb, fault = run(True)
        assert fault.fired and fault.fired[0]["leaves_corrupted"] > 0
        assert fault.fired[0]["page"] is not None
        # slot 0 was the victim: it held request A
        assert fa.status == "poisoned"
        # the survivor, which READS the same shared prefix pages, is
        # bitwise identical to the no-fault run
        assert fb.status == "ok"
        np.testing.assert_array_equal(fb.tokens, clean_b.tokens)
        # and the victim's committed prefix is clean too
        np.testing.assert_array_equal(
            fa.tokens, clean_a.tokens[:len(fa.tokens)])
        # poisoned retire purged the victim's published pages
        check_oracle(server, fb, pb)

    def test_corrupted_pages_never_resold(self, server):
        """After a poisoned retire, the victim's pages are scrubbed and
        its published prefix entries purged — a re-submission of the same
        prompt re-prefills and replays bitwise."""
        p = prompt(20, seed=110)
        sched = server.continuous(slots=1, page_size=PS)
        fault = CacheCorruptionFault(slot=0, step=3)
        sched.inject(fault)
        r0 = sched.submit(p, max_new=10, request_class="m4", seed=110)
        fin0 = sched.drain(max_steps=60)
        assert fin0[r0].status == "poisoned"
        r1 = sched.submit(p, max_new=6, request_class="m4", seed=110)
        fin1 = sched.drain(max_steps=60)
        assert fin1[r1].status == "ok"
        check_oracle(server, fin1[r1], p)
